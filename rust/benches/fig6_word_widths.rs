//! Figure 6 regenerator: equal bit capacity at different word widths —
//! the 32-bit (512+128) framework vs the 128-bit (128+32) framework with
//! OSR, over cycle lengths 8→1024. The paper's shape: the wide framework
//! stays at one output per cycle ("copying four 32-bit words per write
//! cycle") while the narrow one doubles past its level-1 capacity.

use memhier::report::{fig6_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    let table = fig6_table().expect("fig6 simulation");
    println!("=== Figure 6: 32-bit vs 128-bit word width, equal capacity ===\n");
    println!("{}", table.render());
    let rows: Vec<Vec<u64>> = table
        .to_csv()
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|v| v.parse().unwrap()).collect())
        .collect();
    let at = |cl: u64, col: usize| rows.iter().find(|r| r[0] == cl).unwrap()[col] as f64;
    // At cycle length 256 (past the 32-bit config's L1 but within the
    // 128-bit config's level 0) the wide framework stays near-optimal.
    assert!(at(256, 1) / at(256, 3) > 1.6, "wide word width hides replacement");
    assert!(at(256, 3) < 5_600.0, "128-bit config near one output/cycle");
    // And it holds across the whole L0-resident range.
    for cl in [8u64, 64, 256, 512] {
        assert!(at(cl, 3) < 6_000.0, "wide config optimal at l={cl}");
    }
    let path = save_csv(&table, "fig6").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

//! Restart vs. resume successive-halving sweep — the checkpoint layer's
//! headline number.
//!
//! The restart strategy (`explore_halving_restart`, the pre-checkpoint
//! behavior) re-runs every undecided candidate from cycle zero at each
//! rung and restarts the survivors' full runs, so the screening prefix is
//! simulated up to once per rung plus once more per survivor. The resume
//! strategy (`explore_halving`) suspends each candidate into a
//! `HierarchyCheckpoint` at the end of a rung and resumes it at the next,
//! paying every simulated cycle exactly once. Both produce bitwise-
//! identical Pareto fronts (asserted here); this bench measures the
//! wall-clock gap and the saved-cycle ratio, and writes the numbers to
//! `BENCH_halving.json` so CI can publish the perf trajectory.

use memhier::benchkit::Bencher;
use memhier::dse::{
    explore, explore_halving, explore_halving_restart, HalvingSchedule, HierarchyPool,
    KindChoice, SearchSpace,
};
use memhier::pattern::PatternProgram;

/// The seeded space the `checkpoint` tests assert front equality on
/// (kept identical so the bench's sanity asserts track the same
/// invariant).
fn space() -> SearchSpace {
    SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128, 1024],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn workload() -> PatternProgram {
    PatternProgram::cyclic(0, 256).with_outputs(2_560)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);

    // Sanity first: restart, resume, and the exhaustive sweep agree on
    // the front (the acceptance invariant the tests also hold).
    let restarted = explore_halving_restart(&space, &w, &schedule).expect("restart sweep");
    let resumed = explore_halving(&space, &w, &schedule).expect("resume sweep");
    assert_eq!(restarted.points.len(), resumed.points.len());
    for (a, c) in restarted.points.iter().zip(resumed.points.iter()) {
        assert_eq!(a.config, c.config, "restart vs resume point sets diverged");
        assert_eq!(a.cycles, c.cycles);
        assert_eq!(a.area.to_bits(), c.area.to_bits());
        assert_eq!(a.on_front, c.on_front);
    }
    let exhaustive_front =
        explore(&space, &w).expect("exhaustive sweep").iter().filter(|p| p.on_front).count();
    let resumed_front = resumed.points.iter().filter(|p| p.on_front).count();
    assert_eq!(exhaustive_front, resumed_front, "resume front must equal exhaustive front");

    let restart = b.bench("dse/halving_restart", || {
        explore_halving_restart(&space, &w, &schedule).unwrap().points.len()
    });
    println!("{}", restart.summary());
    let resume = b.bench("dse/halving_resume", || {
        explore_halving(&space, &w, &schedule).unwrap().points.len()
    });
    let speedup = restart.mean.as_secs_f64() / resume.mean.as_secs_f64();
    println!("{}  -> {speedup:.2}x vs restart", resume.summary());

    // Pooled resume for scaling context.
    let pool = HierarchyPool::new(0);
    let pooled = b.bench("dse/halving_resume_pooled", || {
        pool.explore_halving(&space, &w, &schedule).unwrap().points.len()
    });
    let pooled_speedup = restart.mean.as_secs_f64() / pooled.mean.as_secs_f64();
    println!("{}  -> {pooled_speedup:.2}x vs serial restart", pooled.summary());

    let st = &resumed.stats;
    // Fraction of the resumed runs' cycle positions inherited from
    // checkpoints rather than re-simulated.
    let saved_ratio = if st.saved_cycles + st.resumed_cycles > 0 {
        st.saved_cycles as f64 / (st.saved_cycles + st.resumed_cycles) as f64
    } else {
        0.0
    };
    println!(
        "resume work: {} candidates, {} pruned, {} saved cycles, {} resumed-delta cycles \
         (saved ratio {:.2})",
        st.candidates, st.pruned, st.saved_cycles, st.resumed_cycles, saved_ratio
    );
    assert!(st.saved_cycles > 0, "the default workload must exercise resume: {st:?}");

    let json = format!(
        "{{\n  \"bench\": \"halving_resume\",\n  \"quick\": {quick},\n  \
         \"restart_mean_ns\": {},\n  \"resume_mean_ns\": {},\n  \
         \"pooled_resume_mean_ns\": {},\n  \"speedup\": {speedup:.4},\n  \
         \"pooled_speedup\": {pooled_speedup:.4},\n  \"candidates\": {},\n  \
         \"pruned\": {},\n  \"screen_exact\": {},\n  \"full_runs\": {},\n  \
         \"saved_cycles\": {},\n  \"resumed_cycles\": {},\n  \"saved_ratio\": {saved_ratio:.4}\n}}\n",
        restart.mean.as_nanos(),
        resume.mean.as_nanos(),
        pooled.mean.as_nanos(),
        st.candidates,
        st.pruned,
        st.screen_exact,
        st.full_runs,
        st.saved_cycles,
        st.resumed_cycles,
    );
    std::fs::write("BENCH_halving.json", &json).expect("write BENCH_halving.json");
    println!("\nwrote BENCH_halving.json");
    println!("halving_resume done");
}

//! Fault-campaign throughput and detection coverage — the resilience
//! layer's headline numbers.
//!
//! A campaign (`sim::fault::run_campaign`) replays a seeded set of
//! randomized upset plans against one warm hierarchy and classifies each
//! run as masked / corrected / detected / silent / hung. This bench
//! measures campaign throughput (faulted runs per second) for an
//! unprotected hierarchy and for the same hierarchy under SECDED, and
//! writes the coverage summary — how the outcome distribution shifts as
//! per-level protection is turned on — to `BENCH_fault.json` so CI can
//! publish the trajectory.

use memhier::benchkit::Bencher;
use memhier::config::{HierarchyConfig, Protection};
use memhier::pattern::PatternProgram;
use memhier::sim::fault::{run_campaign, run_campaign_protected, FaultCampaignStats};

/// Faulted runs per campaign (the unit the throughput numbers are per).
const RUNS: u64 = 48;
const RUNS_QUICK: u64 = 12;
const SEED: u64 = 0xFA117_CA3D;

fn cfg() -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1024, 1, 1)
        .level(32, 128, 1, 2)
        .build()
        .expect("bench config valid")
}

fn workload() -> PatternProgram {
    PatternProgram::cyclic(0, 64).with_outputs(640)
}

/// JSON fragment for one campaign's outcome tally.
fn coverage_json(label: &str, s: &FaultCampaignStats) -> String {
    format!(
        "  \"{label}\": {{\n    \"runs\": {},\n    \"events_scheduled\": {},\n    \
         \"masked\": {},\n    \"corrected\": {},\n    \"detected\": {},\n    \
         \"silent\": {},\n    \"hung\": {},\n    \"vulnerability\": {:.4}\n  }}",
        s.total.runs,
        s.events_scheduled,
        s.total.masked,
        s.total.corrected,
        s.total.detected,
        s.total.silent,
        s.total.hung,
        s.total.vulnerability(),
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let runs = if quick { RUNS_QUICK } else { RUNS };
    let cfg = cfg();
    let w = workload();

    // Sanity first: the campaign is deterministic under its seed, and
    // protection never makes coverage worse — SECDED eliminates silent
    // corruption from level upsets entirely (the acceptance invariant
    // `tests/fault.rs` also holds).
    let plain = run_campaign(&cfg, &w, SEED, runs).expect("unprotected campaign");
    let again = run_campaign(&cfg, &w, SEED, runs).expect("repeat campaign");
    assert_eq!(plain, again, "seeded campaigns must be reproducible");
    let parity = run_campaign_protected(&cfg, &w, Protection::Parity, SEED, runs)
        .expect("parity campaign");
    let secded = run_campaign_protected(&cfg, &w, Protection::Secded, SEED, runs)
        .expect("secded campaign");
    for (label, tally) in parity.per_component.iter().chain(secded.per_component.iter()) {
        if label.starts_with('L') {
            assert_eq!(tally.silent, 0, "protected level {label} must never corrupt silently");
        }
    }

    let plain_r = b.bench("fault/campaign_unprotected", || {
        run_campaign(&cfg, &w, SEED, runs).unwrap().total.runs
    });
    let plain_rps = runs as f64 / plain_r.mean.as_secs_f64();
    println!("{}  -> {plain_rps:.1} faulted runs/s", plain_r.summary());

    let secded_r = b.bench("fault/campaign_secded", || {
        run_campaign_protected(&cfg, &w, Protection::Secded, SEED, runs).unwrap().total.runs
    });
    let secded_rps = runs as f64 / secded_r.mean.as_secs_f64();
    println!("{}  -> {secded_rps:.1} faulted runs/s", secded_r.summary());

    println!(
        "coverage: unprotected {}/{} silent, parity {} detected, secded {} corrected",
        plain.total.silent, plain.total.runs, parity.total.detected, secded.total.corrected
    );

    let json = format!(
        "{{\n  \"bench\": \"fault_campaign\",\n  \"quick\": {quick},\n  \"runs\": {runs},\n  \
         \"unprotected_mean_ns\": {},\n  \"secded_mean_ns\": {},\n  \
         \"unprotected_runs_per_s\": {plain_rps:.2},\n  \"secded_runs_per_s\": {secded_rps:.2},\n\
         {},\n{},\n{}\n}}\n",
        plain_r.mean.as_nanos(),
        secded_r.mean.as_nanos(),
        coverage_json("coverage_none", &plain),
        coverage_json("coverage_parity", &parity),
        coverage_json("coverage_secded", &secded),
    );
    std::fs::write("BENCH_fault.json", &json).expect("write BENCH_fault.json");
    println!("\nwrote BENCH_fault.json");
    println!("fault_campaign done");
}

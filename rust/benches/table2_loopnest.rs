//! Table 2 regenerator: per-layer type, unique weight addresses and cycle
//! length of the TC-ResNet, derived from the layer table and cross-checked
//! against the loop-nest analyzer's trace classification.

use memhier::loopnest::unroll::paper_sweep;
use memhier::loopnest::{analyze_layer, LoopOrder};
use memhier::model::tc_resnet8;
use memhier::model::tcresnet::{TABLE2_CYCLE_LENGTHS, TABLE2_UNIQUE_ADDRESSES};
use memhier::report::{save_csv, table2};

fn main() {
    let t0 = std::time::Instant::now();
    let table = table2();
    println!("=== Table 2: TC-ResNet layer characterization ===\n");
    println!("{}", table.render());

    // Exact match against the paper's table.
    let layers = tc_resnet8();
    for (l, (&u, &c)) in layers.iter().zip(TABLE2_UNIQUE_ADDRESSES.iter().zip(TABLE2_CYCLE_LENGTHS.iter())) {
        assert_eq!(l.weights(), u, "layer {} unique addresses", l.idx);
        assert_eq!(l.cycle_length(), c, "layer {} cycle length", l.idx);
    }
    println!("all 13 rows match the paper exactly.");

    // Loop-nest cross-check: under the UltraTrail unrolling, the traced
    // weight reuse equals the cycle-length column for aligned conv layers.
    let u = paper_sweep()[3].1;
    let mut checked = 0;
    for l in layers.iter().filter(|l| l.k % 8 == 0 && l.c % 8 == 0) {
        let a = analyze_layer(l, &u, LoopOrder::ultratrail());
        assert!(
            (a.weight_reuse - l.x as f64).abs() < 1e-9,
            "layer {}: traced reuse {} vs X {}",
            l.idx,
            a.weight_reuse,
            l.x
        );
        checked += 1;
    }
    println!("loop-nest trace cross-check passed on {checked} aligned conv layers.");
    let path = save_csv(&table, "table2").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

//! Ablation studies for the design choices DESIGN.md calls out, including
//! the paper's own §5.4 proposals:
//!
//! 1. **Dual-banked single-ported WMEM** — §5.4: "A slight redesign with a
//!    dual-banked, single-ported hierarchy could solve this [power] issue
//!    with only a minor chip area overhead." We quantify it.
//! 2. **Input-buffer depth** — the single-register handshake vs the
//!    pipelined FIFO, on the case-study supply path.
//! 3. **Preloading** — the §5.2.1 knob across pattern shapes.
//! 4. **OSR vs no OSR** — what the wide-word configuration loses without
//!    the output shift register.

use memhier::accel::UltraTrail;
use memhier::config::{HierarchyConfig, PortKind};
use memhier::cost::{constants, hierarchy_area, level_leakage, run_power};
use memhier::mem::Hierarchy;
use memhier::model::tc_resnet8;
use memhier::pattern::PatternProgram;
use memhier::sim::SimStats;
use memhier::util::table::{fnum, fpct, TextTable};

fn main() {
    ablation_dual_banked_wmem();
    ablation_ib_depth();
    ablation_preload();
    println!("\nablations done");
}

/// §5.4: replace the case study's dual-ported 104×128 level with two
/// single-ported 52×128 banks — same capacity, single-ported leakage.
fn ablation_dual_banked_wmem() {
    println!("=== Ablation 1: dual-ported vs dual-banked single-ported WMEM (§5.4) ===\n");
    let ut = UltraTrail::default();
    let dp = ut.hierarchy_wmem_config(true);
    let banked = HierarchyConfig::builder()
        .offchip(32, 24, 4.0)
        .ib_depth(8)
        .level(128, 52, 2, 1) // two single-ported banks
        .osr(384, vec![384])
        .preload(true)
        .build()
        .unwrap();

    let mut t = TextTable::new(vec!["metric", "dual-ported", "dual-banked SP", "delta"]);
    let a_dp = hierarchy_area(&dp).total;
    let a_bk = hierarchy_area(&banked).total;
    t.row(vec![
        "wmem area um2".to_string(),
        fnum(a_dp, 0),
        fnum(a_bk, 0),
        fpct((a_bk / a_dp - 1.0) * 100.0),
    ]);
    let leak_dp: f64 = dp.levels.iter().map(level_leakage).sum();
    let leak_bk: f64 = banked.levels.iter().map(level_leakage).sum();
    t.row(vec![
        "macro leakage nW".to_string(),
        fnum(leak_dp * 1e9, 1),
        fnum(leak_bk * 1e9, 1),
        fpct((leak_bk / leak_dp - 1.0) * 100.0),
    ]);
    // Supply timing on the worst layer (11).
    let l11 = tc_resnet8()[11];
    let sup = |cfg: &HierarchyConfig| ut.layer_supply(&l11, cfg).unwrap().internal_cycles;
    let s_dp = sup(&dp);
    let s_bk = sup(&banked);
    t.row(vec![
        "layer-11 supply cycles".to_string(),
        s_dp.to_string(),
        s_bk.to_string(),
        fpct((s_bk as f64 / s_dp as f64 - 1.0) * 100.0),
    ]);
    // Whole-chip power with each WMEM (aggregate one inference).
    let chip_power = |cfg: &HierarchyConfig| {
        let mut agg = SimStats::new(cfg.levels.len());
        let mut cycles = 0;
        for l in &tc_resnet8() {
            let s = ut.layer_supply(l, cfg).unwrap();
            cycles += ut.steps(l).max(s.internal_cycles);
            agg.offchip_reads += s.offchip_reads;
            agg.cdc_transfers += s.cdc_transfers;
            agg.osr_shifts += s.osr_shifts;
            for i in 0..cfg.levels.len() {
                agg.level_reads[i] += s.level_reads[i];
                agg.level_writes[i] += s.level_writes[i];
            }
        }
        agg.internal_cycles = cycles;
        constants().ut_rest_power + run_power(cfg, &agg, 250e3).total
    };
    let p_dp = chip_power(&dp);
    let p_bk = chip_power(&banked);
    t.row(vec![
        "chip power uW".to_string(),
        fnum(p_dp * 1e6, 2),
        fnum(p_bk * 1e6, 2),
        fpct((p_bk / p_dp - 1.0) * 100.0),
    ]);
    println!("{}", t.render());
    // §5.4's prediction: banked SP cuts power at minor area overhead.
    assert!(p_bk < p_dp, "dual-banked SP must reduce power (leakage)");
    assert!(leak_bk < 0.3 * leak_dp, "SP banks avoid the DP leakage penalty");
    assert!(a_bk < 1.25 * a_dp, "minor area overhead");
    println!(
        "§5.4 confirmed: dual-banked SP saves {:.1}% chip power at {:+.1}% wmem area\n",
        (1.0 - p_bk / p_dp) * 100.0,
        (a_bk / a_dp - 1.0) * 100.0
    );
}

/// Input-buffer depth on the case-study supply path.
fn ablation_ib_depth() {
    println!("=== Ablation 2: input-buffer depth (handshake vs FIFO) ===\n");
    let ut = UltraTrail::default();
    let l11 = tc_resnet8()[11];
    let mut t = TextTable::new(vec!["ib_depth", "layer11_supply", "vs_compute(1296)"]);
    for depth in [1u32, 2, 4, 8] {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(depth)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .build()
            .unwrap();
        let s = ut.layer_supply(&l11, &cfg).unwrap().internal_cycles;
        t.row(vec![depth.to_string(), s.to_string(), fnum(s as f64 / 1_296.0, 2)]);
    }
    println!("{}", t.render());
    println!("depth 1 reproduces §5.3.2's supply-bound layer 11; the FIFO hides it.\n");
}

/// Preloading across pattern shapes (§5.2.1).
fn ablation_preload() {
    println!("=== Ablation 3: preloading across pattern shapes ===\n");
    let mut t = TextTable::new(vec!["pattern", "no_preload", "preload", "gain"]);
    for (name, l, s) in [("cyclic l=64", 64u64, 0u64), ("shifted l=96 s=16", 96, 16), ("sequential", 64, 64)] {
        let run = |pre: bool| {
            let cfg = HierarchyConfig::builder()
                .offchip(32, 24, 1.0)
                .level(32, 512, 1, 1)
                .level(32, 128, 1, 2)
                .preload(pre)
                .build()
                .unwrap();
            let mut h = Hierarchy::new(&cfg).unwrap();
            h.load_program(&PatternProgram::shifted_cyclic(0, l, s).with_outputs(4_992)).unwrap();
            h.set_verify(false);
            h.run().unwrap().stats.internal_cycles
        };
        let a = run(false);
        let b = run(true);
        t.row(vec![
            name.to_string(),
            a.to_string(),
            b.to_string(),
            fpct((1.0 - b as f64 / a as f64) * -100.0 * -1.0),
        ]);
        assert!(b <= a, "preload never slower");
    }
    println!("{}", t.render());
    // The port-kind sanity check from the §5.4 discussion.
    let _ = PortKind::Single;
}

//! DSE pool scaling benchmark (the parallel-sweep deliverable): serial
//! `dse::explore` vs `HierarchyPool` at increasing worker counts on the
//! default `SearchSpace`, plus a bitwise-determinism cross-check.
//!
//! Expectation: ≥ 2× wall-clock speedup at 4 threads (the sweep is
//! embarrassingly parallel; the only serial parts are enumeration and
//! the Pareto merge, both negligible next to the simulations).

use memhier::benchkit::Bencher;
use memhier::dse::{explore, HierarchyPool, SearchSpace};
use memhier::pattern::PatternProgram;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let space = SearchSpace::default();
    let workload = PatternProgram::shifted_cyclic(0, 128, 32).with_outputs(5_120);

    let serial = b.bench("dse/explore_serial", || explore(&space, &workload).unwrap().len());
    println!("{}", serial.summary());

    for threads in [2usize, 4, 8] {
        let pool = HierarchyPool::new(threads);
        let name = format!("dse/pool_{threads}_threads");
        let r = b.bench(&name, || pool.explore(&space, &workload).unwrap().len());
        let speedup = serial.mean.as_secs_f64() / r.mean.as_secs_f64();
        println!("{}  -> {speedup:.2}x vs serial", r.summary());
    }

    // Determinism cross-check at 4 threads: the Pareto-front list must be
    // bitwise-identical to the serial path.
    let a = explore(&space, &workload).unwrap();
    let p = HierarchyPool::new(4).explore(&space, &workload).unwrap();
    assert_eq!(a.len(), p.len(), "point counts diverge");
    for (x, y) in a.iter().zip(&p) {
        assert_eq!(x.config, y.config);
        assert_eq!(x.area.to_bits(), y.area.to_bits());
        assert_eq!(x.power.to_bits(), y.power.to_bits());
        assert_eq!(x.cycles, y.cycles);
        assert_eq!(x.on_front, y.on_front);
    }
    println!(
        "\ndeterminism: pool(4) result bitwise-identical to serial over {} points — ok",
        a.len()
    );
    println!("dse_pool done");
}

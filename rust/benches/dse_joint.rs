//! Joint mapping × hierarchy co-exploration — the analytic-traffic
//! pruning headline number.
//!
//! The joint sweep crosses the loop-nest mapping menu (spatial unrolling
//! × temporal order, `dse::dims`) with the hierarchy-config odometer and
//! fronts on four axes (area, power, cycles, off-chip reads). The naive
//! nested sweep simulates every *(mapping, config)* pair; the production
//! path (`explore_joint`) puts the analytical bound-and-prune prescreen
//! and cross-mapping behavioral-class memoization in front, so most
//! candidates never reach the simulator. This bench gates the
//! acceptance claims: the joint space is >= 20x the config-only
//! candidate count, the pruned+memoized path simulates >= 5x fewer
//! cycles than naive, `bound_pruned + memo_hits` covers >= 80% of the
//! joint candidates, and the exact Pareto front stays bitwise-identical
//! to the naive sweep's — serial, pooled, halving, and sharded. Writes
//! `BENCH_joint.json` so CI can publish the trajectory.

use std::path::PathBuf;

use memhier::benchkit::Bencher;
use memhier::dse::{
    explore_joint, explore_joint_halving_pruned, explore_joint_naive, explore_joint_sharded,
    DesignPoint, HalvingSchedule, HierarchyPool, JointSpace, KindChoice, SearchSpace, ShardOptions,
};
use memhier::loopnest::LoopOrder;
use memhier::model::{LayerKind, LayerSpec};

/// Workers for the pooled and sharded contenders.
const FLEET: usize = 4;

/// The bench joint space: a small conv layer whose 70-strong mapping
/// menu collapses onto 15 distinct weight streams (the cross-mapping
/// memoization win), crossed with a stall-light standard-level config
/// space whose deep stacks never wrap (the behavioral-class win).
fn joint_space() -> JointSpace {
    let layer = LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 };
    let space = SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![64, 512, 1024],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    };
    JointSpace::new(
        space,
        layer,
        16,
        &[LoopOrder::ultratrail(), LoopOrder::output_stationary()],
    )
}

/// The exact four-axis front of a point set, in emission order.
fn front(points: &[DesignPoint]) -> Vec<&DesignPoint> {
    points.iter().filter(|p| p.on_front).collect()
}

/// Bitwise front equality: config, mapping, and all four axes.
fn assert_fronts_identical(naive: &[DesignPoint], other: &[DesignPoint], what: &str) {
    let nf = front(naive);
    let of = front(other);
    assert!(!nf.is_empty(), "{what}: front must be non-trivial");
    assert_eq!(nf.len(), of.len(), "{what}: front sizes diverged");
    for (a, b) in nf.iter().zip(of.iter()) {
        assert_eq!(a.config, b.config, "{what}: front configs diverged");
        assert_eq!(a.mapping, b.mapping, "{what}: front mappings diverged");
        assert_eq!(a.cycles, b.cycles, "{what}: cycles diverged");
        assert_eq!(a.offchip_reads, b.offchip_reads, "{what}: off-chip reads diverged");
        assert_eq!(a.area.to_bits(), b.area.to_bits(), "{what}: area bits diverged");
        assert_eq!(a.power.to_bits(), b.power.to_bits(), "{what}: power bits diverged");
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let joint = joint_space();
    let config_candidates = joint.space.candidates().count();

    // The naive nested sweep: every (mapping, config) pair simulated.
    let naive = explore_joint_naive(&joint).expect("naive joint sweep");
    let joint_candidates = naive.stats.enumerated;
    assert!(
        joint_candidates >= 20 * config_candidates,
        "joint space must be >= 20x the config-only space, got {joint_candidates} vs \
         {config_candidates} configs"
    );

    // The production path: prescreen + cross-mapping memoization.
    let pruned = explore_joint(&joint).expect("pruned joint sweep");
    let st = pruned.stats;
    assert_eq!(st.enumerated, joint_candidates, "enumeration shrank under pruning");
    assert_eq!(
        st.enumerated,
        st.bound_pruned + st.simulated + st.memo_hits + st.skipped,
        "joint ledger must cover every candidate"
    );
    assert_fronts_identical(&naive.points, &pruned.points, "serial joint");

    // Work-saving gates: >= 5x fewer simulated cycles, and bound-pruning
    // plus memoization together decide >= 80% of the space analytically.
    let reduction = naive.stats.sim_cycles as f64 / st.sim_cycles.max(1) as f64;
    let analytic = st.bound_pruned + st.memo_hits;
    let analytic_share = analytic as f64 / st.enumerated as f64;
    let memo_rate = st.memo_hits as f64 / st.enumerated as f64;
    println!(
        "simulated cycles: naive {}, pruned+memoized {} ({reduction:.1}x fewer)",
        naive.stats.sim_cycles, st.sim_cycles
    );
    println!(
        "analytic coverage: {} bound-pruned + {} memo hits = {analytic} of {} candidates \
         ({:.1}%; compile-cache hit rate {:.1}%)",
        st.bound_pruned,
        st.memo_hits,
        st.enumerated,
        100.0 * analytic_share,
        100.0 * memo_rate
    );
    assert!(
        reduction >= 5.0,
        "joint sweep must cut simulated cycles >= 5x vs naive, got {reduction:.2}x"
    );
    assert!(
        analytic_share >= 0.8,
        "bound_pruned + memo_hits must cover >= 80% of joint candidates, got \
         {:.1}%",
        100.0 * analytic_share
    );

    // The same front through every execution tier: pooled threads,
    // bound-and-pruned successive halving, and the worker-process fleet.
    let pool = HierarchyPool::new(FLEET);
    let pooled = pool.explore_joint(&joint).expect("pooled joint sweep");
    assert_fronts_identical(&naive.points, &pooled.points, "pooled joint");
    assert_eq!(pooled.stats, st, "pooled stats semantics diverged");

    let schedule = HalvingSchedule::for_workloads(&joint.workloads);
    let halved = explore_joint_halving_pruned(&joint, &schedule).expect("joint halving");
    assert_fronts_identical(&naive.points, &halved.points, "halving joint");

    let mut opts = ShardOptions::new(FLEET);
    // Cargo points this at the bin target built for this bench run, so
    // the fleet runs the exact code under test.
    opts.worker_cmd = Some(PathBuf::from(env!("CARGO_BIN_EXE_memhier")));
    opts.prune = true;
    let sharded = explore_joint_sharded(&joint, &schedule, &opts).expect("sharded joint");
    assert_fronts_identical(&naive.points, &sharded.points, "sharded joint");

    // Wall-clock for the two serial contenders.
    let naive_r = b.bench("dse/joint_naive", || {
        explore_joint_naive(&joint).unwrap().points.len()
    });
    let naive_cps = joint_candidates as f64 / naive_r.mean.as_secs_f64();
    println!("{}  -> {naive_cps:.1} candidates/s", naive_r.summary());

    let pruned_r = b.bench("dse/joint_pruned", || {
        explore_joint(&joint).unwrap().points.len()
    });
    let pruned_cps = joint_candidates as f64 / pruned_r.mean.as_secs_f64();
    let speedup = naive_r.mean.as_secs_f64() / pruned_r.mean.as_secs_f64();
    println!("{}  -> {pruned_cps:.1} candidates/s, {speedup:.2}x vs naive", pruned_r.summary());

    let json = format!(
        "{{\n  \"bench\": \"dse_joint\",\n  \"quick\": {quick},\n  \
         \"joint_candidates\": {joint_candidates},\n  \
         \"config_candidates\": {config_candidates},\n  \
         \"mappings\": {},\n  \"bound_pruned\": {},\n  \
         \"simulated\": {},\n  \"memo_hits\": {},\n  \"skipped\": {},\n  \
         \"naive_sim_cycles\": {},\n  \"pruned_sim_cycles\": {},\n  \
         \"cycle_reduction\": {reduction:.4},\n  \
         \"analytic_share\": {analytic_share:.4},\n  \
         \"memo_hit_rate\": {memo_rate:.4},\n  \
         \"naive_mean_ns\": {},\n  \"pruned_mean_ns\": {},\n  \
         \"wallclock_speedup\": {speedup:.4}\n}}\n",
        joint.mappings.len(),
        st.bound_pruned,
        st.simulated,
        st.memo_hits,
        st.skipped,
        naive.stats.sim_cycles,
        st.sim_cycles,
        naive_r.mean.as_nanos(),
        pruned_r.mean.as_nanos(),
    );
    std::fs::write("BENCH_joint.json", &json).expect("write BENCH_joint.json");
    println!("\nwrote BENCH_joint.json");
    println!("dse_joint done");
}

//! Figure 9 regenerator: occupied chip area — dual-ported SRAMs sized
//! for the full weight set vs the streaming memory frameworks, per
//! unrolling (8/16/32/64 unique addresses per step). Paper claims: the
//! framework is 6.5 % of the dual-ported area at u = 8; the SRAMs grow
//! 17.1 % across the sweep yet stay 3.1× larger than the parallel
//! frameworks.

use memhier::report::{fig9_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    let table = fig9_table();
    println!("=== Figure 9: dual-ported SRAMs vs memory frameworks ===\n");
    println!("{}", table.render());
    let rows: Vec<Vec<f64>> = table
        .to_csv()
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|v| v.parse().unwrap()).collect())
        .collect();
    let frac_u8 = rows[0][3];
    assert!((0.03..0.10).contains(&frac_u8), "u=8 fraction {frac_u8:.3} (paper 0.065)");
    let ratio_u64 = rows[3][1] / rows[3][2];
    assert!((2.0..5.0).contains(&ratio_u64), "u=64 ratio {ratio_u64:.2} (paper 3.1)");
    let growth = rows[3][1] / rows[0][1] - 1.0;
    assert!((0.05..0.40).contains(&growth), "dp growth {growth:.3} (paper 0.171)");
    println!(
        "u=8 framework fraction: {:.1}% (paper 6.5%); dp growth {:+.1}% (paper +17.1%); u=64 ratio {:.1}x (paper 3.1x)",
        frac_u8 * 100.0,
        growth * 100.0,
        ratio_u64
    );
    let path = save_csv(&table, "fig9").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

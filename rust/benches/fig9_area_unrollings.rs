//! Figure 9 regenerator: occupied chip area — dual-ported SRAMs sized
//! for the full weight set vs the streaming memory frameworks, per
//! unrolling (8/16/32/64 unique addresses per step). Paper claims: the
//! framework is 6.5 % of the dual-ported area at u = 8; the SRAMs grow
//! 17.1 % across the sweep yet stay 3.1× larger than the parallel
//! frameworks.
//!
//! The sweep's unrollings are no longer hand-rolled: they come off the
//! joint search's mapping dimension (`memhier::dse::dims`) — the menu a
//! `JointSpace` enumerates over the 64-MAC array on layer 11 (all
//! unrollings in the pinned odometer order, restricted to MCU-supported
//! mappings with their weight streams derived and verified) must contain
//! the four §5.3.1 K-major sweep points, in sweep order.

use memhier::dse::{JointSpace, Mapping, SearchSpace};
use memhier::loopnest::unroll::paper_sweep;
use memhier::loopnest::LoopOrder;
use memhier::model::tc_resnet8;
use memhier::report::{fig9_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    // The joint mapping menu on layer 11 (the layer that sizes the
    // dual-ported alternative) must emit the paper's K-major sweep
    // unrollings — the same candidates `dse --joint` would explore.
    let layer11 = tc_resnet8()[11];
    let joint =
        JointSpace::new(SearchSpace::default(), layer11, 64, &[LoopOrder::ultratrail()]);
    let sweep: Vec<Mapping> = joint
        .mappings
        .iter()
        .copied()
        .filter(|m| m.unrolling.uk == 8 && m.unrolling.uf == 1)
        .collect();
    let got: Vec<u64> = sweep.iter().map(|m| m.unrolling.weight_addrs_per_step()).collect();
    let expected: Vec<u64> = paper_sweep().iter().map(|(u, _)| *u).collect();
    assert_eq!(got, expected, "joint mapping menu must cover the §5.3.1 sweep in order");
    for (m, (_, u)) in sweep.iter().zip(paper_sweep()) {
        assert_eq!(m.unrolling, u, "menu emits the paper's K-major unrollings");
    }
    println!(
        "sweep unrollings drawn from the joint mapping menu: {} supported mappings on layer 11",
        joint.mappings.len()
    );

    let table = fig9_table();
    println!("=== Figure 9: dual-ported SRAMs vs memory frameworks ===\n");
    println!("{}", table.render());
    let rows: Vec<Vec<f64>> = table
        .to_csv()
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|v| v.parse().unwrap()).collect())
        .collect();
    // One table row per joint-menu sweep mapping, keyed identically.
    assert_eq!(rows.len(), sweep.len());
    for (row, m) in rows.iter().zip(&sweep) {
        assert_eq!(row[0] as u64, m.unrolling.weight_addrs_per_step());
    }
    let frac_u8 = rows[0][3];
    assert!((0.03..0.10).contains(&frac_u8), "u=8 fraction {frac_u8:.3} (paper 0.065)");
    let ratio_u64 = rows[3][1] / rows[3][2];
    assert!((2.0..5.0).contains(&ratio_u64), "u=64 ratio {ratio_u64:.2} (paper 3.1)");
    let growth = rows[3][1] / rows[0][1] - 1.0;
    assert!((0.05..0.40).contains(&growth), "dp growth {growth:.3} (paper 0.171)");
    println!(
        "u=8 framework fraction: {:.1}% (paper 6.5%); dp growth {:+.1}% (paper +17.1%); u=64 ratio {:.1}x (paper 3.1x)",
        frac_u8 * 100.0,
        growth * 100.0,
        ratio_u64
    );
    let path = save_csv(&table, "fig9").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

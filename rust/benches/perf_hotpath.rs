//! Hot-path micro-benchmarks (the §Perf deliverable): the per-cycle
//! `Hierarchy::step` loop, pattern-stream generation, trace
//! classification, and the end-to-end figure regenerations. Uses the
//! in-tree `benchkit` harness (criterion is unavailable offline).
//!
//! Target (DESIGN.md §Perf): ≥ 5 M simulated hierarchy cycles/s
//! single-thread in release mode with verification off.

use memhier::benchkit::Bencher;
use memhier::config::HierarchyConfig;
use memhier::mem::Hierarchy;
use memhier::pattern::{classify_trace, AccessPattern, PatternProgram};

fn two_level() -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1024, 1, 1)
        .level(32, 128, 1, 2)
        .build()
        .unwrap()
}

fn main() {
    let b = if std::env::args().any(|a| a == "--quick") { Bencher::quick() } else { Bencher::default() };
    let mut results = Vec::new();

    // 1. The simulator hot loop: 50k outputs of a resident cyclic pattern.
    let cfg = two_level();
    let r = b.bench("hierarchy_step/cyclic_resident_50k", || {
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(50_000)).unwrap();
        h.set_verify(false);
        h.run().unwrap().stats.internal_cycles
    });
    let cycles = 50_000.0 * 1.04; // ~fill overhead
    println!("{}  -> {:.2} M simulated cycles/s", r.summary(), r.throughput(cycles as u64) / 1e6);
    results.push((r, cycles as u64));

    // 2. Streaming worst case (every word through the CDC).
    let r = b.bench("hierarchy_step/sequential_stream_20k", || {
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.load_program(&PatternProgram::sequential(0, 20_000)).unwrap();
        h.set_verify(false);
        h.run().unwrap().stats.internal_cycles
    });
    println!("{}  -> {:.2} M simulated cycles/s", r.summary(), r.throughput(60_000) / 1e6);
    results.push((r, 60_000));

    // 3. Verification overhead (payload + address checking on).
    let r = b.bench("hierarchy_step/cyclic_verified_50k", || {
        let mut h = Hierarchy::new(&cfg).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(50_000)).unwrap();
        h.run().unwrap().stats.internal_cycles
    });
    println!("{}  (verification on)", r.summary());

    // 4. Pattern-stream generation.
    let r = b.bench("pattern/shifted_cyclic_stream_100k", || {
        AccessPattern::ShiftedCyclic {
            start: 0,
            cycle_length: 97,
            inter_cycle_shift: 13,
            skip_shift: 1,
            cycles: 1031,
        }
        .stream()
        .take(100_000)
        .sum::<u64>()
    });
    println!("{}  -> {:.1} M addrs/s", r.summary(), r.throughput(100_000) / 1e6);

    // 5. Trace classification.
    let trace = AccessPattern::ShiftedCyclic {
        start: 0,
        cycle_length: 48,
        inter_cycle_shift: 6,
        skip_shift: 0,
        cycles: 64,
    }
    .addresses();
    let r = b.bench("classify/shifted_cyclic_3k", || classify_trace(&trace));
    println!("{}", r.summary());

    // 6. Case-study supply simulation (the kws_e2e co-simulation cost).
    let r = b.bench("casestudy/layer11_supply", || {
        let ut = memhier::accel::UltraTrail::default();
        let cfg = ut.hierarchy_wmem_config(false);
        ut.layer_supply(&memhier::model::tc_resnet8()[11], &cfg).unwrap().internal_cycles
    });
    println!("{}", r.summary());

    println!("\nperf_hotpath done");
}

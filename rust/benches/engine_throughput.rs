//! Engine throughput: naive tick-per-cycle vs event-horizon fast-forward
//! — the first engine-level baseline in the bench trajectory.
//!
//! Measures end-to-end run wall-clock and simulated-cycles/second for the
//! same (config, workload) pairs under `force_naive` (the oracle loop)
//! and the default fast-forward engine, across stall-light (resident
//! streaming at ~1 output/cycle) and stall-heavy (off-chip latency sweep,
//! both level kinds, OSR, clock ratios) shapes. Every pair is first
//! sanity-checked for bit-identical stats and outputs — the speedup is
//! only interesting because the results are the same. Numbers land in
//! `BENCH_engine.json`; CI runs `--quick` and uploads the artifact.

use memhier::benchkit::Bencher;
use memhier::config::HierarchyConfig;
use memhier::mem::Hierarchy;
use memhier::pattern::PatternProgram;

struct Case {
    name: &'static str,
    cfg: HierarchyConfig,
    prog: PatternProgram,
    /// Whether the acceptance gates apply: true only for the clearly
    /// stall-dominant shapes (streaming through off-chip latency >= 16 at
    /// 1:1 clocks), where most *internal* cycles are provably dead and a
    /// >= 2x wall-clock speedup is structural. The OSR-resident and
    /// 4x-external-clock cases are measured but not gated — their win is
    /// partial (fill phase only) or lives in skipped external edges,
    /// which the skipped-internal-cycles metric does not count.
    gated: bool,
}

fn cases(quick: bool) -> Vec<Case> {
    let scale = |n: u64| if quick { n / 4 } else { n };
    let mut v = vec![
        // Stall-light: window resident in the last level, ~1 output/cycle
        // — the fast-forward check must cost (almost) nothing here.
        Case {
            name: "stall_light/resident_stream",
            cfg: HierarchyConfig::builder()
                .offchip(32, 24, 1.0)
                .level(32, 1024, 1, 1)
                .level(32, 128, 1, 2)
                .build()
                .unwrap(),
            prog: PatternProgram::cyclic(0, 64).with_outputs(scale(40_000)),
            gated: false,
        },
        // Stall-light with CDC cadence: sequential stream at the 3-cycle
        // handshake, latency 1 — short dead windows, frequent horizon
        // checks.
        Case {
            name: "stall_light/sequential_l1",
            cfg: HierarchyConfig::builder()
                .offchip(32, 24, 1.0)
                .level(32, 64, 1, 1)
                .level(32, 16, 1, 2)
                .build()
                .unwrap(),
            prog: PatternProgram::sequential(0, scale(8_192)),
            gated: false,
        },
    ];
    // Off-chip latency sweep on the streaming shape.
    for latency in [4u64, 16, 64] {
        v.push(Case {
            name: match latency {
                4 => "latency_sweep/l4",
                16 => "latency_sweep/l16",
                _ => "latency_sweep/l64",
            },
            cfg: HierarchyConfig::builder()
                .offchip(32, 24, 1.0)
                .offchip_latency(latency)
                .level(32, 64, 1, 1)
                .level(32, 16, 1, 2)
                .build()
                .unwrap(),
            prog: PatternProgram::sequential(0, scale(4_096)),
            gated: latency >= 16,
        });
    }
    // Stall-heavy double-buffered level kind.
    v.push(Case {
        name: "kinds/pingpong_l16",
        cfg: HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .level(32, 64, 1, 1)
            .level_double_buffered(32, 16)
            .build()
            .unwrap(),
        prog: PatternProgram::cyclic(0, 256).with_outputs(scale(2_048)),
        gated: true,
    });
    // Wide words + OSR at deep latency: the window turns resident after
    // the fill, so only the fetch prefix fast-forwards (measured, not
    // gated).
    v.push(Case {
        name: "kinds/osr_wide_l16",
        cfg: HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(16)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap(),
        prog: PatternProgram::cyclic(0, 256).with_outputs(scale(2_048)),
        gated: false,
    });
    // 4x faster external clock with a deep buffer: the dead time sits in
    // external edges, which fast-forward skips but the skipped-internal
    // metric does not count (measured, not gated).
    v.push(Case {
        name: "ratio/ext4x_l16",
        cfg: HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .offchip_latency(16)
            .ib_depth(2)
            .level(32, 128, 1, 1)
            .build()
            .unwrap(),
        prog: PatternProgram::sequential(0, scale(4_096)),
        gated: false,
    });
    v
}

/// One timed mode: fresh load + full run per iteration on a warm
/// hierarchy (verification off — a pure performance measurement, like the
/// DSE scoring path).
fn bench_mode(
    b: &Bencher,
    name: &str,
    cfg: &HierarchyConfig,
    prog: &PatternProgram,
    naive: bool,
) -> (memhier::benchkit::BenchResult, u64, u64) {
    let mut h = Hierarchy::new(cfg).expect("config valid");
    h.set_verify(false);
    h.set_force_naive(naive);
    let mut cycles = 0u64;
    let mut skipped = 0u64;
    let r = b.bench(name, || {
        h.load_program(prog).expect("program loads");
        let r = h.run().expect("run succeeds");
        cycles = r.stats.internal_cycles;
        skipped = r.stats.skipped_cycles;
        cycles
    });
    (r, cycles, skipped)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };

    let mut rows = Vec::new();
    // Acceptance checks are collected and asserted only after
    // BENCH_engine.json is written, so a failing run still publishes the
    // numbers needed to diagnose it.
    let mut failures = Vec::new();
    for case in cases(quick) {
        // Sanity: fast-forward is bit-identical to the naive oracle
        // (stats and collected outputs) before any timing is trusted.
        let run = |naive: bool| {
            let mut h = Hierarchy::new(&case.cfg).unwrap();
            h.set_collect(true);
            h.set_force_naive(naive);
            h.load_program(&case.prog).unwrap();
            h.run().unwrap()
        };
        let (ff, naive) = (run(false), run(true));
        assert_eq!(ff.stats, naive.stats, "{}: ff != naive stats", case.name);
        assert_eq!(ff.outputs, naive.outputs, "{}: ff != naive outputs", case.name);

        let (rn, cycles, _) =
            bench_mode(&b, &format!("{}/naive", case.name), &case.cfg, &case.prog, true);
        let (rf, _, skipped) =
            bench_mode(&b, &format!("{}/ff", case.name), &case.cfg, &case.prog, false);
        let speedup = rn.mean.as_secs_f64() / rf.mean.as_secs_f64();
        let naive_cps = cycles as f64 / rn.mean.as_secs_f64();
        let ff_cps = cycles as f64 / rf.mean.as_secs_f64();
        println!("{}", rn.summary());
        println!(
            "{}  -> {speedup:.2}x vs naive ({:.2}M vs {:.2}M sim-cycles/s, {skipped}/{cycles} \
             skipped)",
            rf.summary(),
            ff_cps / 1e6,
            naive_cps / 1e6,
        );
        if case.gated {
            // Deterministic gate (valid on any runner): a stall-dominant
            // run must skip the majority of its simulated cycles — the
            // same invariant tests/engine_ff.rs holds.
            if skipped * 2 <= cycles {
                failures.push(format!(
                    "{}: only {skipped}/{cycles} cycles skipped on a stall-heavy config",
                    case.name
                ));
            }
            // Wall-clock gate: quick mode (CI) measures sub-millisecond
            // means on noisy shared runners, so the 2x acceptance bar is
            // enforced only on full-length runs; quick runs just record
            // the number in the artifact.
            if !quick && speedup < 2.0 {
                failures.push(format!(
                    "{}: stall-heavy speedup {speedup:.2}x below the 2x acceptance bar",
                    case.name
                ));
            }
        }
        rows.push(format!(
            "  {{\"case\": \"{}\", \"gated\": {}, \"naive_mean_ns\": {}, \
             \"ff_mean_ns\": {}, \"speedup\": {speedup:.4}, \"sim_cycles\": {cycles}, \
             \"skipped_cycles\": {skipped}, \"naive_cycles_per_sec\": {naive_cps:.0}, \
             \"ff_cycles_per_sec\": {ff_cps:.0}}}",
            case.name,
            case.gated,
            rn.mean.as_nanos(),
            rf.mean.as_nanos(),
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"engine_throughput\",\n  \"quick\": {quick},\n  \"cases\": [\n{}\n  \
         ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_engine.json", &json).expect("write BENCH_engine.json");
    println!("\nwrote BENCH_engine.json");
    assert!(failures.is_empty(), "acceptance checks failed:\n{}", failures.join("\n"));
    println!("engine_throughput done");
}

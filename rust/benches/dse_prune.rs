//! Bound-and-prune DSE front end — the analytical-pruning headline
//! number.
//!
//! The prescreen (`dse::bound`) scores every enumerated candidate with
//! exact area and analytical cycle/power bounds, drops the provably
//! non-Pareto ones before a single simulated cycle is spent, and hands
//! only the survivors to the cycle-accurate sweep. On a stall-heavy
//! space the win is large because the losers are exactly the slow
//! candidates — the sweep would otherwise spend most of its cycles
//! simulating configurations the bounds already condemn. This bench
//! asserts the front stays bitwise-identical to the exhaustive sweep,
//! gates a >= 3x reduction in simulated cycles, measures candidates/s
//! for both paths, streams a million-candidate space through the lazy
//! odometer iterator in constant memory, and writes `BENCH_prune.json`
//! so CI can publish the trajectory.

use std::time::Instant;

use memhier::benchkit::Bencher;
use memhier::dse::{explore, explore_pruned, KindChoice, SearchSpace};
use memhier::pattern::PatternProgram;

/// Stall-heavy seeded space: one 48-word working set against depth
/// stacks from 32 to 512 words, standard levels only. Every stack deep
/// enough to hold the window behaves identically (the fetch stream
/// never wraps), so the prescreen collapses those classes and interval-
/// prunes the streaming stacks — the exact sweep keeps only the handful
/// of genuinely distinct contenders.
fn space() -> SearchSpace {
    SearchSpace {
        depths: vec![1, 2, 3],
        ram_depths: vec![32, 48, 64, 96, 128, 192, 256, 384, 512],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn workload() -> PatternProgram {
    PatternProgram::cyclic(0, 48).with_outputs(4_800)
}

/// The million-candidate space for the streaming demo: never
/// materialized, only walked by the odometer iterator.
fn huge_space() -> SearchSpace {
    SearchSpace {
        depths: vec![1, 2, 3, 4, 5],
        ram_depths: (1..=26).map(|i| 32 * i).collect(),
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let space = space();
    let w = workload();

    // Sanity first: the pruned sweep's exact Pareto front reproduces the
    // exhaustive sweep's bit-for-bit, and the prune ledger covers every
    // enumerated candidate (pruned points are flagged, never vanished).
    let exhaustive = explore(&space, &w).expect("exhaustive sweep");
    let pruned = explore_pruned(&space, &w).expect("pruned sweep");
    let ef: Vec<_> = exhaustive.iter().filter(|p| p.on_front).collect();
    let pf: Vec<_> = pruned.points.iter().filter(|p| p.on_front).collect();
    assert!(!ef.is_empty(), "front must be non-trivial");
    assert_eq!(ef.len(), pf.len(), "front sizes diverged");
    for (a, c) in ef.iter().zip(pf.iter()) {
        assert_eq!(a.config, c.config, "fronts diverged");
        assert_eq!(a.cycles, c.cycles);
        assert_eq!(a.area.to_bits(), c.area.to_bits());
        assert_eq!(a.power.to_bits(), c.power.to_bits());
    }
    let st = pruned.stats;
    assert_eq!(
        st.enumerated,
        st.simulated + st.bound_pruned + st.skipped,
        "prune ledger must cover every candidate"
    );
    assert_eq!(st.simulated, pruned.points.len());
    assert!(st.enumerated >= exhaustive.len(), "enumeration shrank under pruning");

    // The headline gate: simulated cycles paid by each path. Exhaustive
    // simulates every candidate's full run; the pruned path only the
    // survivors'.
    let exhaustive_cycles: u64 = exhaustive.iter().map(|p| p.cycles).sum();
    let pruned_cycles: u64 = pruned.points.iter().map(|p| p.cycles).sum();
    let reduction = exhaustive_cycles as f64 / pruned_cycles.max(1) as f64;
    println!(
        "simulated cycles: exhaustive {exhaustive_cycles}, pruned {pruned_cycles} \
         ({reduction:.1}x fewer; {} of {} candidates bound-pruned)",
        st.bound_pruned, st.enumerated
    );
    assert!(
        reduction >= 3.0,
        "bound-and-prune must cut simulated cycles >= 3x on the stall-heavy \
         space, got {reduction:.2}x"
    );

    let candidates = st.enumerated;
    let ex_r = b.bench("dse/prune_exhaustive", || explore(&space, &w).unwrap().len());
    let ex_cps = candidates as f64 / ex_r.mean.as_secs_f64();
    println!("{}  -> {ex_cps:.1} candidates/s", ex_r.summary());

    let pr_r = b.bench("dse/prune_bounded", || {
        explore_pruned(&space, &w).unwrap().points.len()
    });
    let pr_cps = candidates as f64 / pr_r.mean.as_secs_f64();
    let speedup = ex_r.mean.as_secs_f64() / pr_r.mean.as_secs_f64();
    println!("{}  -> {pr_cps:.1} candidates/s, {speedup:.2}x vs exhaustive", pr_r.summary());
    // Wall-clock gate only outside --quick (quick runs are noise-bound).
    if !quick {
        assert!(
            speedup > 1.0,
            "pruned sweep must win wall-clock, got {speedup:.2}x"
        );
    }

    // Streaming demo: walk a >10^6-candidate space through the lazy
    // odometer without materializing it — constant memory, pure
    // enumeration rate.
    let huge = huge_space();
    let t0 = Instant::now();
    let streamed = huge.candidates().count();
    let stream_secs = t0.elapsed().as_secs_f64();
    let stream_rate = streamed as f64 / stream_secs.max(1e-9);
    println!(
        "streamed {streamed} candidates in {stream_secs:.2}s ({stream_rate:.0} candidates/s, \
         never materialized)"
    );
    assert!(
        streamed >= 1_000_000,
        "streaming demo space must exceed a million candidates, got {streamed}"
    );

    let json = format!(
        "{{\n  \"bench\": \"dse_prune\",\n  \"quick\": {quick},\n  \
         \"candidates\": {candidates},\n  \"bound_pruned\": {},\n  \
         \"simulated\": {},\n  \"skipped\": {},\n  \
         \"exhaustive_sim_cycles\": {exhaustive_cycles},\n  \
         \"pruned_sim_cycles\": {pruned_cycles},\n  \
         \"cycle_reduction\": {reduction:.4},\n  \
         \"exhaustive_mean_ns\": {},\n  \"pruned_mean_ns\": {},\n  \
         \"exhaustive_candidates_per_s\": {ex_cps:.2},\n  \
         \"pruned_candidates_per_s\": {pr_cps:.2},\n  \
         \"wallclock_speedup\": {speedup:.4},\n  \
         \"streamed_candidates\": {streamed},\n  \
         \"stream_candidates_per_s\": {stream_rate:.0}\n}}\n",
        st.bound_pruned,
        st.simulated,
        st.skipped,
        ex_r.mean.as_nanos(),
        pr_r.mean.as_nanos(),
    );
    std::fs::write("BENCH_prune.json", &json).expect("write BENCH_prune.json");
    println!("\nwrote BENCH_prune.json");
    println!("dse_prune done");
}

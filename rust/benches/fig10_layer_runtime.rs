//! Figure 10 regenerator: relative runtime of each TC-ResNet layer with
//! the framework under 8/16/32/64-unique-address unrollings, no
//! preloading. Paper efficiencies: 58.8 / 60.6 / 85.7 / 97.6 %.

use memhier::accel::wmem::{fig10_runtimes, sweep_points};
use memhier::report::{fig10_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    let table = fig10_table().expect("fig10");
    println!("=== Figure 10: relative layer runtimes per unrolling ===\n");
    println!("{}", table.render());
    let effs: Vec<f64> = sweep_points().iter().map(|p| fig10_runtimes(p).1).collect();
    let paper = [0.588, 0.606, 0.857, 0.976];
    for ((u, e), p) in [8u64, 16, 32, 64].iter().zip(effs.iter()).zip(paper.iter()) {
        println!("u={u:<3} measured {:.1}%  paper {:.1}%  (Δ {:+.1} pp)", e * 100.0, p * 100.0, (e - p) * 100.0);
        assert!((e - p).abs() < 0.08, "u={u}: efficiency {e:.3} vs paper {p:.3}");
    }
    // FC layers are the least efficient rows at every sweep point (§5.3.2).
    for p in sweep_points() {
        let (per, _) = fig10_runtimes(&p);
        let rel = |i: usize| per[i].runtime as f64 / per[i].steps as f64;
        let worst_conv = (0..13).filter(|i| *i != 8 && *i != 12).map(rel).fold(0.0f64, f64::max);
        assert!(rel(12).max(rel(8)) >= worst_conv * 0.99, "FC layers least efficient");
    }
    let path = save_csv(&table, "fig10").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

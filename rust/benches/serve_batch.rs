//! Warm-session vs cold-build benchmark (the session-layer deliverable):
//!
//! 1. **DSE candidate throughput** — scoring a candidate stream on one
//!    warm session (`Session::rearm` + `run_program`) vs building a fresh
//!    `Hierarchy` per candidate (the pre-session path). Same simulations,
//!    zero steady-state allocation on the warm path.
//! 2. **Server batch co-simulation latency** — streaming all TC-ResNet
//!    layers through one warm session (what `coordinator::server` does
//!    per batch) vs a fresh hierarchy per layer (the old one-shot path).
//! 3. **Successive-halving work savings** — exhaustive vs halving sweep
//!    on the same space (deterministic work accounting + wall clock).

use memhier::benchkit::Bencher;
use memhier::config::HierarchyConfig;
use memhier::dse::{explore, explore_halving, HalvingSchedule, KindChoice, SearchSpace};
use memhier::mem::Hierarchy;
use memhier::pattern::PatternProgram;
use memhier::sim::batch::Session;

/// A candidate stream shaped like a DSE rung: mixed depths/widths/ports.
fn candidates() -> Vec<HierarchyConfig> {
    let mut v = Vec::new();
    for &(w, d0, d1, ports) in &[
        (32u32, 256u64, 0u64, 1u32),
        (32, 1024, 0, 2),
        (32, 512, 128, 1),
        (32, 1024, 128, 2),
        (128, 128, 0, 1),
        (128, 128, 32, 2),
    ] {
        let mut b = HierarchyConfig::builder().offchip(32, 24, 1.0);
        b = b.level(w, d0, 1, if d1 == 0 { ports } else { 1 });
        if d1 > 0 {
            b = b.level(w, d1, 1, ports);
        }
        if w > 32 {
            b = b.osr(w.max(64), vec![32]);
        }
        v.push(b.build().expect("bench config valid"));
    }
    // A ping-pong candidate: the warm path re-arms across a level-kind
    // change (variant swap, storage recycled).
    v.push(
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .expect("bench config valid"),
    );
    v
}

fn score_cold(cfgs: &[HierarchyConfig], workload: &PatternProgram) -> u64 {
    let mut cycles = 0;
    for cfg in cfgs {
        let mut h = Hierarchy::new(cfg).expect("config valid");
        h.set_verify(false);
        h.load_program(workload).expect("loads");
        cycles += h.run().expect("runs").stats.internal_cycles;
    }
    cycles
}

fn score_warm(session: &mut Session, cfgs: &[HierarchyConfig], workload: &PatternProgram) -> u64 {
    let mut cycles = 0;
    for cfg in cfgs {
        session.rearm(cfg).expect("config valid");
        cycles += session.run_program(workload).expect("runs").stats.internal_cycles;
    }
    cycles
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };

    // --- 1. DSE candidate throughput: cold build vs warm session. ---
    let cfgs = candidates();
    let workload = PatternProgram::cyclic(0, 64).with_outputs(1_024);
    let n = cfgs.len() as u64;

    let cold = b.bench("dse/candidates_cold_build", || score_cold(&cfgs, &workload));
    println!("{}  ({:.0} cand/s)", cold.summary(), cold.throughput(n));

    let mut session = Session::new(&cfgs[0]).expect("config valid");
    session.set_verify(false);
    let warm = b.bench("dse/candidates_warm_session", || score_warm(&mut session, &cfgs, &workload));
    let speedup = cold.mean.as_secs_f64() / warm.mean.as_secs_f64();
    println!(
        "{}  ({:.0} cand/s)  -> {speedup:.2}x vs cold build",
        warm.summary(),
        warm.throughput(n)
    );

    // Sanity: warm results equal cold results (determinism, not speed).
    let mut check = Session::new(&cfgs[0]).expect("config valid");
    check.set_verify(false);
    assert_eq!(
        score_cold(&cfgs, &workload),
        score_warm(&mut check, &cfgs, &workload),
        "warm scoring must be bit-identical to cold scoring"
    );

    // --- 2. Server batch co-simulation: all layers, one inference. ---
    let ut = memhier::accel::UltraTrail::default();
    let cfg = ut.hierarchy_wmem_config(true);
    let programs: Vec<PatternProgram> = ut.layers.iter().map(|l| ut.layer_program(l)).collect();

    let cold_batch = b.bench("serve/batch_cosim_cold", || {
        let mut total = 0u64;
        for p in &programs {
            let mut h = Hierarchy::new(&cfg).expect("config valid");
            h.load_program(p).expect("loads");
            total += h.run().expect("runs").stats.internal_cycles;
        }
        total
    });
    println!("{}", cold_batch.summary());

    let mut batch_session = Session::new(&cfg).expect("config valid");
    let warm_batch = b.bench("serve/batch_cosim_warm_session", || {
        let mut total = 0u64;
        for p in &programs {
            total += batch_session.run_program(p).expect("runs").stats.internal_cycles;
        }
        total
    });
    let batch_speedup = cold_batch.mean.as_secs_f64() / warm_batch.mean.as_secs_f64();
    println!("{}  -> {batch_speedup:.2}x vs cold per-layer builds", warm_batch.summary());

    // --- 3. Successive halving vs exhaustive sweep. ---
    let space = SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128, 1024],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    };
    let sweep_workload = PatternProgram::cyclic(0, 256).with_outputs(2_560);
    let schedule = HalvingSchedule::for_workload(&sweep_workload);

    let exhaustive = b.bench("dse/sweep_exhaustive", || {
        explore(&space, &sweep_workload).unwrap().len()
    });
    println!("{}", exhaustive.summary());
    let halving = b.bench("dse/sweep_halving", || {
        explore_halving(&space, &sweep_workload, &schedule).unwrap().points.len()
    });
    let sweep_speedup = exhaustive.mean.as_secs_f64() / halving.mean.as_secs_f64();
    println!("{}  -> {sweep_speedup:.2}x vs exhaustive", halving.summary());

    let outcome = explore_halving(&space, &sweep_workload, &schedule).unwrap();
    println!(
        "halving work: {} candidates -> {} exact-from-screen, {} pruned, {} full runs, {} skipped",
        outcome.stats.candidates,
        outcome.stats.screen_exact,
        outcome.stats.pruned,
        outcome.stats.full_runs,
        outcome.stats.skipped
    );

    println!("\nwarm-session speedups: dse {speedup:.2}x, batch co-sim {batch_speedup:.2}x, halving sweep {sweep_speedup:.2}x");
    println!("serve_batch done");
}

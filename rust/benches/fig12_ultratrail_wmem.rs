//! Figure 12 + headline regenerator: UltraTrail baseline vs the memory
//! hierarchy as weight memory. Paper: −62.2 % chip area, +6.2 % power,
//! −2.4 % performance; weight macros >70 % of the baseline chip.

use memhier::accel::UltraTrail;
use memhier::report::{fig12_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    let table = fig12_table(true).expect("case study");
    println!("=== Figure 12: UltraTrail baseline vs hierarchy WMEM ===\n");
    println!("{}", table.render());

    let cs = UltraTrail::default().case_study(true).expect("case study");
    assert!((-0.67..=-0.57).contains(&cs.area_delta), "area delta {}", cs.area_delta);
    assert!((0.02..0.12).contains(&cs.power_delta), "power delta {}", cs.power_delta);
    assert!((0.0..0.06).contains(&cs.perf_loss), "perf loss {}", cs.perf_loss);
    assert!(cs.baseline_wmem_share > 0.70, "wmem share {}", cs.baseline_wmem_share);
    assert!(cs.latency_s < 0.100, "real-time budget");

    let no_pre = UltraTrail::default().case_study(false).expect("case study");
    println!(
        "without preloading: perf loss {:+.1}% (preloaded {:+.1}%; paper headline 2.4%)",
        no_pre.perf_loss * 100.0,
        cs.perf_loss * 100.0
    );
    let path = save_csv(&table, "fig12").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

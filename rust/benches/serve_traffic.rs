//! Serving-tier traffic replay: speculative warming on vs off over a
//! seeded heavy-tailed multi-tenant mix, plus the LRU churn scaling
//! assertion.
//!
//! The replay offers load near the *cold* serving capacity (the
//! inter-arrival gap is calibrated from a measured cold co-simulation
//! probe), so a server that cold-simulates misses on the request path
//! falls behind and its tail latency grows with the queue, while the
//! warming-enabled server keeps the warm store topped up off the request
//! path and stays ahead. Correctness is gated **always**: both runs must
//! serve bit-identical `accel_cycles` per request id (the determinism
//! contract). The p99 end-to-end win (>= 1.5x) is gated only on
//! full-length runs — quick CI runs record the number in the artifact
//! without asserting wall-clock behavior on shared runners. Numbers land
//! in `BENCH_serve.json`.

use memhier::coordinator::{
    synth_request, KwsResult, KwsServer, ServerConfig, TrafficConfig, WarmingMode, TENANT_STRIDE,
};
use memhier::util::{LruOrder, StreamingHistogram};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Distinct resident tenants in the mix.
const TENANTS: usize = 16;
/// Request-path cycle-cache capacity (deliberately far below TENANTS so
/// the cold run misses often).
const CACHED_BASES: usize = 4;
/// Warm-store capacity: with the cycle cache it covers every tenant.
const WARM_CAPACITY: usize = 12;

fn server(warming: WarmingMode) -> KwsServer {
    KwsServer::sim_only(ServerConfig {
        max_batch: 8,
        max_cached_bases: CACHED_BASES,
        queue_depth: 0, // unbounded: both runs serve every request
        tenant_cap: 0,
        warming,
        warm_capacity: WARM_CAPACITY,
        warm_ahead: 4,
        ..ServerConfig::default()
    })
    .expect("sim-only server")
}

/// Measure the cold co-simulation cost per request: distinct never-seen
/// tenants, no cache, no warming.
fn probe_cold_cost() -> Duration {
    let mut probe = KwsServer::sim_only(ServerConfig {
        max_batch: 8,
        max_cached_bases: 0,
        warming: WarmingMode::Off,
        ..ServerConfig::default()
    })
    .expect("probe server");
    let reqs: Vec<_> = (0..6u64)
        .map(|i| synth_request(i).with_weight_base((TENANTS as u64 + i) * TENANT_STRIDE))
        .collect();
    let t0 = Instant::now();
    probe.serve_batch(&reqs).expect("probe batch");
    t0.elapsed() / reqs.len() as u32
}

/// Prime a server: one cold pass over every tenant (fills the cycle cache
/// to its bound and seeds the arrival predictor), then — when warming in
/// the background — wait for the warm store to fill so the timed replay
/// measures steady-state serving, not start-up.
fn prime(srv: &mut KwsServer) {
    let reqs: Vec<_> = (0..TENANTS as u64)
        .map(|i| synth_request(1000 + i).with_weight_base(i * TENANT_STRIDE))
        .collect();
    for chunk in reqs.chunks(8) {
        srv.serve_batch(chunk).expect("prime batch");
    }
    let t0 = Instant::now();
    while srv.warm_parked().is_some_and(|n| n < WARM_CAPACITY)
        && t0.elapsed() < Duration::from_secs(2)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
}

struct ModeOutcome {
    results: Vec<KwsResult>,
    wall: Duration,
    e2e: StreamingHistogram,
    service: StreamingHistogram,
    queue_wait: StreamingHistogram,
    cold_sims: u64,
    warm_hits: u64,
    cache_hits: u64,
}

fn run_mode(warming: WarmingMode, trace: &[memhier::coordinator::TracedRequest]) -> ModeOutcome {
    let mut srv = server(warming);
    prime(&mut srv);
    let t0 = Instant::now();
    let results = srv.serve_trace(trace.to_vec()).expect("trace replay");
    let wall = t0.elapsed();
    let mut e2e = StreamingHistogram::new();
    let mut service = StreamingHistogram::new();
    let mut queue_wait = StreamingHistogram::new();
    for r in &results {
        e2e.record_duration(r.queue_wait + r.host_latency);
        service.record_duration(r.host_latency);
        queue_wait.record_duration(r.queue_wait);
    }
    let s = srv.stats();
    ModeOutcome {
        results,
        wall,
        e2e,
        service,
        queue_wait,
        cold_sims: s.cold_sims,
        warm_hits: s.warm_hits,
        cache_hits: s.cache_hits,
    }
}

/// Churn an [`LruOrder`] of `n` keys and return the elapsed time: the
/// O(log n) eviction satellite's scaling assertion compares per-op cost
/// across two sizes two orders of magnitude apart.
fn lru_churn_time(n: u64, churn: u64) -> Duration {
    let mut lru = LruOrder::new();
    for k in 0..n {
        lru.touch(k);
    }
    let t0 = Instant::now();
    for i in 0..churn {
        lru.touch(i % n);
        if let Some(k) = lru.pop_oldest() {
            lru.touch(k);
        }
    }
    t0.elapsed()
}

fn json_mode(name: &str, m: &ModeOutcome) -> String {
    let rps = m.results.len() as f64 / m.wall.as_secs_f64();
    format!(
        "  {{\"mode\": \"{name}\", \"served\": {}, \"wall_ns\": {}, \"req_per_sec\": {rps:.1}, \
         \"e2e_p50_ns\": {}, \"e2e_p95_ns\": {}, \"e2e_p99_ns\": {}, \
         \"service_p50_ns\": {}, \"service_p95_ns\": {}, \"service_p99_ns\": {}, \
         \"queue_wait_p99_ns\": {}, \
         \"cache_hits\": {}, \"warm_hits\": {}, \"cold_sims\": {}}}",
        m.results.len(),
        m.wall.as_nanos(),
        m.e2e.p50(),
        m.e2e.p95(),
        m.e2e.p99(),
        m.service.p50(),
        m.service.p95(),
        m.service.p99(),
        m.queue_wait.p99(),
        m.cache_hits,
        m.warm_hits,
        m.cold_sims,
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut failures: Vec<String> = Vec::new();

    // Calibrate offered load to the measured cold capacity: ~40 % of a
    // cold co-simulation per arrival keeps the no-warming server past
    // saturation at a realistic (~60 %) miss rate.
    let tc = probe_cold_cost();
    let mean_gap = (tc * 2 / 5).max(Duration::from_micros(50));
    let traffic = TrafficConfig {
        seed: 0xC0FF_EE,
        requests: if quick { 160 } else { 600 },
        tenants: TENANTS,
        zipf_s: 0.8,
        mean_gap,
        burst_p: 0.08,
        burst_len: 4,
        slo: None,
    };
    let trace = traffic.generate();
    println!(
        "cold co-sim probe: {tc:?}/request; offering {} requests, {} tenants, gap {mean_gap:?}",
        trace.len(),
        TENANTS
    );

    let off = run_mode(WarmingMode::Off, &trace);
    let on = run_mode(WarmingMode::Background, &trace);

    // Equal-correctness gate (always): warming must never change a served
    // cycle count — per request id, bit-identical accel_cycles.
    let cycles_of = |rs: &[KwsResult]| -> BTreeMap<u64, Option<u64>> {
        rs.iter().map(|r| (r.id, r.accel_cycles)).collect()
    };
    let (c_off, c_on) = (cycles_of(&off.results), cycles_of(&on.results));
    if c_off.len() != trace.len() || c_on.len() != trace.len() {
        failures.push(format!(
            "unbounded-queue replay must serve everything: off {}/{}, on {}/{}",
            c_off.len(),
            trace.len(),
            c_on.len(),
            trace.len()
        ));
    }
    for (id, cy) in &c_off {
        if c_on.get(id) != Some(cy) {
            failures.push(format!(
                "accel_cycles diverged for request {id}: off {cy:?}, on {:?}",
                c_on.get(id)
            ));
            break;
        }
    }
    if off.results.iter().any(|r| r.accel_cycles.is_none()) {
        failures.push("co-simulation disabled in replay: accel_cycles missing".into());
    }

    let p99_ratio = off.e2e.p99() as f64 / (on.e2e.p99() as f64).max(1.0);
    println!(
        "warming off: p50/p95/p99 e2e {:>8.1} {:>8.1} {:>8.1} us ({} cold sims)",
        off.e2e.p50() as f64 / 1e3,
        off.e2e.p95() as f64 / 1e3,
        off.e2e.p99() as f64 / 1e3,
        off.cold_sims
    );
    println!(
        "warming on : p50/p95/p99 e2e {:>8.1} {:>8.1} {:>8.1} us ({} cold sims, {} warm hits)",
        on.e2e.p50() as f64 / 1e3,
        on.e2e.p95() as f64 / 1e3,
        on.e2e.p99() as f64 / 1e3,
        on.cold_sims,
        on.warm_hits
    );
    println!("p99 end-to-end improvement: {p99_ratio:.2}x");

    // Wall-clock gate only on full runs: shared CI runners are too noisy
    // for tail-latency assertions; quick mode records the ratio instead.
    if !quick && p99_ratio < 1.5 {
        failures.push(format!(
            "warming p99 improvement {p99_ratio:.2}x below the 1.5x acceptance bar"
        ));
    }

    // LRU churn scaling: per-op cost at 8192 keys must stay within a
    // log-ish factor of 64 keys (the old min-scan eviction was O(n): a
    // 128x size step cost ~128x; the BTreeMap order costs ~2x).
    let churn = if quick { 20_000 } else { 200_000 };
    let (small, big) = (lru_churn_time(64, churn), lru_churn_time(8192, churn));
    let lru_ratio = big.as_secs_f64() / small.as_secs_f64().max(1e-9);
    println!("lru churn: {churn} ops at n=64 {small:?}, n=8192 {big:?} ({lru_ratio:.1}x)");
    if lru_ratio > 16.0 {
        failures.push(format!(
            "LRU churn cost grew {lru_ratio:.1}x from n=64 to n=8192 — eviction is not O(log n)"
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"serve_traffic\",\n  \"quick\": {quick},\n  \"requests\": {},\n  \
         \"tenants\": {TENANTS},\n  \"cold_probe_ns\": {},\n  \"mean_gap_ns\": {},\n  \
         \"p99_improvement\": {p99_ratio:.4},\n  \"lru_churn_ratio\": {lru_ratio:.4},\n  \
         \"modes\": [\n{},\n{}\n  ]\n}}\n",
        trace.len(),
        tc.as_nanos(),
        mean_gap.as_nanos(),
        json_mode("off", &off),
        json_mode("background", &on),
    );
    std::fs::write("BENCH_serve.json", &json).expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");
    assert!(failures.is_empty(), "acceptance checks failed:\n{}", failures.join("\n"));
    println!("serve_traffic done");
}

//! Figure 8 regenerator: inter-cycle-shift sweep at selected cycle
//! lengths, single- vs dual-ported level 0. The paper's shape: optimal
//! throughput while the shift stays below one third of the cycle length;
//! worst case one output every three cycles at shift = cycle length; the
//! dual-ported level 0 delays the decline but does not improve the worst
//! case.

use memhier::report::{fig8_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    let table = fig8_table().expect("fig8 simulation");
    println!("=== Figure 8: inter-cycle shift sweep (SP vs DP level 0) ===\n");
    println!("{}", table.render());
    let rows: Vec<Vec<u64>> = table
        .to_csv()
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|v| v.parse().unwrap()).collect())
        .collect();
    let at = |l: u64, s: u64, col: usize| {
        rows.iter().find(|r| r[0] == l && r[1] == s).map(|r| r[col]).unwrap()
    };
    for l in [96u64, 128] {
        // Small shifts run at ~1 output/cycle.
        let small = at(l, l / 8, 2) as f64;
        assert!(small < 5_800.0, "l={l}: small shifts near-optimal, got {small}");
        // Shift = cycle length bottoms out at ~3 cycles/output for both
        // port configurations.
        let worst_sp = at(l, l, 2) as f64 / 5_000.0;
        let worst_dp = at(l, l, 3) as f64 / 5_000.0;
        assert!((2.6..3.4).contains(&worst_sp), "l={l}: SP worst case {worst_sp:.2}");
        assert!((2.6..3.4).contains(&worst_dp), "l={l}: DP worst case {worst_dp:.2}");
        // DP never slower than SP (delayed decline).
        for s in [l / 3, l / 2, 2 * l / 3] {
            assert!(at(l, s, 3) <= at(l, s, 2) + 8, "l={l} s={s}: DP must not be slower");
        }
    }
    let path = save_csv(&table, "fig8").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

//! Serial vs pooled vs sharded successive halving — the distributed
//! DSE layer's headline number.
//!
//! The shard coordinator (`dse::shard`) farms halving rungs across
//! worker *processes* speaking the checkpoint wire format over
//! stdin/stdout, so the sweep scales past one address space while the
//! front stays bitwise-identical to the serial sweep (asserted here,
//! as in `tests/shard.rs`). This bench measures candidates/second for
//! the serial baseline, the in-process thread pool, and the process
//! fleet, and writes the numbers to `BENCH_shard.json` so CI can
//! publish the scaling trajectory.

use std::path::PathBuf;

use memhier::benchkit::Bencher;
use memhier::dse::{
    explore_halving, explore_halving_sharded, HalvingSchedule, HierarchyPool, KindChoice,
    SearchSpace, ShardOptions,
};
use memhier::pattern::PatternProgram;

/// How many workers the pooled and sharded contenders get.
const FLEET: usize = 4;

/// The seeded space the shard tests assert front equality on (kept
/// identical so the bench's sanity asserts track the same invariant).
fn space() -> SearchSpace {
    SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128, 1024],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: false,
        protections: vec![memhier::config::Protection::None],
        eval_hz: 100e6,
    }
}

fn workload() -> PatternProgram {
    PatternProgram::cyclic(0, 256).with_outputs(2_560)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let space = space();
    let w = workload();
    let schedule = HalvingSchedule::for_workload(&w);
    let mut opts = ShardOptions::new(FLEET);
    // Cargo points this at the bin target built for this bench run, so
    // the fleet runs the exact code under test.
    opts.worker_cmd = Some(PathBuf::from(env!("CARGO_BIN_EXE_memhier")));

    // Sanity first: the sharded sweep reproduces the serial sweep
    // bit-for-bit (points and stats semantics) — the acceptance
    // invariant `tests/shard.rs` also holds.
    let serial = explore_halving(&space, &w, &schedule).expect("serial sweep");
    let sharded = explore_halving_sharded(&space, &w, &schedule, &opts).expect("sharded sweep");
    assert_eq!(serial.points.len(), sharded.points.len());
    for (a, c) in serial.points.iter().zip(sharded.points.iter()) {
        assert_eq!(a.config, c.config, "serial vs sharded point sets diverged");
        assert_eq!(a.cycles, c.cycles);
        assert_eq!(a.area.to_bits(), c.area.to_bits());
        assert_eq!(a.on_front, c.on_front);
    }
    assert_eq!(serial.stats, sharded.stats, "stats semantics diverged");
    let candidates = serial.stats.candidates;

    let serial_r = b.bench("dse/shard_serial", || {
        explore_halving(&space, &w, &schedule).unwrap().points.len()
    });
    let serial_cps = candidates as f64 / serial_r.mean.as_secs_f64();
    println!("{}  -> {serial_cps:.1} candidates/s", serial_r.summary());

    let pool = HierarchyPool::new(FLEET);
    let pooled_r = b.bench("dse/shard_pooled", || {
        pool.explore_halving(&space, &w, &schedule).unwrap().points.len()
    });
    let pooled_cps = candidates as f64 / pooled_r.mean.as_secs_f64();
    println!("{}  -> {pooled_cps:.1} candidates/s", pooled_r.summary());

    let sharded_r = b.bench("dse/shard_fleet", || {
        explore_halving_sharded(&space, &w, &schedule, &opts).unwrap().points.len()
    });
    let sharded_cps = candidates as f64 / sharded_r.mean.as_secs_f64();
    let vs_serial = serial_r.mean.as_secs_f64() / sharded_r.mean.as_secs_f64();
    let vs_pooled = pooled_r.mean.as_secs_f64() / sharded_r.mean.as_secs_f64();
    println!(
        "{}  -> {sharded_cps:.1} candidates/s, {vs_serial:.2}x vs serial, \
         {vs_pooled:.2}x vs one pool",
        sharded_r.summary()
    );

    // Scaling gate: with >= FLEET real cores, the process fleet must
    // beat the serial sweep by a wide margin. (Skipped in --quick mode
    // and on small machines, where the measurement is noise.)
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if !quick && cores >= FLEET {
        assert!(
            vs_serial >= 1.7,
            "sharded sweep must scale: {vs_serial:.2}x vs serial on {cores} cores"
        );
    }

    let json = format!(
        "{{\n  \"bench\": \"dse_shard\",\n  \"quick\": {quick},\n  \"shards\": {FLEET},\n  \
         \"cores\": {cores},\n  \"candidates\": {candidates},\n  \
         \"serial_mean_ns\": {},\n  \"pooled_mean_ns\": {},\n  \"sharded_mean_ns\": {},\n  \
         \"serial_candidates_per_s\": {serial_cps:.2},\n  \
         \"pooled_candidates_per_s\": {pooled_cps:.2},\n  \
         \"sharded_candidates_per_s\": {sharded_cps:.2},\n  \
         \"sharded_speedup_vs_serial\": {vs_serial:.4},\n  \
         \"sharded_speedup_vs_pooled\": {vs_pooled:.4}\n}}\n",
        serial_r.mean.as_nanos(),
        pooled_r.mean.as_nanos(),
        sharded_r.mean.as_nanos(),
    );
    std::fs::write("BENCH_shard.json", &json).expect("write BENCH_shard.json");
    println!("\nwrote BENCH_shard.json");
    println!("dse_shard done");
}

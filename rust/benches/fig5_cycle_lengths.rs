//! Figure 5 regenerator: clock cycles to output 5,000 data words over
//! cycle lengths 8→1024 for level-1 depths {32, 128, 512}, with and
//! without preloading. The paper's shape: runtime ≈ doubles once the
//! cycle length exceeds the level-1 capacity; preloading removes the fill
//! phase (−21 % at depth 512).

use memhier::report::{fig5_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    let table = fig5_table().expect("fig5 simulation");
    println!("=== Figure 5: cycles to 5,000 outputs vs cycle length ===\n");
    println!("{}", table.render());
    // Shape assertions (the claims of §5.2.1).
    let rows: Vec<Vec<u64>> = table
        .to_csv()
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|v| v.parse().unwrap()).collect())
        .collect();
    let at = |cl: u64, col: usize| rows.iter().find(|r| r[0] == cl).unwrap()[col];
    // Depth 32 (col 1): cycle length 32 fits, 64 does not -> ~2x.
    let fits = at(32, 1) as f64;
    let spills = at(64, 1) as f64;
    assert!(spills / fits > 1.6, "doubling past L1 capacity: {fits} -> {spills}");
    // Preloading helps the 512-depth configuration (cols 5 vs 6).
    let no_pre = at(512, 5) as f64;
    let pre = at(512, 6) as f64;
    let gain = 1.0 - pre / no_pre;
    println!("preload gain at depth 512, l=512: {:.1}% (paper: 21%)", gain * 100.0);
    assert!(gain > 0.10, "preloading must remove the fill phase");
    let path = save_csv(&table, "fig5").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

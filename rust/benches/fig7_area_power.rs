//! Figure 7 regenerator: chip area and power of the two Figure-6
//! frameworks. Calibration anchors: 7,566 µm² (32-bit) vs 15,202 µm²
//! (128-bit + OSR), with ≈2.5× the power.

use memhier::report::{fig7_table, save_csv};

fn main() {
    let t0 = std::time::Instant::now();
    let table = fig7_table().expect("fig7");
    println!("=== Figure 7: area & power of the Fig 6 frameworks ===\n");
    println!("{}", table.render());
    let csv = table.to_csv();
    let rows: Vec<Vec<String>> =
        csv.lines().skip(1).map(|l| l.split(',').map(str::to_string).collect()).collect();
    let area32: f64 = rows[0][1].parse().unwrap();
    let area128: f64 = rows[1][1].parse().unwrap();
    let p32: f64 = rows[0][2].parse().unwrap();
    let p128: f64 = rows[1][2].parse().unwrap();
    assert!((area32 - 7_566.0).abs() / 7_566.0 < 0.01, "32-bit area anchor");
    assert!((area128 - 15_202.0).abs() / 15_202.0 < 0.01, "128-bit area anchor");
    let ratio = p128 / p32;
    println!("power ratio: {ratio:.2}x (paper: ~2.5x; 0.31 mW vs 0.124 mW)");
    assert!((1.8..3.2).contains(&ratio), "power ratio shape");
    let path = save_csv(&table, "fig7").expect("csv");
    println!("regenerated in {:?}; wrote {}", t0.elapsed(), path.display());
}

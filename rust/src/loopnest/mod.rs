//! Loop-nest analysis of DNN layers (§5.3).
//!
//! A convolutional layer is a nest over batch `N`, groups `G`, output
//! channels `K`, input channels `C`, output positions `X` and filter taps
//! `F`. Mapping it onto UltraTrail's 8×8 MAC array means choosing an
//! **unrolling** — which loop dimensions are spatially parallelized onto
//! the 64 units — and a **loop order** for the remaining (temporal)
//! iterations. Both choices shape the memory access patterns of the weight
//! and input data sets.
//!
//! This module enumerates feasible unrollings ([`unroll`]), generates the
//! resulting address traces ([`trace`]), and analyzes them
//! ([`analyze`]) with the pattern classifier — producing exactly the
//! quantities the paper's Table 2 and §5.3.1 discussion report: unique
//! addresses, cycle lengths, unique addresses per loop step (port width
//! demand), data parallelism, and MCU supportability.

pub mod analyze;
pub mod trace;
pub mod unroll;

pub use analyze::{analyze_layer, LayerAnalysis};
pub use trace::{input_trace, weight_trace, LoopDim, LoopOrder};
pub use unroll::{enumerate_unrollings, Unrolling};

//! Layer analysis: the §5.3 methodology — generate memory traces for an
//! unrolling, classify them, and derive the selection metrics the paper
//! discusses (data parallelism, unique addresses per step, pattern
//! complexity, MCU supportability).

use super::trace::{input_trace, weight_trace, LoopOrder};
use super::unroll::Unrolling;
use crate::model::{LayerKind, LayerSpec};
use crate::pattern::{classify_trace, Classification};

/// Analysis result for one layer under one unrolling.
#[derive(Debug, Clone)]
pub struct LayerAnalysis {
    /// Layer index.
    pub layer: usize,
    /// Conv or FC.
    pub kind: LayerKind,
    /// Unique weight addresses (weight-port words) of the layer.
    pub weight_unique: u64,
    /// Classified weight access pattern.
    pub weight_pattern: Classification,
    /// Unique input tile addresses.
    pub input_unique: u64,
    /// Classified input access pattern.
    pub input_pattern: Classification,
    /// Weight reuse factor (reads / unique).
    pub weight_reuse: f64,
    /// Unique weight addresses needed per loop step (port width demand).
    pub weight_addrs_per_step: u64,
    /// Average MAC utilization of the unrolling on this layer.
    pub utilization: f64,
    /// Whether the MCU can execute both patterns directly (§5.3: some
    /// unrollings "currently lack MCU support").
    pub mcu_supported: bool,
}

/// Analyze one layer under an unrolling and loop order.
pub fn analyze_layer(l: &LayerSpec, u: &Unrolling, order: LoopOrder) -> LayerAnalysis {
    let wt = weight_trace(l, u, order);
    let it = input_trace(l, u, order);
    let w_unique = crate::pattern::classify::unique_addresses(&wt);
    let i_unique = crate::pattern::classify::unique_addresses(&it);
    let w_class = classify_trace(&wt);
    let i_class = classify_trace(&it);
    let mcu_supported = w_class.mcu_supported() && i_class.mcu_supported();
    LayerAnalysis {
        layer: l.idx,
        kind: l.kind,
        weight_unique: w_unique,
        weight_pattern: w_class,
        input_unique: i_unique,
        input_pattern: i_class,
        weight_reuse: if w_unique == 0 { 0.0 } else { wt.len() as f64 / w_unique as f64 },
        weight_addrs_per_step: u.weight_addrs_per_step(),
        utilization: u.utilization(l),
        mcu_supported,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loopnest::unroll::paper_sweep;
    use crate::model::tc_resnet8;

    #[test]
    fn weight_reuse_equals_x_for_full_channel_unroll() {
        // With uk=8, uc=8 (64 unique addrs/step) under the UltraTrail
        // order, each port word is revisited once per X tile — Table 2's
        // "cycle length" interpretation.
        let layers = tc_resnet8();
        let u = paper_sweep()[3].1;
        for l in layers.iter().filter(|l| l.kind == LayerKind::Conv) {
            if l.k % 8 == 0 && l.c % 8 == 0 {
                let a = analyze_layer(l, &u, LoopOrder::ultratrail());
                assert!(
                    (a.weight_reuse - l.x as f64).abs() < 1e-9,
                    "layer {}: reuse {} != X {}",
                    l.idx,
                    a.weight_reuse,
                    l.x
                );
            }
        }
    }

    #[test]
    fn fc_layers_have_no_reuse() {
        let layers = tc_resnet8();
        let u = paper_sweep()[3].1;
        for l in layers.iter().filter(|l| l.kind == LayerKind::Fc) {
            let a = analyze_layer(l, &u, LoopOrder::ultratrail());
            assert!((a.weight_reuse - 1.0).abs() < 1e-9, "layer {} FC reuse", l.idx);
        }
    }

    #[test]
    fn weight_patterns_are_mcu_supported_for_ultratrail_order() {
        // §5.3: "The weight data sets exhibit a sequential [or simple
        // cyclic] pattern" — the single-level hierarchy can execute them.
        let layers = tc_resnet8();
        let u = paper_sweep()[3].1;
        for l in &layers {
            let a = analyze_layer(l, &u, LoopOrder::ultratrail());
            assert!(
                a.weight_pattern.mcu_supported(),
                "layer {} weight pattern {:?}",
                l.idx,
                a.weight_pattern
            );
        }
    }

    #[test]
    fn utilization_reported_per_layer() {
        let l = tc_resnet8()[0]; // C=40
        let u = paper_sweep()[3].1; // uc=8 divides 40
        let a = analyze_layer(&l, &u, LoopOrder::ultratrail());
        assert!((a.utilization - 1.0).abs() < 1e-12);
        assert_eq!(a.weight_addrs_per_step, 64);
    }
}

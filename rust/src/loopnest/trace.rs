//! Memory-trace generation for an unrolled loop nest.
//!
//! Weights live at addresses `((k·C + c)·F + f)` of the layer's weight
//! space (one address per weight-port *step group*); inputs live at
//! `c·X_in + x_in`. The temporal loops iterate tiles in a configurable
//! order — the resulting address sequences are the "memory traces of the
//! selected unrolling" the paper analyzes (§5.3).

use super::unroll::Unrolling;
use crate::model::LayerSpec;
use crate::util::ceil_div;

/// A temporal loop dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopDim {
    /// Output channels (tiles of `uk`).
    K,
    /// Input channels (tiles of `uc`).
    C,
    /// Output positions (tiles of `ux`).
    X,
    /// Filter taps (tiles of `uf`).
    F,
}

/// Temporal loop order, outermost first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoopOrder(pub [LoopDim; 4]);

impl LoopOrder {
    /// UltraTrail's weight-stationary-ish default: K outer, then C, X
    /// inner-most iterates time, F innermost.
    pub fn ultratrail() -> Self {
        LoopOrder([LoopDim::K, LoopDim::C, LoopDim::X, LoopDim::F])
    }

    /// Output-stationary order: X outer, weights cycle per position.
    pub fn output_stationary() -> Self {
        LoopOrder([LoopDim::X, LoopDim::K, LoopDim::C, LoopDim::F])
    }
}

/// Tile counts per dimension for a layer under an unrolling.
fn tiles(l: &LayerSpec, u: &Unrolling) -> [u64; 4] {
    [
        ceil_div(l.k, u.uk),
        ceil_div(l.c, u.uc),
        ceil_div(l.x, u.ux),
        ceil_div(l.f, u.uf),
    ]
}

fn dim_index(d: LoopDim) -> usize {
    match d {
        LoopDim::K => 0,
        LoopDim::C => 1,
        LoopDim::X => 2,
        LoopDim::F => 3,
    }
}

/// Iterate the temporal loop nest, yielding (k_tile, c_tile, x_tile,
/// f_tile) per step in the given order.
fn steps(l: &LayerSpec, u: &Unrolling, order: LoopOrder) -> Vec<[u64; 4]> {
    let t = tiles(l, u);
    let idx = order.0.map(dim_index);
    let counts = [t[idx[0]], t[idx[1]], t[idx[2]], t[idx[3]]];
    let mut out = Vec::with_capacity((counts.iter().product::<u64>()) as usize);
    for a in 0..counts[0] {
        for b in 0..counts[1] {
            for c in 0..counts[2] {
                for d in 0..counts[3] {
                    let mut tile = [0u64; 4];
                    tile[idx[0]] = a;
                    tile[idx[1]] = b;
                    tile[idx[2]] = c;
                    tile[idx[3]] = d;
                    out.push(tile);
                }
            }
        }
    }
    out
}

/// Weight address trace: one address per loop step, identifying the
/// weight-port word (group of `uk·uc·uf` weights) the step consumes.
/// Port words are indexed `(k_tile·Ct + c_tile)·Ft + f_tile`.
pub fn weight_trace(l: &LayerSpec, u: &Unrolling, order: LoopOrder) -> Vec<u64> {
    let t = tiles(l, u);
    steps(l, u, order)
        .into_iter()
        .map(|[kt, ct, _xt, ft]| (kt * t[1] + ct) * t[3] + ft)
        .collect()
}

/// Input address trace: one address per loop step, identifying the input
/// tile `(c_tile·Xt + x_tile)` the step consumes (filter taps slide within
/// the tile, adding `f_tile` as a sub-offset for strided analysis).
pub fn input_trace(l: &LayerSpec, u: &Unrolling, order: LoopOrder) -> Vec<u64> {
    let t = tiles(l, u);
    steps(l, u, order)
        .into_iter()
        .map(|[_kt, ct, xt, ft]| ct * (t[2] + t[3] - 1) + xt + ft)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{tc_resnet8, LayerSpec};
    use crate::model::LayerKind;
    use crate::pattern::{classify_trace, Classification};

    fn small() -> LayerSpec {
        LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 }
    }

    #[test]
    fn trace_lengths_match_step_counts() {
        let l = small();
        let u = Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 };
        let tr = weight_trace(&l, &u, LoopOrder::ultratrail());
        assert_eq!(tr.len() as u64, u.steps(&l)); // 2*1*4*3 = 24
    }

    #[test]
    fn ultratrail_order_weights_cycle_per_x() {
        // K outer, C, X, F inner: for fixed (k,c) the F-tap port words
        // cycle once per x tile -> cyclic windows of length Ft repeated
        // Xt times, shifting to the next (c) window after.
        let l = small();
        let u = Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 };
        let tr = weight_trace(&l, &u, LoopOrder::ultratrail());
        // First x iteration reads taps 0,1,2; second x the same.
        assert_eq!(&tr[0..6], &[0, 1, 2, 0, 1, 2]);
        let c = classify_trace(&tr[0..12]);
        assert_eq!(c, Classification::Cyclic { start: 0, cycle_length: 3 });
    }

    #[test]
    fn output_stationary_weights_cycle_over_all_tiles() {
        // X outer: per position the full (K,C,F) tile set is read ->
        // cyclic with cycle length = total port words.
        let l = small();
        let u = Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 };
        let tr = weight_trace(&l, &u, LoopOrder::output_stationary());
        let c = classify_trace(&tr);
        assert_eq!(
            c,
            Classification::Cyclic { start: 0, cycle_length: 2 * 1 * 3 },
            "2 K-tiles x 1 C-tile x 3 taps"
        );
    }

    #[test]
    fn fc_layer_weights_are_sequential() {
        // §5.3.2: FC layers never reuse weights.
        let l = tc_resnet8()[12];
        let u = Unrolling { uk: 4, uc: 16, ux: 1, uf: 1 };
        let tr = weight_trace(&l, &u, LoopOrder::ultratrail());
        let c = classify_trace(&tr);
        assert!(
            matches!(c, Classification::Sequential { .. }),
            "FC trace should be sequential, got {c:?}"
        );
    }

    #[test]
    fn weight_trace_unique_count_matches_port_words() {
        use crate::pattern::classify::unique_addresses;
        let l = tc_resnet8()[0];
        let u = Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 };
        let tr = weight_trace(&l, &u, LoopOrder::ultratrail());
        // Port words = ceil(K/8)*ceil(C/8)*F = 2*5*3 = 30.
        assert_eq!(unique_addresses(&tr), 30);
    }

    #[test]
    fn input_trace_is_structured() {
        let l = small();
        let u = Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 };
        let tr = input_trace(&l, &u, LoopOrder::ultratrail());
        // Inputs shift with x and f: never pseudo-random for conv nests.
        let c = classify_trace(&tr);
        assert_ne!(c, Classification::PseudoRandom, "got {c:?}");
    }
}

//! Unrolling enumeration: ways to spatially map loop dimensions onto the
//! MAC array.
//!
//! UltraTrail's data flow is mostly static, so every layer must use the
//! same unrolling (§5.3). An unrolling assigns a parallel factor to K, C,
//! X and F whose product equals the MAC count (64 for the 8×8 array).
//! The §5.3.1 evaluation sweeps the *unique weight addresses per loop
//! step* — `uk·uc·uf` — over {8, 16, 32, 64} by trading X-parallelism
//! (which reuses one weight across time steps) for channel parallelism.

use crate::model::LayerSpec;

/// A spatial unrolling of the loop nest onto the MAC array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Unrolling {
    /// Output-channel parallel factor.
    pub uk: u64,
    /// Input-channel parallel factor.
    pub uc: u64,
    /// Output-position (time) parallel factor.
    pub ux: u64,
    /// Filter-tap parallel factor.
    pub uf: u64,
}

impl Unrolling {
    /// Total MAC units used.
    pub fn macs(&self) -> u64 {
        self.uk * self.uc * self.ux * self.uf
    }

    /// Unique weight addresses needed per loop step (§5.3.1): weights are
    /// indexed by (k, c, f), so X-parallel units share them.
    pub fn weight_addrs_per_step(&self) -> u64 {
        self.uk * self.uc * self.uf
    }

    /// Unique input addresses needed per loop step: inputs are indexed by
    /// (c, x, f); K-parallel units share them. Adjacent (x, f) pairs
    /// overlap for stride-1 convs, giving `ux + uf - 1` positions.
    pub fn input_addrs_per_step(&self) -> u64 {
        self.uc * (self.ux + self.uf - 1)
    }

    /// Weight port width in bits at the given weight precision.
    pub fn weight_port_bits(&self, bits_per_weight: u64) -> u64 {
        self.weight_addrs_per_step() * bits_per_weight
    }

    /// Temporal loop step count for a layer under this unrolling (ceil
    /// division per dimension).
    pub fn steps(&self, l: &LayerSpec) -> u64 {
        use crate::util::ceil_div;
        ceil_div(l.k, self.uk) * ceil_div(l.c, self.uc) * ceil_div(l.x, self.ux) * ceil_div(l.f, self.uf)
    }

    /// Average MAC utilization over a layer: useful MACs / (steps × array
    /// size). Below 1.0 when dimensions don't divide the factors.
    pub fn utilization(&self, l: &LayerSpec) -> f64 {
        l.macs() as f64 / (self.steps(l) * self.macs()) as f64
    }
}

/// Divisors of `n` up to `cap`, ascending.
fn divisors(n: u64, cap: u64) -> impl Iterator<Item = u64> {
    (1..=n.min(cap)).filter(move |d| n % d == 0)
}

/// Enumerate all unrollings with `uk·uc·ux·uf == n_macs`, factors bounded
/// by `max_factor` per dimension.
///
/// **Emission order is part of the API**: ascending lexicographic in
/// `(uk, uc, ux)` — `uk` is the slowest digit, `ux` the fastest, and
/// `uf` is determined by the other three. The joint DSE
/// ([`crate::dse::dims`]) uses this list as an odometer dimension, so
/// the order is pinned the same way config enumeration is
/// (`enumeration_order_is_pinned` keeps the old filter-based walk as a
/// differential reference).
///
/// Each nesting level iterates only the divisors of the *remaining*
/// quotient: `uc` ranges over divisors of `n_macs / uk`, `ux` over
/// divisors of `n_macs / (uk·uc)` — every emitted candidate is valid by
/// construction, no filtering.
pub fn enumerate_unrollings(n_macs: u64, max_factor: u64) -> Vec<Unrolling> {
    let mut out = Vec::new();
    for uk in divisors(n_macs, max_factor) {
        for uc in divisors(n_macs / uk, max_factor) {
            for ux in divisors(n_macs / (uk * uc), max_factor) {
                let uf = n_macs / (uk * uc * ux);
                if uf <= max_factor {
                    out.push(Unrolling { uk, uc, ux, uf });
                }
            }
        }
    }
    out
}

/// The four §5.3.1 sweep points: K-major unrollings with 8/16/32/64
/// unique weight addresses per step on the 8×8 array.
pub fn paper_sweep() -> Vec<(u64, Unrolling)> {
    vec![
        (8, Unrolling { uk: 8, uc: 1, ux: 8, uf: 1 }),
        (16, Unrolling { uk: 8, uc: 2, ux: 4, uf: 1 }),
        (32, Unrolling { uk: 8, uc: 4, ux: 2, uf: 1 }),
        (64, Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tc_resnet8;

    #[test]
    fn paper_sweep_unique_addresses() {
        for (expect, u) in paper_sweep() {
            assert_eq!(u.macs(), 64, "all sweep points use the full array");
            assert_eq!(u.weight_addrs_per_step(), expect);
        }
    }

    #[test]
    fn sweep_port_widths_match_section_5_3_1() {
        // "Unrollings featuring only eight unique addresses per loop step
        // demand a 64-bit word width": 8 x 6-bit = 48 bits -> 64-bit word.
        let (_, u8) = paper_sweep().into_iter().next().unwrap();
        assert!(u8.weight_port_bits(6) <= 64);
        // 64 unique addresses: 384-bit port (64 x 6).
        let (_, u64_) = paper_sweep().into_iter().nth(3).unwrap();
        assert_eq!(u64_.weight_port_bits(6), 384);
    }

    #[test]
    fn layer11_depth_requirement() {
        // "at least 2,592 RAM depth" for the 8-unique-address unrolling:
        // 20,736 weights / 8 per word.
        let l11 = tc_resnet8()[11];
        let (_, u) = paper_sweep().into_iter().next().unwrap();
        assert_eq!(l11.weights() / u.weight_addrs_per_step(), 2_592);
    }

    #[test]
    fn enumeration_is_complete_and_valid() {
        let all = enumerate_unrollings(64, 64);
        assert!(all.iter().all(|u| u.macs() == 64));
        // 64 = 2^6: compositions of 6 over 4 slots = C(9,3) = 84.
        assert_eq!(all.len(), 84);
        // Contains the paper's sweep points.
        for (_, u) in paper_sweep() {
            assert!(all.contains(&u));
        }
    }

    /// The pre-refactor walk: iterate the full divisor list of `n_macs`
    /// at every nesting level and filter out non-dividing combinations.
    /// Kept verbatim as the differential reference pinning the order.
    fn enumerate_reference(n_macs: u64, max_factor: u64) -> Vec<Unrolling> {
        let mut out = Vec::new();
        let divisors: Vec<u64> =
            (1..=n_macs.min(max_factor)).filter(|d| n_macs % d == 0).collect();
        for &uk in &divisors {
            for &uc in &divisors {
                if n_macs % (uk * uc) != 0 {
                    continue;
                }
                for &ux in &divisors {
                    let rem = uk * uc * ux;
                    if n_macs % rem != 0 {
                        continue;
                    }
                    let uf = n_macs / rem;
                    if uf <= max_factor {
                        out.push(Unrolling { uk, uc, ux, uf });
                    }
                }
            }
        }
        out
    }

    #[test]
    fn enumeration_order_is_pinned() {
        // The documented (uk, uc, ux)-lexicographic order must match the
        // old filter-based walk exactly — sequence, not just set — for
        // square and non-square arrays and under factor caps.
        for (n, cap) in [(64, 64), (64, 8), (36, 36), (48, 6), (1, 1), (7, 7)] {
            let a = enumerate_unrollings(n, cap);
            let b = enumerate_reference(n, cap);
            assert_eq!(a, b, "n_macs={n} max_factor={cap}");
            // And it really is ascending lexicographic in (uk, uc, ux).
            for w in a.windows(2) {
                let ka = (w[0].uk, w[0].uc, w[0].ux);
                let kb = (w[1].uk, w[1].uc, w[1].ux);
                assert!(ka < kb, "order violation: {ka:?} !< {kb:?}");
            }
        }
    }

    #[test]
    fn utilization_penalizes_non_dividing_factors() {
        let l0 = tc_resnet8()[0]; // K=16, C=40, F=3, X=98
        let u = Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 };
        // C=40 divides 8; K=16 divides 8: full utilization.
        assert!((u.utilization(&l0) - 1.0).abs() < 1e-12);
        let u = Unrolling { uk: 8, uc: 1, ux: 1, uf: 8 };
        // F=3 under uf=8 wastes 5/8 of the array.
        assert!(u.utilization(&l0) < 0.5);
    }

    #[test]
    fn input_addresses_overlap_for_time_parallelism() {
        let u = Unrolling { uk: 8, uc: 1, ux: 8, uf: 1 };
        assert_eq!(u.input_addrs_per_step(), 8);
        let u = Unrolling { uk: 1, uc: 1, ux: 8, uf: 8 };
        // 8 positions x 8 taps overlap into 15 distinct inputs.
        assert_eq!(u.input_addrs_per_step(), 15);
    }
}

//! The KWS serving coordinator: batches inference requests, runs the
//! AOT-compiled TC-ResNet through the PJRT runtime, and co-simulates the
//! weight stream through the memory hierarchy to produce the cycle-level
//! timing a real UltraTrail deployment would see.
//!
//! The paper's contribution is the memory subsystem, so the coordinator is
//! deliberately thin: a request queue on std channels, a batcher, and the
//! per-inference timing model. Python never runs here — the model is a
//! compiled artifact.

pub mod kws;
pub mod server;

pub use kws::{synth_request, KwsRequest, KwsResult, MFCC_BINS, MFCC_FRAMES, N_CLASSES};
pub use server::{CoordinatorStats, KwsServer, ServerConfig};

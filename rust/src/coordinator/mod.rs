//! The KWS serving coordinator: an admission-controlled, SLO-aware,
//! speculatively-warmed multi-tenant serving tier over the memory-
//! hierarchy co-simulation.
//!
//! ```text
//!  producer ─► admission queue ─► SLO-aware batcher ─► executor
//!              (bounded depth,    (max_batch | oldest   (co-sim +
//!               tenant caps,       deadline | drain)     host infer)
//!               typed sheds)              ▲                  │
//!                   │ arrivals            │ warm hits        │ cache
//!                   ▼                     │                  ▼ updates
//!              arrival predictor ─► speculative warmer ─► warm store
//!              (EWMA, logical clock)  (2nd warm Session)  (bounded bytes)
//! ```
//!
//! The paper's contribution is the memory subsystem, so every serving
//! feature is built around the co-simulation: a request's dominant cost
//! is cold-simulating its tenant's weight stream through the hierarchy,
//! and the tier's job is to keep that work off the request path —
//! admission control sheds what it can't serve ([`queue`]), the warmer
//! pre-simulates who arrives next ([`warm`]), and the batcher trades
//! batch fill against per-request deadlines ([`server`]).
//!
//! **Determinism contract**: a served `accel_cycles` value is the same
//! whether it came from the cycle cache, the warm store, or a cold
//! simulation — warm-session determinism makes all three bit-identical,
//! so warming and caching are latency optimizations, never semantic
//! ones. With [`server::WarmingMode::Synchronous`] the *entire* serving
//! pipeline (warming decisions included) is a pure function of the
//! admitted request sequence. Python never runs here — the host model is
//! a compiled artifact (or a deterministic stand-in, see
//! [`server::KwsServer::sim_only`]).

pub mod kws;
pub mod queue;
pub mod server;
pub mod traffic;
pub mod warm;

pub use kws::{synth_request, KwsRequest, KwsResult, MFCC_BINS, MFCC_FRAMES, N_CLASSES};
pub use queue::{AdmissionQueue, QueuedRequest, ShedReason};
pub use server::{CoordinatorStats, KwsServer, ServerConfig, TenantStats, WarmingMode};
pub use traffic::{TracedRequest, TrafficConfig, TENANT_STRIDE};
pub use warm::{ArrivalPredictor, WarmStats, WarmStore};

//! Keyword-spotting request/response types and the synthetic feature
//! corpus (stands in for the Google speech-commands subset: the case
//! study needs realistic shapes and latencies, not accuracy claims).

use crate::util::rng::{Rng, Xoshiro256};

/// MFCC feature bins (input channels of the TC-ResNet stem).
pub const MFCC_BINS: usize = 40;
/// Feature frames per utterance (1 s at 10 ms hop); the 3-tap stem
/// reduces this to the 98 output positions of Table 2 layer 0.
pub const MFCC_FRAMES: usize = 100;
/// Keyword classes (speech-commands 10 keywords + silence + unknown).
pub const N_CLASSES: usize = 12;

/// One inference request.
#[derive(Debug, Clone)]
pub struct KwsRequest {
    /// Request id.
    pub id: u64,
    /// MFCC-like features, `MFCC_BINS × MFCC_FRAMES`, row-major.
    pub features: Vec<f32>,
    /// Off-chip base address of this request's weight set. Multi-tenant
    /// serving keeps several resident models at different addresses; the
    /// per-batch weight-stream co-simulation fetches from this base, so
    /// requests with different bases exercise different access patterns
    /// on the same warm hierarchy. `0` = the default model.
    pub weight_base: u64,
    /// Latency SLO: the request should complete within this much time of
    /// its arrival. Drives the SLO-aware batcher (a batch closes no later
    /// than the oldest request's deadline) and the `deadline_miss`
    /// counter. `None` = best-effort (the server's default SLO, if any,
    /// applies).
    pub slo: Option<std::time::Duration>,
}

impl KwsRequest {
    /// Point this request at a weight set resident at `base` (builder
    /// style). Must leave room for the full weight stream inside the
    /// co-simulated hierarchy's off-chip address space (24-bit in the
    /// UltraTrail configuration).
    pub fn with_weight_base(mut self, base: u64) -> Self {
        self.weight_base = base;
        self
    }

    /// Attach a completion SLO (builder style).
    pub fn with_slo(mut self, slo: std::time::Duration) -> Self {
        self.slo = Some(slo);
        self
    }
}

/// One inference result.
#[derive(Debug, Clone)]
pub struct KwsResult {
    /// Request id.
    pub id: u64,
    /// Class logits.
    pub logits: Vec<f32>,
    /// Argmax class.
    pub class: usize,
    /// Simulated accelerator cycles for this inference (weight streaming
    /// co-simulation), if enabled.
    pub accel_cycles: Option<u64>,
    /// Wall-clock **service** time of this request alone (co-simulation +
    /// host inference) — *not* measured from batch start, so requests
    /// late in a batch are not inflated by their predecessors.
    pub host_latency: std::time::Duration,
    /// Time spent waiting before service began: queueing plus in-batch
    /// wait behind earlier requests. `host_latency + queue_wait` is the
    /// end-to-end latency the client sees.
    pub queue_wait: std::time::Duration,
    /// Sequence number of the batch this request was served in (batch
    /// formation is observable: all members share it).
    pub batch_seq: u64,
    /// Whether the request completed after its deadline (arrival + SLO).
    pub deadline_missed: bool,
}

/// Deterministic synthetic utterance: band-limited noise with a
/// class-dependent spectral envelope, mimicking MFCC statistics.
pub fn synth_request(id: u64) -> KwsRequest {
    let mut rng = Xoshiro256::new(id.wrapping_mul(0x9E37_79B9));
    let class = (id % N_CLASSES as u64) as usize;
    let mut features = vec![0f32; MFCC_BINS * MFCC_FRAMES];
    for b in 0..MFCC_BINS {
        // Class-dependent envelope peak.
        let peak = (class * MFCC_BINS / N_CLASSES) as f64;
        let env = (-((b as f64 - peak) / 6.0).powi(2)).exp();
        for t in 0..MFCC_FRAMES {
            let noise = rng.gen_f64() * 2.0 - 1.0;
            let tone = (t as f64 * 0.1 + b as f64 * 0.3).sin() * env;
            features[b * MFCC_FRAMES + t] = (0.7 * tone + 0.3 * noise) as f32;
        }
    }
    KwsRequest { id, features, weight_base: 0, slo: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_is_deterministic_and_shaped() {
        let a = synth_request(7);
        let b = synth_request(7);
        assert_eq!(a.features, b.features);
        assert_eq!(a.features.len(), MFCC_BINS * MFCC_FRAMES);
        let c = synth_request(8);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn features_are_bounded() {
        let r = synth_request(42);
        assert!(r.features.iter().all(|v| v.abs() <= 1.5));
        // Non-degenerate: real variance.
        let mean: f32 = r.features.iter().sum::<f32>() / r.features.len() as f32;
        let sq_sum: f32 = r.features.iter().map(|v| (v - mean) * (v - mean)).sum();
        let var = sq_sum / r.features.len() as f32;
        assert!(var > 0.01);
    }
}

//! Synthetic multi-tenant traffic: seeded, heavy-tailed request traces
//! for exercising the serving tier.
//!
//! Real multi-tenant serving mixes are skewed (a few hot tenants, a long
//! tail of cold ones) and bursty (arrivals cluster). [`TrafficConfig`]
//! models both: tenant popularity is Zipf-distributed over `tenants`
//! resident weight sets (each at its own `weight_base`, spaced by
//! [`TENANT_STRIDE`]), and inter-arrival gaps are exponential with
//! occasional multiplicative bursts. Everything is driven by one seeded
//! [`Xoshiro256`], so a trace is a pure function of its config — the
//! serving benchmark replays the *same* trace with warming on and off.

use super::kws::{synth_request, KwsRequest};
use crate::util::rng::{Rng, Xoshiro256};
use std::time::Duration;

/// Address stride between resident tenant weight sets. The largest
/// UltraTrail layer streams ~3.9k off-chip units, so a 4096-unit stride
/// keeps every tenant's stream disjoint and inside the 24-bit address
/// space for up to 4096 tenants.
pub const TENANT_STRIDE: u64 = 4096;

/// One timed request of a synthetic trace.
#[derive(Debug, Clone)]
pub struct TracedRequest {
    /// Submission offset from replay start.
    pub at: Duration,
    /// The request (tenant selected by the trace's Zipf draw).
    pub req: KwsRequest,
}

/// Seeded synthetic traffic parameters.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// RNG seed; same seed, same trace.
    pub seed: u64,
    /// Total requests in the trace.
    pub requests: usize,
    /// Distinct resident tenants (weight sets).
    pub tenants: usize,
    /// Zipf skew exponent (`0` = uniform, `~1` = classic heavy tail).
    pub zipf_s: f64,
    /// Mean inter-arrival gap.
    pub mean_gap: Duration,
    /// Probability that a request starts a burst (near-zero gaps).
    pub burst_p: f64,
    /// Requests per burst.
    pub burst_len: usize,
    /// Per-request SLO stamped on every request (`None` = best-effort).
    pub slo: Option<Duration>,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            seed: 0x7AFF_1C,
            requests: 256,
            tenants: 48,
            zipf_s: 1.1,
            mean_gap: Duration::from_micros(200),
            burst_p: 0.1,
            burst_len: 6,
            slo: None,
        }
    }
}

impl TrafficConfig {
    /// Generate the trace: `requests` timed requests, tenant picked per
    /// request by a Zipf draw, arrival offsets accumulated from
    /// exponential gaps with bursts. Deterministic for a given config.
    pub fn generate(&self) -> Vec<TracedRequest> {
        let mut rng = Xoshiro256::new(self.seed);
        let zipf = ZipfSampler::new(self.tenants.max(1), self.zipf_s);
        let mut trace = Vec::with_capacity(self.requests);
        let mut at = Duration::ZERO;
        let mut burst_left = 0usize;
        for id in 0..self.requests as u64 {
            let gap = if burst_left > 0 {
                burst_left -= 1;
                // In-burst arrivals are near-simultaneous.
                self.mean_gap / 50
            } else {
                if rng.gen_f64() < self.burst_p {
                    burst_left = self.burst_len.saturating_sub(1);
                }
                // Exponential gap: -ln(U) * mean.
                let u = rng.gen_f64().max(1e-12);
                Duration::from_nanos((-u.ln() * self.mean_gap.as_nanos() as f64) as u64)
            };
            at += gap;
            let tenant = zipf.sample(&mut rng) as u64;
            let mut req = synth_request(id).with_weight_base(tenant * TENANT_STRIDE);
            if let Some(slo) = self.slo {
                req = req.with_slo(slo);
            }
            trace.push(TracedRequest { at, req });
        }
        trace
    }
}

/// Inverse-CDF Zipf sampler over ranks `0..n` (rank 0 hottest).
#[derive(Debug, Clone)]
struct ZipfSampler {
    /// Cumulative normalized weights, ascending.
    cdf: Vec<f64>,
}

impl ZipfSampler {
    fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        for w in &mut cdf {
            *w /= acc;
        }
        Self { cdf }
    }

    fn sample(&self, rng: &mut impl Rng) -> usize {
        let u = rng.gen_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn trace_is_deterministic_and_monotonic() {
        let cfg = TrafficConfig { requests: 64, ..Default::default() };
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.req.id, y.req.id);
            assert_eq!(x.req.weight_base, y.req.weight_base);
        }
        // Arrival offsets never go backwards.
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn zipf_skew_concentrates_on_hot_tenants() {
        let cfg = TrafficConfig { requests: 2000, tenants: 32, zipf_s: 1.2, ..Default::default() };
        let trace = cfg.generate();
        let mut counts: BTreeMap<u64, usize> = BTreeMap::new();
        for t in &trace {
            *counts.entry(t.req.weight_base).or_default() += 1;
            assert_eq!(t.req.weight_base % TENANT_STRIDE, 0);
            assert!(t.req.weight_base < 32 * TENANT_STRIDE);
        }
        // The hottest tenant (rank 0 = base 0) dominates any tail tenant.
        let hot = counts.get(&0).copied().unwrap_or(0);
        let tail_max =
            counts.iter().filter(|(&b, _)| b >= 16 * TENANT_STRIDE).map(|(_, &c)| c).max();
        assert!(
            hot > 4 * tail_max.unwrap_or(0).max(1),
            "Zipf head must dominate the tail: hot={hot}, tail={tail_max:?}"
        );
        // Multiple tenants appear — it's a mix, not a single stream.
        assert!(counts.len() >= 8, "expected a real tenant mix, got {}", counts.len());
    }

    #[test]
    fn slo_stamps_every_request() {
        let slo = Duration::from_millis(5);
        let cfg = TrafficConfig { requests: 16, slo: Some(slo), ..Default::default() };
        assert!(cfg.generate().iter().all(|t| t.req.slo == Some(slo)));
        let none = TrafficConfig { requests: 16, slo: None, ..Default::default() };
        assert!(none.generate().iter().all(|t| t.req.slo.is_none()));
    }

    #[test]
    fn uniform_zipf_spreads_load() {
        let cfg = TrafficConfig { requests: 1000, tenants: 8, zipf_s: 0.0, ..Default::default() };
        let trace = cfg.generate();
        let mut counts: BTreeMap<u64, usize> = BTreeMap::new();
        for t in &trace {
            *counts.entry(t.req.weight_base).or_default() += 1;
        }
        assert_eq!(counts.len(), 8, "uniform draw should touch every tenant");
        assert!(counts.values().all(|&c| c > 50), "uniform draw should balance: {counts:?}");
    }
}

//! Speculative checkpoint warming: predict which tenant arrives next and
//! pre-simulate its hierarchy state off the request path.
//!
//! Three pieces (all deterministic given the same observation sequence):
//!
//! * [`ArrivalPredictor`] — a per-`weight_base` EWMA of inter-arrival
//!   gaps on a **logical clock** (one tick per admitted request, not wall
//!   time, so warming decisions replay bit-identically under a seeded
//!   trace). A tenant's next arrival is predicted at
//!   `last_seen + ewma_gap`; the warmer warms the tenants due soonest.
//! * [`park_session`] — runs a program batch on a warm
//!   [`Session`](crate::sim::batch::Session) and parks the result: the
//!   per-program supply cycles plus the final hierarchy state serialized
//!   through [`crate::mem::wire`] (the same bounded, versioned format the
//!   sharded DSE ships between processes).
//! * [`WarmStore`] — a bounded (entry- *and* byte-budgeted) store of
//!   parked [`WarmEntry`]s with O(log n) LRU eviction
//!   ([`crate::util::LruOrder`]). The request path *takes* entries out;
//!   the warmer fills them back in.
//!
//! Determinism contract: a warm entry's cycle count is produced by the
//! same warm-session simulation a cold request-path miss would run
//! (warm-vs-cold bit-identity, `tests/serve.rs`), so serving from warmed
//! state is purely a latency optimization — never a semantic one.

use crate::mem::wire::encode_checkpoint;
use crate::pattern::PatternProgram;
use crate::sim::batch::Session;
use crate::util::LruOrder;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Per-tenant arrival history (logical-clock ticks).
#[derive(Debug, Clone, Copy)]
struct Arrival {
    /// Logical tick of the most recent observation.
    last_seen: u64,
    /// EWMA of inter-arrival gaps, in ticks.
    ewma_gap: f64,
}

/// Per-`weight_base` arrival predictor (see module docs).
#[derive(Debug, Clone)]
pub struct ArrivalPredictor {
    /// EWMA weight of the newest gap.
    alpha: f64,
    /// Logical clock: admitted requests observed so far.
    clock: u64,
    tenants: BTreeMap<u64, Arrival>,
}

impl Default for ArrivalPredictor {
    fn default() -> Self {
        Self::new(0.3)
    }
}

impl ArrivalPredictor {
    /// Predictor with EWMA weight `alpha` (newest gap's share).
    pub fn new(alpha: f64) -> Self {
        Self { alpha: alpha.clamp(0.01, 1.0), clock: 0, tenants: BTreeMap::new() }
    }

    /// Record one admitted request for `base`, advancing the logical
    /// clock. A first-seen tenant gets the elapsed clock as its gap prior
    /// (a tenant first seen after t requests has apparent rate 1/t).
    pub fn observe(&mut self, base: u64) {
        self.clock += 1;
        match self.tenants.get_mut(&base) {
            Some(a) => {
                let gap = (self.clock - a.last_seen) as f64;
                a.ewma_gap = self.alpha * gap + (1.0 - self.alpha) * a.ewma_gap;
                a.last_seen = self.clock;
            }
            None => {
                let prior = self.clock as f64;
                self.tenants.insert(base, Arrival { last_seen: self.clock, ewma_gap: prior });
            }
        }
    }

    /// Predicted logical tick of `base`'s next arrival (`None` if never
    /// seen).
    pub fn predicted_next(&self, base: u64) -> Option<f64> {
        self.tenants.get(&base).map(|a| a.last_seen as f64 + a.ewma_gap)
    }

    /// The `k` tenants most likely to arrive next (earliest predicted
    /// next-arrival first; recency, then base, breaks ties), excluding
    /// those for which `skip` returns true — typically tenants whose
    /// state is already warm or cached. Deterministic for a given
    /// observation history.
    pub fn candidates(&self, k: usize, mut skip: impl FnMut(u64) -> bool) -> Vec<u64> {
        let mut scored: Vec<(f64, u64, u64)> = self
            .tenants
            .iter()
            .filter(|(&b, _)| !skip(b))
            .map(|(&b, a)| (a.last_seen as f64 + a.ewma_gap, u64::MAX - a.last_seen, b))
            .collect();
        scored.sort_by(|x, y| {
            x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)).then(x.2.cmp(&y.2))
        });
        scored.into_iter().take(k).map(|(_, _, b)| b).collect()
    }

    /// Logical requests observed.
    pub fn observed(&self) -> u64 {
        self.clock
    }

    /// Distinct tenants seen.
    pub fn tenants(&self) -> usize {
        self.tenants.len()
    }
}

/// The result of parking a pre-simulated tenant: realized cycles plus the
/// wire-serialized final hierarchy state.
#[derive(Debug, Clone)]
pub struct WarmEntry {
    /// Realized accelerator cycles of the parked inference (the number
    /// the request path serves without re-simulating).
    pub cycles: u64,
    /// The final [`crate::mem::HierarchyCheckpoint`], serialized through
    /// [`crate::mem::wire`] — bounded storage, restorable by any
    /// compatible session.
    pub blob: Vec<u8>,
}

/// A parked program-batch simulation (see [`park_session`]).
#[derive(Debug, Clone)]
pub struct ParkedRun {
    /// Per-program supply cycles, in program order.
    pub supplies: Vec<u64>,
    /// Wire-encoded checkpoint of the hierarchy state after the final
    /// program.
    pub blob: Vec<u8>,
}

/// Run `progs` back-to-back on `session` and park the outcome: supply
/// cycles per program plus the final hierarchy state, wire-encoded. The
/// warm-session determinism guarantee makes the supplies bit-identical to
/// cold, per-program fresh simulations — `tests/serve.rs` asserts this
/// for every pattern family × level kind.
pub fn park_session(session: &mut Session, progs: &[PatternProgram]) -> Result<ParkedRun> {
    let last = progs
        .last()
        .ok_or_else(|| Error::Pattern("park_session: empty program batch".into()))?;
    let mut supplies = Vec::with_capacity(progs.len());
    for p in progs {
        supplies.push(session.run_program(p)?.stats.internal_cycles);
    }
    let ck = session.snapshot()?;
    let blob = encode_checkpoint(&ck, last)?;
    Ok(ParkedRun { supplies, blob })
}

/// Warm-store occupancy and traffic counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmStats {
    /// Entries inserted by the warmer.
    pub warmed: u64,
    /// Entries taken by the request path (warm hits).
    pub taken: u64,
    /// Entries evicted before use (wasted speculative work).
    pub evicted: u64,
    /// Inserts rejected because one blob exceeded the byte budget.
    pub oversize_rejects: u64,
}

/// Bounded store of speculatively warmed tenant state (see module docs).
#[derive(Debug)]
pub struct WarmStore {
    entries: BTreeMap<u64, WarmEntry>,
    lru: LruOrder<u64>,
    /// Entry-count bound (0 = unbounded).
    max_entries: usize,
    /// Byte budget over all blobs (0 = unbounded).
    max_bytes: usize,
    bytes: usize,
    /// Traffic counters.
    pub stats: WarmStats,
}

impl WarmStore {
    /// A store bounded to `max_entries` parked tenants and `max_bytes` of
    /// serialized state (`0` disables the respective bound).
    pub fn new(max_entries: usize, max_bytes: usize) -> Self {
        Self {
            entries: BTreeMap::new(),
            lru: LruOrder::new(),
            max_entries,
            max_bytes,
            bytes: 0,
            stats: WarmStats::default(),
        }
    }

    /// Park `entry` for `base`, evicting least-recently-warmed entries
    /// until both bounds hold. An entry whose blob alone exceeds the byte
    /// budget is rejected (counted in
    /// [`WarmStats::oversize_rejects`]).
    pub fn insert(&mut self, base: u64, entry: WarmEntry) {
        if self.max_bytes > 0 && entry.blob.len() > self.max_bytes {
            self.stats.oversize_rejects += 1;
            return;
        }
        if let Some(old) = self.entries.remove(&base) {
            self.bytes -= old.blob.len();
            self.lru.remove(&base);
        }
        self.bytes += entry.blob.len();
        self.entries.insert(base, entry);
        self.lru.touch(base);
        self.stats.warmed += 1;
        while (self.max_entries > 0 && self.entries.len() > self.max_entries)
            || (self.max_bytes > 0 && self.bytes > self.max_bytes)
        {
            let Some(oldest) = self.lru.pop_oldest() else { break };
            let evicted = self.entries.remove(&oldest).expect("lru tracks entries");
            self.bytes -= evicted.blob.len();
            self.stats.evicted += 1;
        }
    }

    /// Take the parked entry for `base` out of the store (a warm hit —
    /// the state moves to the request path's cycle cache).
    pub fn take(&mut self, base: u64) -> Option<WarmEntry> {
        let entry = self.entries.remove(&base)?;
        self.bytes -= entry.blob.len();
        self.lru.remove(&base);
        self.stats.taken += 1;
        Some(entry)
    }

    /// Whether `base` is parked.
    pub fn contains(&self, base: u64) -> bool {
        self.entries.contains_key(&base)
    }

    /// Parked tenant count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Entry-count bound (`0` = unbounded). The warmer tops the store up
    /// to this capacity and then idles instead of churning a full store.
    pub fn capacity(&self) -> usize {
        self.max_entries
    }

    /// True when nothing is parked.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialized bytes currently parked.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predictor_ranks_by_predicted_next_arrival() {
        // Tenant A every 2 requests, tenant B every 4: after a warm-up,
        // A's predicted next arrival is always sooner.
        let mut p = ArrivalPredictor::new(0.5);
        for i in 0..32u64 {
            p.observe(0xA000);
            if i % 2 == 0 {
                p.observe(0xB000);
            }
        }
        let next = p.candidates(2, |_| false);
        assert_eq!(next[0], 0xA000, "faster tenant predicted first: {next:?}");
        assert_eq!(next.len(), 2);
        // Skip filter excludes already-warm tenants.
        let next = p.candidates(2, |b| b == 0xA000);
        assert_eq!(next, vec![0xB000]);
        assert_eq!(p.tenants(), 2);
        assert!(p.predicted_next(0xA000).unwrap() < p.predicted_next(0xB000).unwrap());
        assert_eq!(p.predicted_next(0xC000), None);
    }

    #[test]
    fn predictor_is_deterministic() {
        let feed = |p: &mut ArrivalPredictor| {
            for i in 0..100u64 {
                p.observe((i * i) % 7);
            }
        };
        let (mut a, mut b) = (ArrivalPredictor::default(), ArrivalPredictor::default());
        feed(&mut a);
        feed(&mut b);
        assert_eq!(a.candidates(7, |_| false), b.candidates(7, |_| false));
        assert_eq!(a.observed(), 100);
    }

    #[test]
    fn warm_store_bounds_entries_and_bytes() {
        let blob = |n: usize| WarmEntry { cycles: n as u64, blob: vec![0u8; n] };
        let mut s = WarmStore::new(2, 0);
        s.insert(1, blob(10));
        s.insert(2, blob(10));
        s.insert(3, blob(10));
        assert_eq!(s.len(), 2);
        assert!(!s.contains(1), "oldest evicted");
        assert_eq!(s.stats.evicted, 1);
        assert_eq!(s.bytes(), 20);

        // Byte budget: inserting past it evicts oldest-first.
        let mut s = WarmStore::new(0, 25);
        s.insert(1, blob(10));
        s.insert(2, blob(10));
        s.insert(3, blob(10));
        assert_eq!(s.len(), 2);
        assert_eq!(s.bytes(), 20);
        assert!(s.contains(2) && s.contains(3));
        // A single oversize blob is rejected outright, store untouched.
        s.insert(4, blob(30));
        assert_eq!(s.stats.oversize_rejects, 1);
        assert!(!s.contains(4));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn warm_store_take_and_replace_account_bytes() {
        let blob = |n: usize| WarmEntry { cycles: 7, blob: vec![0u8; n] };
        let mut s = WarmStore::new(4, 100);
        s.insert(1, blob(10));
        assert_eq!(s.take(1).unwrap().blob.len(), 10);
        assert_eq!(s.bytes(), 0);
        assert!(s.take(1).is_none(), "taken entries are gone");
        assert!(s.is_empty());
        // Replacing an entry swaps its bytes, not accumulates.
        s.insert(2, blob(10));
        s.insert(2, blob(20));
        assert_eq!(s.bytes(), 20);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats.taken, 1);
    }
}

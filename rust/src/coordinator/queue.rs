//! Admission-controlled request queue: the serving tier's bounded waiting
//! room.
//!
//! Requests that cannot be admitted are **load-shed with a typed reject**
//! ([`ShedReason`]) instead of queueing unboundedly: the queue enforces a
//! global depth bound and a per-tenant fairness cap (no single
//! `weight_base` may occupy more than its share of the waiting room, so a
//! bursty tenant cannot starve the rest). Shedding is an admission-time
//! decision — once admitted, a request is always served.
//!
//! The queue also carries the timing the SLO-aware batcher needs: each
//! [`QueuedRequest`] remembers its arrival instant (queue-wait
//! accounting) and its deadline (arrival + SLO), and
//! [`AdmissionQueue::close_deadline`] exposes the batch-close instant
//! derived from the *oldest* queued request.

use super::kws::KwsRequest;
use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Why a request was load-shed at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The queue is at its global depth bound.
    QueueFull {
        /// The configured bound that was hit.
        depth: usize,
    },
    /// The request's tenant (`weight_base`) is at its fairness cap.
    TenantCap {
        /// The tenant at its cap.
        weight_base: u64,
        /// The configured per-tenant bound.
        cap: usize,
    },
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::QueueFull { depth } => write!(f, "queue full (depth {depth})"),
            ShedReason::TenantCap { weight_base, cap } => {
                write!(f, "tenant {weight_base:#x} at fairness cap {cap}")
            }
        }
    }
}

/// One admitted request with its queueing metadata.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    /// The request.
    pub req: KwsRequest,
    /// When the request entered the server (queue-wait epoch).
    pub arrival: Instant,
    /// Absolute completion deadline (arrival + SLO), if the request has
    /// one.
    pub deadline: Option<Instant>,
}

/// Bounded request queue with per-tenant fairness caps (see module docs).
#[derive(Debug)]
pub struct AdmissionQueue {
    q: VecDeque<QueuedRequest>,
    /// Queued requests per `weight_base` (entries removed at zero).
    per_tenant: BTreeMap<u64, usize>,
    /// Global depth bound (0 = unbounded).
    depth: usize,
    /// Per-tenant bound (0 = uncapped).
    tenant_cap: usize,
}

impl AdmissionQueue {
    /// A queue with the given bounds (`0` disables the respective bound).
    pub fn new(depth: usize, tenant_cap: usize) -> Self {
        Self { q: VecDeque::new(), per_tenant: BTreeMap::new(), depth, tenant_cap }
    }

    /// Admit a request, or shed it with a typed reason.
    pub fn try_push(&mut self, qr: QueuedRequest) -> Result<(), ShedReason> {
        if self.depth > 0 && self.q.len() >= self.depth {
            return Err(ShedReason::QueueFull { depth: self.depth });
        }
        let base = qr.req.weight_base;
        let tenant = self.per_tenant.entry(base).or_insert(0);
        if self.tenant_cap > 0 && *tenant >= self.tenant_cap {
            if *tenant == 0 {
                self.per_tenant.remove(&base);
            }
            return Err(ShedReason::TenantCap { weight_base: base, cap: self.tenant_cap });
        }
        *tenant += 1;
        self.q.push_back(qr);
        Ok(())
    }

    /// Dequeue up to `n` requests in arrival order.
    pub fn take(&mut self, n: usize) -> Vec<QueuedRequest> {
        let n = n.min(self.q.len());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let qr = self.q.pop_front().expect("len checked");
            match self.per_tenant.get_mut(&qr.req.weight_base) {
                Some(c) if *c > 1 => *c -= 1,
                _ => {
                    self.per_tenant.remove(&qr.req.weight_base);
                }
            }
            out.push(qr);
        }
        out
    }

    /// The instant at which a forming batch must close, derived from the
    /// oldest queued request: its deadline (SLO-aware close) or, absent
    /// one, its arrival plus `linger`. `None` when empty.
    pub fn close_deadline(&self, linger: Duration) -> Option<Instant> {
        let oldest = self.q.front()?;
        Some(oldest.deadline.unwrap_or(oldest.arrival + linger))
    }

    /// Queued request count.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::synth_request;

    fn queued(id: u64, base: u64) -> QueuedRequest {
        QueuedRequest {
            req: synth_request(id).with_weight_base(base),
            arrival: Instant::now(),
            deadline: None,
        }
    }

    #[test]
    fn depth_bound_sheds_with_typed_reason() {
        let mut q = AdmissionQueue::new(2, 0);
        assert!(q.try_push(queued(0, 0)).is_ok());
        assert!(q.try_push(queued(1, 0)).is_ok());
        assert_eq!(q.try_push(queued(2, 0)), Err(ShedReason::QueueFull { depth: 2 }));
        // Draining reopens admission.
        assert_eq!(q.take(1).len(), 1);
        assert!(q.try_push(queued(3, 0)).is_ok());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn tenant_cap_protects_other_tenants() {
        let mut q = AdmissionQueue::new(8, 2);
        assert!(q.try_push(queued(0, 0x1000)).is_ok());
        assert!(q.try_push(queued(1, 0x1000)).is_ok());
        // The greedy tenant is capped...
        assert_eq!(
            q.try_push(queued(2, 0x1000)),
            Err(ShedReason::TenantCap { weight_base: 0x1000, cap: 2 })
        );
        // ...while another tenant still gets in.
        assert!(q.try_push(queued(3, 0x2000)).is_ok());
        // Serving the greedy tenant's requests frees its budget.
        q.take(2);
        assert!(q.try_push(queued(4, 0x1000)).is_ok());
    }

    #[test]
    fn unbounded_queue_never_sheds() {
        let mut q = AdmissionQueue::new(0, 0);
        for i in 0..100 {
            assert!(q.try_push(queued(i, i % 3)).is_ok());
        }
        assert_eq!(q.len(), 100);
        // Arrival order is preserved through take().
        let ids: Vec<u64> = q.take(100).iter().map(|x| x.req.id).collect();
        assert_eq!(ids, (0..100).collect::<Vec<_>>());
        assert!(q.is_empty());
        assert!(q.per_tenant.is_empty(), "tenant accounting must drain to zero");
    }

    #[test]
    fn close_deadline_tracks_oldest() {
        let mut q = AdmissionQueue::new(0, 0);
        assert_eq!(q.close_deadline(Duration::from_millis(1)), None);
        let t0 = Instant::now();
        let mut a = queued(0, 0);
        a.arrival = t0;
        a.deadline = Some(t0 + Duration::from_millis(5));
        q.try_push(a).unwrap();
        let mut b = queued(1, 0);
        b.arrival = t0;
        b.deadline = Some(t0 + Duration::from_millis(50));
        q.try_push(b).unwrap();
        // The oldest request's deadline governs, not the newest.
        assert_eq!(q.close_deadline(Duration::ZERO), Some(t0 + Duration::from_millis(5)));
        q.take(1);
        assert_eq!(q.close_deadline(Duration::ZERO), Some(t0 + Duration::from_millis(50)));
        // Without a deadline, arrival + linger governs.
        let mut c = queued(2, 0);
        c.arrival = t0;
        q.q.clear();
        q.per_tenant.clear();
        q.try_push(c).unwrap();
        assert_eq!(
            q.close_deadline(Duration::from_millis(3)),
            Some(t0 + Duration::from_millis(3))
        );
    }
}

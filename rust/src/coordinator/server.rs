//! The serving tier: admission-controlled queue → speculative warmer →
//! SLO-aware batcher → executor.
//!
//! ```text
//!            requests                  ┌──────────────────────────────┐
//!   producer ────────► admission      │ warmer (2nd warm Session)     │
//!   thread             queue          │  EWMA arrival predictor       │
//!                      │  bounded     │  pre-simulates likely-next    │
//!                      │  typed sheds │  tenants, parks cycles +      │
//!                      ▼              │  wire-encoded checkpoints     │
//!                SLO-aware batcher    └──────────────┬───────────────┘
//!                (max_batch | oldest                 │ WarmStore
//!                 deadline | drain)                  ▼ (bounded bytes)
//!                      │            cycle cache → warm store → cold sim
//!                      ▼                 │             │          │
//!                  executor ◄────────────┴─────────────┴──────────┘
//!                  (warm Session co-sim + host inference)
//! ```
//!
//! **Admission** (`coordinator::queue`): the waiting room is bounded
//! (global depth + per-tenant fairness cap); overload load-sheds with a
//! typed [`ShedReason`] instead of queueing unboundedly.
//!
//! **Speculative warming** (`coordinator::warm`): a per-`weight_base`
//! arrival predictor (EWMA of logical inter-arrival gaps + recency)
//! drives a warmer that pre-simulates likely-next tenants on a **second
//! warm [`Session`]** and parks the realized cycles plus the final
//! hierarchy state (wire-encoded via [`crate::mem::wire`], byte-bounded)
//! in a [`WarmStore`]. The request path resolves a tenant's cycles as
//! cycle-cache hit → warm-store hit → cold co-simulation; only the last
//! pays simulation time on the request path.
//! [`WarmingMode::Background`] runs the warmer on its own thread (the
//! production shape); [`WarmingMode::Synchronous`] runs one warming step
//! between batches on the caller's thread, which makes warming decisions
//! — and therefore every counter in [`CoordinatorStats`] — deterministic
//! under a seeded request trace.
//!
//! **SLO-aware batching**: a forming batch closes on whichever fires
//! first of `max_batch` reached, the **oldest** queued request's deadline
//! (arrival + SLO; `max_linger` when the request has no SLO), or queue
//! drain (the producer disconnected). Completions past their deadline
//! increment `deadline_miss`.
//!
//! **Determinism contract**: warming is a latency optimization, never a
//! semantic one. Cycle counts served from speculatively warmed state are
//! bit-identical to cold co-simulation (warm-vs-cold session
//! determinism; asserted per pattern family × level kind in
//! `tests/serve.rs`), so enabling or disabling warming can never change
//! a served `accel_cycles` value — only its latency.

use super::kws::{KwsRequest, KwsResult, MFCC_BINS, MFCC_FRAMES, N_CLASSES};
use super::queue::{AdmissionQueue, QueuedRequest, ShedReason};
use super::traffic::TracedRequest;
use super::warm::{park_session, ArrivalPredictor, WarmEntry, WarmStats, WarmStore};
use crate::accel::UltraTrail;
use crate::config::HierarchyConfig;
use crate::pattern::PatternProgram;
use crate::runtime::{LoadedModel, Runtime};
use crate::sim::batch::Session;
use crate::util::{LruOrder, StreamingHistogram};
use crate::{Error, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How the speculative warmer runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarmingMode {
    /// No warming: every cycle-cache miss cold-simulates on the request
    /// path.
    Off,
    /// One warming step runs on the serving thread after each batch.
    /// Slower than `Background` but fully deterministic — warming
    /// decisions depend only on the admitted request sequence.
    Synchronous,
    /// A dedicated warmer thread with its own warm session fills the
    /// store while batches drain (the production configuration).
    Background,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Co-simulate the UltraTrail weight stream per inference (adds the
    /// accelerator cycle count to each result).
    pub cosim_weights: bool,
    /// Use preloading in the co-simulated hierarchy.
    pub preload: bool,
    /// Maximum distinct `weight_base` entries the co-simulation cycle
    /// cache retains (least-recently-used entries are evicted beyond
    /// this; `0` = unbounded). Multi-tenant serving sees one entry per
    /// tenant model, so this bounds the server's per-tenant memory.
    pub max_cached_bases: usize,
    /// Admission queue depth bound (`0` = unbounded). Arrivals beyond it
    /// are shed with [`ShedReason::QueueFull`].
    pub queue_depth: usize,
    /// Per-tenant fairness cap on queued requests (`0` = uncapped).
    /// Arrivals beyond it are shed with [`ShedReason::TenantCap`].
    pub tenant_cap: usize,
    /// SLO applied to requests that carry none of their own.
    pub default_slo: Option<Duration>,
    /// How long the batcher lingers for more requests when the oldest
    /// queued request has no deadline. `ZERO` = close as soon as the
    /// channel is momentarily empty (the pre-SLO behavior).
    pub max_linger: Duration,
    /// Speculative warming mode.
    pub warming: WarmingMode,
    /// Warm-store capacity in parked tenants (`0` = unbounded).
    pub warm_capacity: usize,
    /// Warm-store byte budget over serialized checkpoints (`0` =
    /// unbounded).
    pub warm_bytes: usize,
    /// Tenants warmed per warmer pass.
    pub warm_ahead: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            cosim_weights: true,
            preload: true,
            max_cached_bases: 64,
            queue_depth: 1024,
            tenant_cap: 0,
            default_slo: None,
            max_linger: Duration::ZERO,
            warming: WarmingMode::Off,
            warm_capacity: 16,
            warm_bytes: 1 << 20,
            warm_ahead: 2,
        }
    }
}

/// Per-tenant serving counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TenantStats {
    /// Requests served.
    pub served: u64,
    /// Cycle-cache hits.
    pub cache_hits: u64,
    /// Warm-store hits (speculatively pre-simulated).
    pub warm_hits: u64,
    /// Cold co-simulations on the request path.
    pub cold_sims: u64,
    /// Requests shed at admission.
    pub shed: u64,
    /// Completions past their deadline.
    pub deadline_miss: u64,
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct CoordinatorStats {
    /// Requests served.
    pub served: u64,
    /// Batches executed.
    pub batches: u64,
    /// Requests shed at admission (total).
    pub shed: u64,
    /// Sheds due to the global queue bound.
    pub shed_queue_full: u64,
    /// Sheds due to a per-tenant fairness cap.
    pub shed_tenant_cap: u64,
    /// Completions past their deadline (arrival + SLO).
    pub deadline_miss: u64,
    /// Total host wall time across batches.
    pub host_time: Duration,
    /// Mean simulated accelerator cycles per inference.
    pub mean_accel_cycles: f64,
    /// Cycle-cache hits across all tenants.
    pub cache_hits: u64,
    /// Warm-store hits across all tenants.
    pub warm_hits: u64,
    /// Request-path cold co-simulations across all tenants.
    pub cold_sims: u64,
    /// Warm-store hits whose parked checkpoint blob failed to decode and
    /// therefore degraded to a cold co-simulation (a corrupt entry is
    /// discarded, never served).
    pub warm_decode_fallbacks: u64,
    /// Queue-wait distribution (nanoseconds): admission to service start.
    pub queue_wait: StreamingHistogram,
    /// Service-time distribution (nanoseconds): per-request co-sim +
    /// host inference, *excluding* wait behind batch predecessors.
    pub service: StreamingHistogram,
    /// Served accelerator-cycle distribution (deterministic for a given
    /// request sequence, warming on or off).
    pub accel_cycles: StreamingHistogram,
    /// Per-tenant (`weight_base`) counters.
    pub tenants: BTreeMap<u64, TenantStats>,
}

/// Where a request's accelerator cycles came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CycleSource {
    CacheHit,
    WarmHit,
    ColdSim,
}

/// The per-model co-simulation parameters, shared between the request
/// path and the warmer (each holds its own warm [`Session`]).
#[derive(Debug, Clone)]
struct CosimModel {
    /// Base-0 per-layer weight-supply programs.
    programs: Vec<PatternProgram>,
    /// Per-layer ideal MAC-array steps (the compute side of
    /// `max(steps, supply)`).
    steps: Vec<u64>,
    /// Largest per-layer weight stream in off-chip units (address-space
    /// bound for `weight_base` validation).
    max_layer_units: u64,
    /// Exclusive upper bound of the co-simulated off-chip address space.
    addr_limit: u64,
    /// The hierarchy configuration sessions are opened under.
    cfg: HierarchyConfig,
}

impl CosimModel {
    fn new(preload: bool) -> Self {
        let ut = UltraTrail::default();
        let cfg = ut.hierarchy_wmem_config(preload);
        let programs = ut.layers.iter().map(|l| ut.layer_program(l)).collect();
        let steps = ut.layers.iter().map(|l| ut.steps(l)).collect();
        let max_layer_units = ut.layers.iter().map(|l| ut.weight_units(l)).max().unwrap_or(0);
        let addr_limit = 1u64 << cfg.offchip.addr_width.min(48);
        Self { programs, steps, max_layer_units, addr_limit, cfg }
    }

    /// Reject bases whose weight stream would leave the co-simulated
    /// off-chip address space.
    fn check_base(&self, base: u64) -> Result<()> {
        match base.checked_add(self.max_layer_units) {
            Some(end) if end <= self.addr_limit => Ok(()),
            _ => Err(Error::Pattern(format!(
                "weight_base {base:#x} leaves no room for a {}-unit weight stream \
                 in the {:#x}-word off-chip address space",
                self.max_layer_units, self.addr_limit
            ))),
        }
    }

    /// The per-layer programs with their weight stream based at `base`.
    fn based_programs(&self, base: u64) -> Vec<PatternProgram> {
        self.programs
            .iter()
            .map(|p0| {
                let mut p = p0.clone();
                p.start_address = base;
                p
            })
            .collect()
    }

    /// Realized inference cycles from per-layer supply cycles.
    fn realized(&self, supplies: &[u64]) -> u64 {
        self.steps.iter().zip(supplies.iter()).map(|(&s, &u)| s.max(u)).sum()
    }

    /// Cold-path cycles: stream every layer through `session` at `base`.
    fn simulate_cycles(&self, session: &mut Session, base: u64) -> Result<u64> {
        let mut total = 0u64;
        for (i, p0) in self.programs.iter().enumerate() {
            let mut p = p0.clone();
            p.start_address = base;
            let supply = session.run_program(&p)?.stats.internal_cycles;
            total += self.steps[i].max(supply);
        }
        Ok(total)
    }

    /// Warmer-path simulation: same cycles as
    /// [`Self::simulate_cycles`] (warm-session determinism), plus the
    /// final hierarchy state parked as a wire-encoded checkpoint.
    fn simulate_parked(&self, session: &mut Session, base: u64) -> Result<WarmEntry> {
        let parked = park_session(session, &self.based_programs(base))?;
        Ok(WarmEntry { cycles: self.realized(&parked.supplies), blob: parked.blob })
    }
}

/// The request-path weight-stream co-simulation: one warm session plus a
/// **bounded** LRU cache of realized inference cycle counts per weight
/// base address (one entry per tenant; see
/// [`ServerConfig::max_cached_bases`]). Eviction is O(log n) via an
/// explicit [`LruOrder`] — a tenant churn burst costs O(n log n), not
/// O(n²).
struct WeightCosim {
    model: CosimModel,
    session: Session,
    /// Realized cycles of one inference per weight base address.
    cycles_by_base: BTreeMap<u64, u64>,
    /// Recency order over `cycles_by_base` keys.
    lru: LruOrder<u64>,
    /// Cache capacity (0 = unbounded).
    max_cached_bases: usize,
}

impl WeightCosim {
    fn new(preload: bool, max_cached_bases: usize) -> Result<Self> {
        let model = CosimModel::new(preload);
        let session = Session::new(&model.cfg)?;
        Ok(Self {
            model,
            session,
            cycles_by_base: BTreeMap::new(),
            lru: LruOrder::new(),
            max_cached_bases,
        })
    }

    /// Cached cycles for `base`, refreshing its recency.
    fn cached(&mut self, base: u64) -> Option<u64> {
        let c = self.cycles_by_base.get(&base).copied()?;
        self.lru.touch(base);
        Some(c)
    }

    /// Insert `cycles` for `base` and evict past the bound; returns the
    /// evicted bases (so the warmer's view of the cache stays current).
    fn insert(&mut self, base: u64, cycles: u64) -> Vec<u64> {
        self.cycles_by_base.insert(base, cycles);
        self.lru.touch(base);
        self.evict_lru()
    }

    /// Drop least-recently-used entries until the cache fits its bound.
    /// O(log n) per eviction (see [`LruOrder`]).
    fn evict_lru(&mut self) -> Vec<u64> {
        let mut evicted = Vec::new();
        if self.max_cached_bases == 0 {
            return evicted;
        }
        while self.cycles_by_base.len() > self.max_cached_bases {
            let oldest = self.lru.pop_oldest().expect("cache non-empty");
            self.cycles_by_base.remove(&oldest);
            evicted.push(oldest);
        }
        evicted
    }

    /// Realized cycles of one inference whose weights sit at `base`:
    /// served from cache, else streamed once through the warm session
    /// (all layers back-to-back on one hierarchy) and cached. At base 0
    /// this equals [`UltraTrail::case_study`]'s `realized_cycles` —
    /// warm-vs-cold determinism guarantees it (and makes eviction purely
    /// a performance event: a re-simulated base yields the same count).
    fn realized_cycles(&mut self, base: u64) -> Result<u64> {
        self.model.check_base(base)?;
        if let Some(c) = self.cached(base) {
            return Ok(c);
        }
        let c = self.model.simulate_cycles(&mut self.session, base)?;
        self.insert(base, c);
        Ok(c)
    }
}

/// State shared between the serving thread and the warmer.
struct WarmShared {
    store: WarmStore,
    predictor: ArrivalPredictor,
    /// Bases currently resident in the request path's cycle cache (the
    /// warmer skips these — their cycles are already a cache hit).
    cached: BTreeSet<u64>,
    shutdown: bool,
}

type SharedWarm = Arc<(Mutex<WarmShared>, Condvar)>;

/// The speculative warmer: shared store/predictor plus either a
/// background thread or a synchronous second session.
struct Warmer {
    shared: SharedWarm,
    /// Background-mode thread handle.
    thread: Option<std::thread::JoinHandle<()>>,
    /// Synchronous-mode second warm session (Background keeps its
    /// session inside the thread).
    sync_session: Option<Session>,
    model: CosimModel,
    ahead: usize,
}

impl Warmer {
    fn new(cfg: &ServerConfig, model: CosimModel) -> Result<Self> {
        let shared: SharedWarm = Arc::new((
            Mutex::new(WarmShared {
                store: WarmStore::new(cfg.warm_capacity, cfg.warm_bytes),
                predictor: ArrivalPredictor::default(),
                cached: BTreeSet::new(),
                shutdown: false,
            }),
            Condvar::new(),
        ));
        let (thread, sync_session) = match cfg.warming {
            WarmingMode::Background => {
                let (m, s, ahead) = (model.clone(), Arc::clone(&shared), cfg.warm_ahead.max(1));
                (Some(std::thread::spawn(move || warmer_thread(m, s, ahead))), None)
            }
            WarmingMode::Synchronous => (None, Some(Session::new(&model.cfg)?)),
            WarmingMode::Off => unreachable!("Warmer is only built when warming is on"),
        };
        Ok(Self { shared, thread, sync_session, model, ahead: cfg.warm_ahead.max(1) })
    }

    /// Tenants worth warming now: predicted-next order, skipping parked
    /// and cache-resident bases, bounded by the store's free capacity
    /// (the warmer tops the store up; it never churns a full store).
    fn pick(shared: &WarmShared, ahead: usize) -> Vec<u64> {
        let free = match shared.store.capacity() {
            0 => ahead,
            cap => cap.saturating_sub(shared.store.len()).min(ahead),
        };
        if free == 0 {
            return Vec::new();
        }
        shared
            .predictor
            .candidates(free, |b| shared.store.contains(b) || shared.cached.contains(&b))
    }
}

impl Drop for Warmer {
    fn drop(&mut self) {
        if let Some(h) = self.thread.take() {
            let (lock, cvar) = &*self.shared;
            if let Ok(mut s) = lock.lock() {
                s.shutdown = true;
            }
            cvar.notify_all();
            let _ = h.join();
        }
    }
}

/// Background warmer loop: wait for demand, pre-simulate predicted-next
/// tenants on a thread-local warm session, park the results.
fn warmer_thread(model: CosimModel, shared: SharedWarm, ahead: usize) {
    let Ok(mut session) = Session::new(&model.cfg) else { return };
    let (lock, cvar) = &*shared;
    loop {
        let todo = {
            let Ok(mut s) = lock.lock() else { return };
            loop {
                if s.shutdown {
                    return;
                }
                let picks = Warmer::pick(&s, ahead);
                if !picks.is_empty() {
                    break picks;
                }
                let Ok((guard, _)) = cvar.wait_timeout(s, Duration::from_millis(1)) else {
                    return;
                };
                s = guard;
            }
        };
        for base in todo {
            if model.check_base(base).is_err() {
                continue;
            }
            // Simulate outside the lock — the serving thread must never
            // wait on warming work.
            let Ok(entry) = model.simulate_parked(&mut session, base) else { continue };
            let Ok(mut s) = lock.lock() else { return };
            if s.shutdown {
                return;
            }
            if !s.cached.contains(&base) {
                s.store.insert(base, entry);
            }
        }
    }
}

/// How host inference runs.
enum HostBackend {
    /// The PJRT runtime executing a compiled artifact.
    Pjrt {
        runtime: Runtime,
        model: LoadedModel,
    },
    /// No runtime: a deterministic band-energy classifier stands in for
    /// the compiled model, so the serving tier (whose contribution is
    /// the memory-hierarchy co-simulation) runs end-to-end in the
    /// offline build. See [`KwsServer::sim_only`].
    SimOnly,
}

impl HostBackend {
    fn infer(&self, features: &[f32]) -> Result<Vec<f32>> {
        match self {
            HostBackend::Pjrt { runtime, model } => {
                let shape = vec![1i64, MFCC_BINS as i64, MFCC_FRAMES as i64];
                let outs = runtime.run_f32(model, &[(features.to_vec(), shape)])?;
                Ok(outs.into_iter().next().unwrap_or_default())
            }
            HostBackend::SimOnly => Ok(band_energy_logits(features)),
        }
    }
}

/// Deterministic stand-in classifier: mean per-bin energy, pooled over
/// each class's spectral band (mirroring `synth_request`'s
/// class-dependent envelope). Cheap, allocation-light, reproducible.
fn band_energy_logits(features: &[f32]) -> Vec<f32> {
    let mut bin_energy = [0f32; MFCC_BINS];
    for (b, e) in bin_energy.iter_mut().enumerate() {
        let row = &features[b * MFCC_FRAMES..(b + 1) * MFCC_FRAMES];
        *e = row.iter().map(|v| v.abs()).sum::<f32>() / MFCC_FRAMES as f32;
    }
    (0..N_CLASSES)
        .map(|c| {
            let peak = c * MFCC_BINS / N_CLASSES;
            let lo = peak.saturating_sub(1);
            let hi = (peak + 2).min(MFCC_BINS);
            bin_energy[lo..hi].iter().sum::<f32>() / (hi - lo) as f32
        })
        .collect()
}

/// The KWS server: owns the host backend, the request-path co-simulation,
/// and (optionally) the speculative warmer.
pub struct KwsServer {
    host: HostBackend,
    cfg: ServerConfig,
    /// Warm request-path weight-stream co-simulation (None = disabled).
    cosim: Option<WeightCosim>,
    /// Speculative warming state (None when off or co-sim disabled).
    warmer: Option<Warmer>,
    /// Sum/count of co-simulated cycles over all served requests.
    accel_sum: f64,
    accel_served: u64,
    /// Monotonic batch sequence number.
    batch_seq: u64,
    stats: CoordinatorStats,
}

impl KwsServer {
    /// Load the model artifact and prepare the server. Start-up does not
    /// pre-compute cycle counts: the co-simulation session is opened warm
    /// and individual tenants are simulated (or speculatively warmed) on
    /// demand.
    pub fn new(artifact: &std::path::Path, cfg: ServerConfig) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let model = runtime.load_hlo_text(artifact)?;
        Self::build(HostBackend::Pjrt { runtime, model }, cfg)
    }

    /// A server without the PJRT runtime: host inference uses a
    /// deterministic band-energy stand-in, while the co-simulation tier —
    /// the part this crate models — is identical to [`KwsServer::new`].
    /// This is what the serving tests, benches, and the `serve` CLI use
    /// in the offline build.
    pub fn sim_only(cfg: ServerConfig) -> Result<Self> {
        Self::build(HostBackend::SimOnly, cfg)
    }

    fn build(host: HostBackend, cfg: ServerConfig) -> Result<Self> {
        let cosim = if cfg.cosim_weights {
            Some(WeightCosim::new(cfg.preload, cfg.max_cached_bases)?)
        } else {
            None
        };
        let warmer = match (&cosim, cfg.warming) {
            (Some(c), WarmingMode::Synchronous | WarmingMode::Background) => {
                Some(Warmer::new(&cfg, c.model.clone())?)
            }
            _ => None,
        };
        Ok(Self {
            host,
            cfg,
            cosim,
            warmer,
            accel_sum: 0.0,
            accel_served: 0,
            batch_seq: 0,
            stats: CoordinatorStats::default(),
        })
    }

    /// Serve one batch synchronously. Per-request `host_latency` is each
    /// request's own service time; `queue_wait` carries the in-batch
    /// wait behind earlier requests. An empty batch is a no-op, not a
    /// panic.
    pub fn serve_batch(&mut self, requests: &[KwsRequest]) -> Result<Vec<KwsResult>> {
        if requests.is_empty() {
            return Ok(Vec::new());
        }
        let now = Instant::now();
        let batch: Vec<QueuedRequest> = requests
            .iter()
            .map(|r| QueuedRequest {
                deadline: r.slo.or(self.cfg.default_slo).map(|s| now + s),
                req: r.clone(),
                arrival: now,
            })
            .collect();
        for q in &batch {
            self.observe_arrival(q.req.weight_base);
        }
        self.execute_batch(batch)
    }

    /// Run a request stream through the serving loop (producer thread →
    /// admission queue → SLO-aware batcher → executor). Shed requests
    /// produce no result; they are counted in [`CoordinatorStats`].
    pub fn serve_stream(&mut self, requests: Vec<KwsRequest>) -> Result<Vec<KwsResult>> {
        self.serve_timed(requests.into_iter().map(|r| (Duration::ZERO, r)).collect())
    }

    /// Replay a timed trace: each request is submitted at its `at` offset
    /// from replay start (the synthetic-traffic benchmark's entry point).
    pub fn serve_trace(&mut self, trace: Vec<TracedRequest>) -> Result<Vec<KwsResult>> {
        self.serve_timed(trace.into_iter().map(|t| (t.at, t.req)).collect())
    }

    /// The serving loop shared by [`Self::serve_stream`] and
    /// [`Self::serve_trace`].
    fn serve_timed(&mut self, trace: Vec<(Duration, KwsRequest)>) -> Result<Vec<KwsResult>> {
        let (tx, rx) = mpsc::channel::<(KwsRequest, Instant)>();
        let producer = std::thread::spawn(move || {
            let origin = Instant::now();
            for (at, r) in trace {
                let target = origin + at;
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                if tx.send((r, Instant::now())).is_err() {
                    break;
                }
            }
        });
        let mut queue = AdmissionQueue::new(self.cfg.queue_depth, self.cfg.tenant_cap);
        let mut results = Vec::new();
        let mut open = true;
        let mut serve_err = None;
        loop {
            // Drain everything immediately available through admission.
            loop {
                match rx.try_recv() {
                    Ok((r, at)) => self.admit(&mut queue, r, at),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            if queue.is_empty() {
                if !open {
                    break;
                }
                // Idle: block for the next arrival, then re-drain.
                match rx.recv() {
                    Ok((r, at)) => self.admit(&mut queue, r, at),
                    Err(_) => open = false,
                }
                continue;
            }
            // Batch formation: fill until max_batch, the oldest request's
            // deadline, or queue drain — whichever fires first.
            while open && queue.len() < self.cfg.max_batch {
                let deadline =
                    queue.close_deadline(self.cfg.max_linger).expect("queue checked non-empty");
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok((r, at)) => self.admit(&mut queue, r, at),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
            let batch = queue.take(self.cfg.max_batch);
            match self.execute_batch(batch) {
                Ok(rs) => results.extend(rs),
                Err(e) => {
                    serve_err = Some(e);
                    break;
                }
            }
        }
        drop(rx);
        let joined = producer.join();
        if let Some(e) = serve_err {
            return Err(e);
        }
        joined.map_err(|_| Error::Runtime("request producer thread panicked".into()))?;
        Ok(results)
    }

    /// Admission: observe the arrival (predictor + warmer wake-up), then
    /// queue or shed.
    fn admit(&mut self, queue: &mut AdmissionQueue, req: KwsRequest, arrival: Instant) {
        let base = req.weight_base;
        self.observe_arrival(base);
        let deadline = req.slo.or(self.cfg.default_slo).map(|s| arrival + s);
        if let Err(reason) = queue.try_push(QueuedRequest { req, arrival, deadline }) {
            self.stats.shed += 1;
            match reason {
                ShedReason::QueueFull { .. } => self.stats.shed_queue_full += 1,
                ShedReason::TenantCap { .. } => self.stats.shed_tenant_cap += 1,
            }
            self.stats.tenants.entry(base).or_default().shed += 1;
        }
    }

    /// Feed the arrival predictor and wake the background warmer.
    fn observe_arrival(&mut self, base: u64) {
        if let Some(w) = &self.warmer {
            let (lock, cvar) = &*w.shared;
            if let Ok(mut s) = lock.lock() {
                s.predictor.observe(base);
            }
            cvar.notify_one();
        }
    }

    /// Execute one formed batch: per-request co-sim (cache → warm store →
    /// cold) + host inference, with per-request latency accounting.
    fn execute_batch(&mut self, batch: Vec<QueuedRequest>) -> Result<Vec<KwsResult>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        let t_batch = Instant::now();
        self.batch_seq += 1;
        let mut out = Vec::with_capacity(batch.len());
        for q in &batch {
            let t0 = Instant::now();
            let queue_wait = t0.duration_since(q.arrival);
            let accel = self.accel_cycles(q.req.weight_base)?;
            let logits = self.host.infer(&q.req.features)?;
            let class = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            // Satellite fix: service time is *this* request's own work,
            // measured from its service start — not from batch start.
            let service = t0.elapsed();
            let deadline_missed = q.deadline.is_some_and(|d| Instant::now() > d);
            self.stats.served += 1;
            self.stats.queue_wait.record_duration(queue_wait);
            self.stats.service.record_duration(service);
            if deadline_missed {
                self.stats.deadline_miss += 1;
            }
            let tenant = self.stats.tenants.entry(q.req.weight_base).or_default();
            tenant.served += 1;
            if deadline_missed {
                tenant.deadline_miss += 1;
            }
            if let Some((c, source)) = accel {
                self.stats.accel_cycles.record(c);
                self.accel_sum += c as f64;
                self.accel_served += 1;
                let tenant = self.stats.tenants.entry(q.req.weight_base).or_default();
                match source {
                    CycleSource::CacheHit => {
                        self.stats.cache_hits += 1;
                        tenant.cache_hits += 1;
                    }
                    CycleSource::WarmHit => {
                        self.stats.warm_hits += 1;
                        tenant.warm_hits += 1;
                    }
                    CycleSource::ColdSim => {
                        self.stats.cold_sims += 1;
                        tenant.cold_sims += 1;
                    }
                }
            }
            out.push(KwsResult {
                id: q.req.id,
                logits,
                class,
                accel_cycles: accel.map(|(c, _)| c),
                host_latency: service,
                queue_wait,
                batch_seq: self.batch_seq,
                deadline_missed,
            });
        }
        self.stats.batches += 1;
        self.stats.host_time += t_batch.elapsed();
        if self.accel_served > 0 {
            self.stats.mean_accel_cycles = self.accel_sum / self.accel_served as f64;
        }
        self.warm_step_sync();
        Ok(out)
    }

    /// Resolve a tenant's accelerator cycles: cycle cache → warm store →
    /// cold co-simulation. All three sources yield bit-identical counts
    /// (warm-vs-cold determinism); they differ only in request-path
    /// latency.
    fn accel_cycles(&mut self, base: u64) -> Result<Option<(u64, CycleSource)>> {
        let Some(cosim) = self.cosim.as_mut() else { return Ok(None) };
        cosim.model.check_base(base)?;
        if let Some(c) = cosim.cached(base) {
            return Ok(Some((c, CycleSource::CacheHit)));
        }
        if let Some(w) = &self.warmer {
            let taken = {
                let (lock, _) = &*w.shared;
                lock.lock().ok().and_then(|mut s| s.store.take(base))
            };
            if let Some(entry) = taken {
                // A parked entry is trusted only after its checkpoint
                // blob round-trips the wire decode: a corrupt or
                // truncated blob (torn store, serialization bug) means
                // the entry's provenance can no longer be audited, so it
                // is discarded and the request degrades to a cold
                // co-simulation instead of erroring.
                if crate::mem::wire::decode_checkpoint(&entry.blob).is_ok() {
                    let evicted = cosim.insert(base, entry.cycles);
                    Self::publish_cache_update(&self.warmer, base, &evicted);
                    return Ok(Some((entry.cycles, CycleSource::WarmHit)));
                }
                self.stats.warm_decode_fallbacks += 1;
            }
        }
        let c = cosim.model.simulate_cycles(&mut cosim.session, base)?;
        let evicted = cosim.insert(base, c);
        Self::publish_cache_update(&self.warmer, base, &evicted);
        Ok(Some((c, CycleSource::ColdSim)))
    }

    /// Keep the warmer's view of cycle-cache residency current (so it
    /// never wastes speculative work on an already-cached tenant) and
    /// wake it — an eviction is fresh warming demand.
    fn publish_cache_update(warmer: &Option<Warmer>, added: u64, evicted: &[u64]) {
        let Some(w) = warmer else { return };
        let (lock, cvar) = &*w.shared;
        if let Ok(mut s) = lock.lock() {
            s.cached.insert(added);
            for b in evicted {
                s.cached.remove(b);
            }
        }
        cvar.notify_one();
    }

    /// Synchronous-mode warming: one pass on the serving thread, after a
    /// batch. (Background mode warms continuously on its own thread.)
    fn warm_step_sync(&mut self) {
        let Some(w) = self.warmer.as_mut() else { return };
        let Some(session) = w.sync_session.as_mut() else { return };
        let (lock, _) = &*w.shared;
        let todo = match lock.lock() {
            Ok(s) => Warmer::pick(&s, w.ahead),
            Err(_) => return,
        };
        for base in todo {
            if w.model.check_base(base).is_err() {
                continue;
            }
            let Ok(entry) = w.model.simulate_parked(session, base) else { continue };
            if let Ok(mut s) = lock.lock() {
                if !s.cached.contains(&base) {
                    s.store.insert(base, entry);
                }
            }
        }
    }

    /// Serving statistics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }

    /// Warm-store counters (None when warming is off).
    pub fn warm_stats(&self) -> Option<WarmStats> {
        let w = self.warmer.as_ref()?;
        let (lock, _) = &*w.shared;
        lock.lock().ok().map(|s| s.store.stats)
    }

    /// Currently parked warm tenants (None when warming is off).
    pub fn warm_parked(&self) -> Option<usize> {
        let w = self.warmer.as_ref()?;
        let (lock, _) = &*w.shared;
        lock.lock().ok().map(|s| s.store.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_cosim_matches_case_study_and_caches() {
        // The per-batch warm co-simulation must reproduce the one-shot
        // case-study cycle count exactly (warm-vs-cold determinism), and
        // cache per weight base.
        let mut cosim = WeightCosim::new(true, 64).unwrap();
        let a = cosim.realized_cycles(0).unwrap();
        let cs = UltraTrail::default().case_study(true).unwrap();
        assert_eq!(a, cs.realized_cycles, "warm cosim diverged from the case study");
        assert_eq!(cosim.realized_cycles(0).unwrap(), a);
        assert_eq!(cosim.cycles_by_base.len(), 1, "repeat patterns must hit the cache");
        // A different weight base is a different access pattern on the
        // same warm session; a pure sequential supply is base-invariant
        // in cycles, so the count matches while being cached separately.
        let b = cosim.realized_cycles(1 << 20).unwrap();
        assert_eq!(b, a);
        assert_eq!(cosim.cycles_by_base.len(), 2);
    }

    #[test]
    fn out_of_space_weight_base_rejected() {
        // A base whose stream would exceed the 24-bit address space must
        // error instead of simulating nonexistent addresses.
        let mut cosim = WeightCosim::new(false, 64).unwrap();
        assert!(cosim.realized_cycles(u64::MAX).is_err());
        assert!(cosim.realized_cycles(1 << 24).is_err());
        assert!(cosim.cycles_by_base.is_empty(), "rejected bases must not be cached");
        // The boundary case that still fits is accepted.
        let fitting = (1u64 << 24) - cosim.model.max_layer_units;
        assert!(cosim.realized_cycles(fitting).is_ok());
    }

    #[test]
    fn cosim_cache_evicts_least_recently_used() {
        let mut cosim = WeightCosim::new(false, 2).unwrap();
        let a = cosim.realized_cycles(0).unwrap();
        cosim.realized_cycles(1 << 16).unwrap();
        // Touch base 0 so base 1<<16 becomes the LRU entry, then insert a
        // third base: the bound holds and the LRU entry is the one gone.
        cosim.realized_cycles(0).unwrap();
        let evicted = {
            cosim.realized_cycles(1 << 17).unwrap();
            cosim.cycles_by_base.len()
        };
        assert_eq!(evicted, 2, "cache must stay within its bound");
        assert!(cosim.cycles_by_base.contains_key(&0), "recently used entry survives");
        assert!(cosim.cycles_by_base.contains_key(&(1 << 17)), "newest entry survives");
        assert!(
            !cosim.cycles_by_base.contains_key(&(1 << 16)),
            "least-recently-used entry is evicted"
        );
        // An evicted base re-simulates to the same count (determinism).
        assert_eq!(cosim.realized_cycles(1 << 16).unwrap(), a);
        assert_eq!(cosim.cycles_by_base.len(), 2);
        // The LRU index never desynchronizes from the cache map.
        assert_eq!(cosim.lru.len(), cosim.cycles_by_base.len());
        // Unbounded mode never evicts.
        let mut unbounded = WeightCosim::new(false, 0).unwrap();
        for base in [0u64, 1 << 16, 1 << 17, 1 << 18] {
            unbounded.realized_cycles(base).unwrap();
        }
        assert_eq!(unbounded.cycles_by_base.len(), 4);
    }

    #[test]
    fn corrupt_warm_blob_degrades_to_cold_sim() {
        // A poisoned warm-store entry (plausible cycles, undecodable
        // checkpoint blob) must never be served: the request falls back
        // to a cold co-simulation, the fallback is counted, and the real
        // cycle count is what gets cached.
        let mut server = KwsServer::sim_only(ServerConfig {
            warming: WarmingMode::Synchronous,
            ..ServerConfig::default()
        })
        .unwrap();
        {
            let w = server.warmer.as_ref().expect("synchronous warming keeps a warmer");
            let (lock, _) = &*w.shared;
            lock.lock().unwrap().store.insert(0, WarmEntry { cycles: 123, blob: vec![0xFF; 16] });
        }
        let (cycles, source) = server.accel_cycles(0).unwrap().unwrap();
        assert_eq!(source, CycleSource::ColdSim, "corrupt warm entry must not be served");
        assert_ne!(cycles, 123, "poisoned cycle count must not leak");
        assert_eq!(server.stats.warm_decode_fallbacks, 1);
        // The corrupt entry was discarded and the cold result cached.
        let (again, source2) = server.accel_cycles(0).unwrap().unwrap();
        assert_eq!(again, cycles);
        assert_eq!(source2, CycleSource::CacheHit);
    }

    #[test]
    fn warmed_entry_cycles_match_cold_simulation() {
        // The warmer's parked cycles must be bit-identical to the request
        // path's cold simulation for the same base (the determinism
        // contract that makes warming purely a latency optimization).
        let model = CosimModel::new(true);
        let mut warm_session = Session::new(&model.cfg).unwrap();
        let mut cold_session = Session::new(&model.cfg).unwrap();
        for base in [0u64, 1 << 16, 3 << 18] {
            let parked = model.simulate_parked(&mut warm_session, base).unwrap();
            let cold = model.simulate_cycles(&mut cold_session, base).unwrap();
            assert_eq!(parked.cycles, cold, "base {base:#x}: warmed != cold");
            assert!(!parked.blob.is_empty(), "parked state must carry a checkpoint");
        }
    }

    #[test]
    fn band_energy_classifier_recovers_synth_classes() {
        // The sim-only host backend must be deterministic and mostly
        // recover the class encoded in the synthetic envelope.
        let mut correct = 0;
        for id in 0..(2 * N_CLASSES as u64) {
            let r = super::super::kws::synth_request(id);
            let a = band_energy_logits(&r.features);
            let b = band_energy_logits(&r.features);
            assert_eq!(a, b, "stand-in classifier must be deterministic");
            let class =
                a.iter().enumerate().max_by(|x, y| x.1.total_cmp(y.1)).map(|(i, _)| i).unwrap();
            if class == (id % N_CLASSES as u64) as usize {
                correct += 1;
            }
        }
        assert!(correct >= N_CLASSES, "stand-in classifier degenerate: {correct} correct");
    }
}

//! The serving loop: worker threads pull batched requests from a channel,
//! execute the compiled model, and co-simulate the weight stream.
//!
//! The weight-stream co-simulation runs through the same stage-based
//! [`crate::sim::engine`] as every other simulation in the crate:
//! [`UltraTrail::case_study`] fans the per-layer supply simulations out
//! across a worker pool (one engine per worker, deterministic
//! merge-by-layer), so server start-up cost scales with cores while the
//! reported cycle counts stay bit-identical to a serial run.

use super::kws::{KwsRequest, KwsResult, MFCC_BINS, MFCC_FRAMES};
use crate::accel::UltraTrail;
use crate::runtime::{LoadedModel, Runtime};
use crate::Result;
use std::sync::mpsc;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Co-simulate the UltraTrail weight stream per inference (adds the
    /// accelerator cycle count to each result).
    pub cosim_weights: bool,
    /// Use preloading in the co-simulated hierarchy.
    pub preload: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { max_batch: 8, cosim_weights: true, preload: true }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct CoordinatorStats {
    /// Requests served.
    pub served: u64,
    /// Batches executed.
    pub batches: u64,
    /// Total host wall time across batches.
    pub host_time: std::time::Duration,
    /// Mean simulated accelerator cycles per inference.
    pub mean_accel_cycles: f64,
}

/// The KWS server: owns the runtime, model, and (optional) hierarchy
/// co-simulation.
pub struct KwsServer {
    runtime: Runtime,
    model: LoadedModel,
    cfg: ServerConfig,
    /// Cycles of one inference through the simulated hierarchy (computed
    /// once — weights are identical per inference).
    accel_cycles: Option<u64>,
    stats: CoordinatorStats,
}

impl KwsServer {
    /// Load the model artifact and prepare the server.
    pub fn new(artifact: &std::path::Path, cfg: ServerConfig) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let model = runtime.load_hlo_text(artifact)?;
        let accel_cycles = if cfg.cosim_weights {
            let cs = UltraTrail::default().case_study(cfg.preload)?;
            Some(cs.realized_cycles)
        } else {
            None
        };
        Ok(Self { runtime, model, cfg, accel_cycles, stats: CoordinatorStats::default() })
    }

    /// Serve one batch synchronously.
    pub fn serve_batch(&mut self, requests: &[KwsRequest]) -> Result<Vec<KwsResult>> {
        assert!(!requests.is_empty());
        let t0 = Instant::now();
        let mut results = Vec::with_capacity(requests.len());
        // The artifact is compiled for batch 1 (UltraTrail processes one
        // utterance at a time); the batcher amortizes host overhead.
        for r in requests {
            let inputs =
                vec![(r.features.clone(), vec![1i64, MFCC_BINS as i64, MFCC_FRAMES as i64])];
            let outs = self.runtime.run_f32(&self.model, &inputs)?;
            let logits = outs.into_iter().next().unwrap_or_default();
            let class = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            results.push(KwsResult {
                id: r.id,
                logits,
                class,
                accel_cycles: self.accel_cycles,
                host_latency: t0.elapsed(),
            });
        }
        self.stats.served += requests.len() as u64;
        self.stats.batches += 1;
        self.stats.host_time += t0.elapsed();
        if let Some(c) = self.accel_cycles {
            self.stats.mean_accel_cycles = c as f64;
        }
        Ok(results)
    }

    /// Run a request stream through a channel-fed serving loop (the
    /// "request path": producer thread → batcher → executor).
    pub fn serve_stream(&mut self, requests: Vec<KwsRequest>) -> Result<Vec<KwsResult>> {
        let (tx, rx) = mpsc::channel::<KwsRequest>();
        let producer = std::thread::spawn(move || {
            for r in requests {
                if tx.send(r).is_err() {
                    break;
                }
            }
        });
        let mut results = Vec::new();
        let mut batch = Vec::new();
        loop {
            match rx.recv() {
                Ok(r) => {
                    batch.push(r);
                    // Drain whatever is immediately available up to max_batch.
                    while batch.len() < self.cfg.max_batch {
                        match rx.try_recv() {
                            Ok(r) => batch.push(r),
                            Err(_) => break,
                        }
                    }
                    results.extend(self.serve_batch(&batch)?);
                    batch.clear();
                }
                Err(_) => break, // producer done
            }
        }
        producer.join().expect("producer thread");
        Ok(results)
    }

    /// Serving statistics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }
}

//! The serving loop: worker threads pull batched requests from a channel,
//! execute the compiled model, and co-simulate the weight stream.
//!
//! The weight-stream co-simulation runs on a **persistent warm
//! [`Session`]** owned by the server: per batch, each request's weight
//! access pattern (its `weight_base` — multi-tenant serving keeps
//! different models at different off-chip addresses) is streamed through
//! the same re-armed hierarchy, layer by layer, exactly as the hardware
//! reprograms one physical hierarchy per layer. Distinct patterns are
//! simulated once and cached in a bounded LRU keyed by `weight_base`
//! ([`ServerConfig::max_cached_bases`]), so steady-state serving pays
//! zero simulation cost for repeated patterns, a warm (allocation-free)
//! co-simulation for new or evicted ones, and bounded memory however many
//! tenants rotate through — no hierarchy is ever rebuilt after start-up,
//! and start-up itself no longer runs a full case study.

use super::kws::{KwsRequest, KwsResult, MFCC_BINS, MFCC_FRAMES};
use crate::accel::UltraTrail;
use crate::runtime::{LoadedModel, Runtime};
use crate::sim::batch::Session;
use crate::Result;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Co-simulate the UltraTrail weight stream per inference (adds the
    /// accelerator cycle count to each result).
    pub cosim_weights: bool,
    /// Use preloading in the co-simulated hierarchy.
    pub preload: bool,
    /// Maximum distinct `weight_base` entries the co-simulation cycle
    /// cache retains (least-recently-used entries are evicted beyond
    /// this; `0` = unbounded). Multi-tenant serving sees one entry per
    /// tenant model, so this bounds the server's per-tenant memory.
    pub max_cached_bases: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { max_batch: 8, cosim_weights: true, preload: true, max_cached_bases: 64 }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct CoordinatorStats {
    /// Requests served.
    pub served: u64,
    /// Batches executed.
    pub batches: u64,
    /// Total host wall time across batches.
    pub host_time: std::time::Duration,
    /// Mean simulated accelerator cycles per inference.
    pub mean_accel_cycles: f64,
}

/// One cached co-simulation result with its LRU stamp.
#[derive(Debug, Clone, Copy)]
struct CachedCycles {
    cycles: u64,
    last_used: u64,
}

/// The persistent weight-stream co-simulation: one warm session re-armed
/// per layer program, plus a **bounded** LRU cache of realized inference
/// cycle counts per weight base address (multi-tenant serving keeps one
/// entry per tenant; see [`ServerConfig::max_cached_bases`]).
struct WeightCosim {
    ut: UltraTrail,
    session: Session,
    /// Per-layer ideal MAC-array steps (the compute side of
    /// `max(steps, supply)`).
    steps: Vec<u64>,
    /// Largest per-layer weight stream in off-chip units (address-space
    /// bound for `weight_base` validation).
    max_layer_units: u64,
    /// Exclusive upper bound of the co-simulated off-chip address space.
    addr_limit: u64,
    /// Realized cycles of one inference per weight base address.
    cycles_by_base: BTreeMap<u64, CachedCycles>,
    /// Cache capacity (0 = unbounded).
    max_cached_bases: usize,
    /// Monotonic access stamp driving the LRU order.
    tick: u64,
}

impl WeightCosim {
    fn new(preload: bool, max_cached_bases: usize) -> Result<Self> {
        let ut = UltraTrail::default();
        let cfg = ut.hierarchy_wmem_config(preload);
        let steps = ut.layers.iter().map(|l| ut.steps(l)).collect();
        let max_layer_units = ut.layers.iter().map(|l| ut.weight_units(l)).max().unwrap_or(0);
        let addr_limit = 1u64 << cfg.offchip.addr_width.min(48);
        Ok(Self {
            ut,
            session: Session::new(&cfg)?,
            steps,
            max_layer_units,
            addr_limit,
            cycles_by_base: BTreeMap::new(),
            max_cached_bases,
            tick: 0,
        })
    }

    /// Realized cycles of one inference whose weights sit at `base`:
    /// streamed once through the warm session (all layers back-to-back on
    /// one hierarchy), then served from cache until evicted. At base 0
    /// this equals [`UltraTrail::case_study`]'s `realized_cycles` —
    /// warm-vs-cold determinism guarantees it (and makes eviction purely
    /// a performance event: a re-simulated base yields the same count). A
    /// base whose weight stream would fall outside the co-simulated
    /// off-chip address space is rejected.
    fn realized_cycles(&mut self, base: u64) -> Result<u64> {
        match base.checked_add(self.max_layer_units) {
            Some(end) if end <= self.addr_limit => {}
            _ => {
                return Err(crate::Error::Pattern(format!(
                    "weight_base {base:#x} leaves no room for a {}-unit weight stream \
                     in the {:#x}-word off-chip address space",
                    self.max_layer_units, self.addr_limit
                )))
            }
        }
        self.tick += 1;
        let stamp = self.tick;
        if let Some(entry) = self.cycles_by_base.get_mut(&base) {
            entry.last_used = stamp;
            return Ok(entry.cycles);
        }
        let mut total = 0u64;
        for (i, l) in self.ut.layers.iter().enumerate() {
            let mut prog = self.ut.layer_program(l);
            prog.start_address = base;
            let supply = self.session.run_program(&prog)?.stats.internal_cycles;
            total += self.steps[i].max(supply);
        }
        self.cycles_by_base.insert(base, CachedCycles { cycles: total, last_used: stamp });
        self.evict_lru();
        Ok(total)
    }

    /// Drop least-recently-used entries until the cache fits its bound.
    fn evict_lru(&mut self) {
        if self.max_cached_bases == 0 {
            return;
        }
        while self.cycles_by_base.len() > self.max_cached_bases {
            let oldest = self
                .cycles_by_base
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&b, _)| b)
                .expect("cache non-empty");
            self.cycles_by_base.remove(&oldest);
        }
    }
}

/// The KWS server: owns the runtime, model, and (optional) persistent
/// warm hierarchy co-simulation.
pub struct KwsServer {
    runtime: Runtime,
    model: LoadedModel,
    cfg: ServerConfig,
    /// Warm per-batch weight-stream co-simulation (None = disabled).
    cosim: Option<WeightCosim>,
    /// Sum/count of co-simulated cycles over all served requests.
    accel_sum: f64,
    accel_served: u64,
    stats: CoordinatorStats,
}

impl KwsServer {
    /// Load the model artifact and prepare the server. Start-up no longer
    /// pre-computes a one-shot cycle count: the co-simulation session is
    /// opened warm and individual patterns are simulated on first use.
    pub fn new(artifact: &std::path::Path, cfg: ServerConfig) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let model = runtime.load_hlo_text(artifact)?;
        let cosim = if cfg.cosim_weights {
            Some(WeightCosim::new(cfg.preload, cfg.max_cached_bases)?)
        } else {
            None
        };
        Ok(Self {
            runtime,
            model,
            cfg,
            cosim,
            accel_sum: 0.0,
            accel_served: 0,
            stats: CoordinatorStats::default(),
        })
    }

    /// Serve one batch synchronously, co-simulating each request's weight
    /// stream on the warm session (cached per distinct `weight_base`).
    pub fn serve_batch(&mut self, requests: &[KwsRequest]) -> Result<Vec<KwsResult>> {
        assert!(!requests.is_empty());
        let t0 = Instant::now();
        let mut results = Vec::with_capacity(requests.len());
        // The artifact is compiled for batch 1 (UltraTrail processes one
        // utterance at a time); the batcher amortizes host overhead.
        for r in requests {
            let accel_cycles = match self.cosim.as_mut() {
                Some(c) => Some(c.realized_cycles(r.weight_base)?),
                None => None,
            };
            let inputs =
                vec![(r.features.clone(), vec![1i64, MFCC_BINS as i64, MFCC_FRAMES as i64])];
            let outs = self.runtime.run_f32(&self.model, &inputs)?;
            let logits = outs.into_iter().next().unwrap_or_default();
            let class = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            if let Some(c) = accel_cycles {
                self.accel_sum += c as f64;
                self.accel_served += 1;
            }
            results.push(KwsResult {
                id: r.id,
                logits,
                class,
                accel_cycles,
                host_latency: t0.elapsed(),
            });
        }
        self.stats.served += requests.len() as u64;
        self.stats.batches += 1;
        self.stats.host_time += t0.elapsed();
        if self.accel_served > 0 {
            self.stats.mean_accel_cycles = self.accel_sum / self.accel_served as f64;
        }
        Ok(results)
    }

    /// Run a request stream through a channel-fed serving loop (the
    /// "request path": producer thread → batcher → executor).
    pub fn serve_stream(&mut self, requests: Vec<KwsRequest>) -> Result<Vec<KwsResult>> {
        let (tx, rx) = mpsc::channel::<KwsRequest>();
        let producer = std::thread::spawn(move || {
            for r in requests {
                if tx.send(r).is_err() {
                    break;
                }
            }
        });
        let mut results = Vec::new();
        let mut batch = Vec::new();
        loop {
            match rx.recv() {
                Ok(r) => {
                    batch.push(r);
                    // Drain whatever is immediately available up to max_batch.
                    while batch.len() < self.cfg.max_batch {
                        match rx.try_recv() {
                            Ok(r) => batch.push(r),
                            Err(_) => break,
                        }
                    }
                    results.extend(self.serve_batch(&batch)?);
                    batch.clear();
                }
                Err(_) => break, // producer done
            }
        }
        producer.join().expect("producer thread");
        Ok(results)
    }

    /// Serving statistics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_cosim_matches_case_study_and_caches() {
        // The per-batch warm co-simulation must reproduce the one-shot
        // case-study cycle count exactly (warm-vs-cold determinism), and
        // cache per weight base.
        let mut cosim = WeightCosim::new(true, 64).unwrap();
        let a = cosim.realized_cycles(0).unwrap();
        let cs = UltraTrail::default().case_study(true).unwrap();
        assert_eq!(a, cs.realized_cycles, "warm cosim diverged from the case study");
        assert_eq!(cosim.realized_cycles(0).unwrap(), a);
        assert_eq!(cosim.cycles_by_base.len(), 1, "repeat patterns must hit the cache");
        // A different weight base is a different access pattern on the
        // same warm session; a pure sequential supply is base-invariant
        // in cycles, so the count matches while being cached separately.
        let b = cosim.realized_cycles(1 << 20).unwrap();
        assert_eq!(b, a);
        assert_eq!(cosim.cycles_by_base.len(), 2);
    }

    #[test]
    fn out_of_space_weight_base_rejected() {
        // A base whose stream would exceed the 24-bit address space must
        // error instead of simulating nonexistent addresses.
        let mut cosim = WeightCosim::new(false, 64).unwrap();
        assert!(cosim.realized_cycles(u64::MAX).is_err());
        assert!(cosim.realized_cycles(1 << 24).is_err());
        assert!(cosim.cycles_by_base.is_empty(), "rejected bases must not be cached");
        // The boundary case that still fits is accepted.
        let fitting = (1u64 << 24) - cosim.max_layer_units;
        assert!(cosim.realized_cycles(fitting).is_ok());
    }

    #[test]
    fn cosim_cache_evicts_least_recently_used() {
        let mut cosim = WeightCosim::new(false, 2).unwrap();
        let a = cosim.realized_cycles(0).unwrap();
        cosim.realized_cycles(1 << 16).unwrap();
        // Touch base 0 so base 1<<16 becomes the LRU entry, then insert a
        // third base: the bound holds and the LRU entry is the one gone.
        cosim.realized_cycles(0).unwrap();
        cosim.realized_cycles(1 << 17).unwrap();
        assert_eq!(cosim.cycles_by_base.len(), 2, "cache must stay within its bound");
        assert!(cosim.cycles_by_base.contains_key(&0), "recently used entry survives");
        assert!(
            cosim.cycles_by_base.contains_key(&(1 << 17)),
            "newest entry survives"
        );
        assert!(
            !cosim.cycles_by_base.contains_key(&(1 << 16)),
            "least-recently-used entry is evicted"
        );
        // An evicted base re-simulates to the same count (determinism).
        assert_eq!(cosim.realized_cycles(1 << 16).unwrap(), a);
        assert_eq!(cosim.cycles_by_base.len(), 2);
        // Unbounded mode never evicts.
        let mut unbounded = WeightCosim::new(false, 0).unwrap();
        for base in [0u64, 1 << 16, 1 << 17, 1 << 18] {
            unbounded.realized_cycles(base).unwrap();
        }
        assert_eq!(unbounded.cycles_by_base.len(), 4);
    }
}

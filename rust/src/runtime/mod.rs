//! PJRT runtime: loads AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire inference path. HLO **text** is the interchange format — the
//! crate's xla_extension (0.5.1) rejects jax ≥ 0.5 serialized protos with
//! 64-bit instruction ids, while the text parser reassigns ids.

use crate::{Error, Result};
use std::path::Path;

/// A compiled executable plus its I/O metadata.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact path (for diagnostics).
    pub path: String,
}

/// The PJRT runtime: one CPU client, many loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Runtime(e.to_string()))?;
        Ok(Self { client })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", path.display())))?;
        Ok(LoadedModel { exe, path: path.display().to_string() })
    }

    /// Execute with f32 tensor inputs; returns the flattened f32 outputs
    /// of the result tuple (aot.py lowers with `return_tuple=True`).
    pub fn run_f32(&self, model: &LoadedModel, inputs: &[(Vec<f32>, Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
            literals.push(lit);
        }
        let result = model
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", model.path)))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
        let tuple = out
            .decompose_tuple()
            .map_err(|e| Error::Runtime(format!("decompose tuple: {e}")))?;
        let mut outputs = Vec::with_capacity(tuple.len());
        for t in tuple {
            outputs.push(t.to_vec::<f32>().map_err(|e| Error::Runtime(e.to_string()))?);
        }
        Ok(outputs)
    }
}

/// Default artifact location for the TC-ResNet model.
pub fn default_artifact() -> std::path::PathBuf {
    std::path::PathBuf::from("artifacts/tcresnet.hlo.txt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo_text(Path::new("/nonexistent/model.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("missing artifact must error"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    // Full load-and-execute tests live in rust/tests/runtime_e2e.rs and
    // run against the real artifacts.
}

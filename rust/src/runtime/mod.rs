//! Model runtime: loads AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and executes them.
//!
//! The original backend is the CPU PJRT client from the `xla` crate
//! (0.5.1): HLO **text** is the interchange format, because that crate
//! rejects jax ≥ 0.5 serialized protos with 64-bit instruction ids while
//! the text parser reassigns ids. The build environment here is offline
//! and cannot fetch `xla`, so this module ships a dependency-free stub
//! with the same API surface:
//!
//! * [`Runtime::cpu`] comes up and reports a CPU platform;
//! * [`Runtime::load_hlo_text`] validates the artifact's presence (the
//!   "run `make artifacts` first" contract) and parses the HLO header so
//!   obviously-corrupt artifacts are rejected early;
//! * [`Runtime::run_f32`] returns an `Error::Runtime` explaining that the
//!   executor backend is stubbed.
//!
//! Restoring the real executor is a one-module change: add `xla` back to
//! `Cargo.toml` and swap the bodies below for the PJRT calls (client,
//! `HloModuleProto::from_text_file`, `compile`, `execute`). All callers
//! (`coordinator::server`, `rust/tests/runtime_e2e.rs`) are written
//! against this module's API only, and the e2e tests skip when artifacts
//! are absent, so the stub keeps `cargo test` green from a pristine
//! checkout.

use crate::{Error, Result};
use std::path::Path;

/// A loaded (but, in the stub, not executable) model plus its metadata.
pub struct LoadedModel {
    /// Artifact path (for diagnostics).
    pub path: String,
    /// HLO module name parsed from the artifact header.
    pub module_name: String,
}

/// The model runtime: one CPU client, many loaded executables.
pub struct Runtime {
    platform: &'static str,
}

impl Runtime {
    /// Create a CPU runtime.
    pub fn cpu() -> Result<Self> {
        Ok(Self { platform: "cpu (stub — PJRT backend unavailable offline)" })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.platform.to_string()
    }

    /// Load an HLO-text artifact and validate its header.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        // HLO text starts with `HloModule <name>[, attributes]`.
        let module_name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule "))
            .map(|rest| {
                rest.split([',', ' '])
                    .next()
                    .unwrap_or("unnamed")
                    .to_string()
            })
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "parse {}: no HloModule header (not an HLO text artifact)",
                    path.display()
                ))
            })?;
        Ok(LoadedModel { path: path.display().to_string(), module_name })
    }

    /// Execute with f32 tensor inputs. The stub cannot execute; it reports
    /// a clear error so callers degrade loudly instead of silently.
    pub fn run_f32(
        &self,
        model: &LoadedModel,
        _inputs: &[(Vec<f32>, Vec<i64>)],
    ) -> Result<Vec<Vec<f32>>> {
        Err(Error::Runtime(format!(
            "cannot execute {}: the PJRT backend is stubbed in this offline build \
             (restore the `xla` dependency to run compiled models)",
            model.path
        )))
    }
}

/// Default artifact location for the TC-ResNet model.
pub fn default_artifact() -> std::path::PathBuf {
    std::path::PathBuf::from("artifacts/tcresnet.hlo.txt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().expect("CPU runtime");
        assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo_text(Path::new("/nonexistent/model.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("missing artifact must error"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn hlo_header_parsed_and_garbage_rejected() {
        let dir = std::env::temp_dir();
        let good = dir.join("memhier_test_good.hlo.txt");
        std::fs::write(&good, "HloModule tcresnet, entry_computation_layout={...}\n").unwrap();
        let rt = Runtime::cpu().unwrap();
        let m = rt.load_hlo_text(&good).unwrap();
        assert_eq!(m.module_name, "tcresnet");
        // Execution through the stub fails loudly, not silently.
        assert!(rt.run_f32(&m, &[]).is_err());
        let bad = dir.join("memhier_test_bad.hlo.txt");
        std::fs::write(&bad, "not an hlo artifact\n").unwrap();
        assert!(rt.load_hlo_text(&bad).is_err());
        let _ = std::fs::remove_file(good);
        let _ = std::fs::remove_file(bad);
    }

    // Full load-and-execute tests live in rust/tests/runtime_e2e.rs and
    // run against the real artifacts (skipping under the stub backend).
}

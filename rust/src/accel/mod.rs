//! The UltraTrail case-study substrate (§5.3): an 8×8 MAC-array TC-ResNet
//! keyword-spotting accelerator, its baseline weight memory, and the
//! memory-framework replacement.
//!
//! * [`wmem`] — weight-memory supply plans for the §5.3.1 unrolling sweep
//!   (Figs 9 and 10): dual-ported SRAM alternatives vs framework
//!   configurations, with supply cadences *measured from the cycle
//!   simulator*, not assumed.
//! * [`ultratrail`] — the full §5.3.2 case study (Figs 11 and 12): chip
//!   area and power of baseline UltraTrail vs the hierarchy-as-WMEM
//!   configuration, and the per-layer runtime/efficiency accounting behind
//!   the paper's −62.2 % area / −2.4 % performance headline.

pub mod ultratrail;
pub mod wmem;

pub use ultratrail::{CaseStudy, LayerTiming, UltraTrail};
pub use wmem::{fig9_areas, fig10_runtimes, measure_supply_cadence, SweepPoint, WmemPlan};

//! Weight-memory supply plans for the §5.3.1 unrolling sweep.
//!
//! The sweep varies the unique weight addresses per loop step
//! u ∈ {8, 16, 32, 64} (§5.3.1 uses 8-bit weights, so the port is u×8
//! bits). Each point needs a storage plan:
//!
//! | u  | port    | dual-ported SRAM alternative | framework                |
//! |----|---------|------------------------------|--------------------------|
//! | 8  | 64 bit  | 2 × (64×2048) DP banks       | 1 × (64×32) DP level     |
//! | 16 | 128 bit | 2 × (128×1024) DP banks      | 2 × 64-bit words serial  |
//! | 32 | 256 bit | 2 × (128×1024) DP banks      | 2 frameworks in parallel |
//! | 64 | 512 bit | 4 × (128×512) DP banks       | 2 frameworks in parallel |
//!
//! The dual-ported alternative must hold the *largest layer* (layer 11:
//! 20 736 weights → 2 592 words at u = 8, above the 2 048-word macro
//! capacity limit, hence two banks — §5.3.1). The framework streams from
//! off-chip and only needs its 32-word window.

use crate::config::{HierarchyConfig, PortKind};
use crate::cost::{hierarchy_area, sram_area};
use crate::mem::Hierarchy;
use crate::model::tc_resnet8;
use crate::pattern::PatternProgram;
use crate::util::ceil_div;

/// Weight precision of the §5.3.1 sweep (8-bit data words).
pub const SWEEP_WEIGHT_BITS: u64 = 8;
/// Library limit: maximum words per dual-ported macro (§5.3.1).
pub const DP_MACRO_MAX_DEPTH: u64 = 2_048;
/// Framework window depth used by the sweep (§5.3.1: "capacity of 32
/// words").
pub const FRAMEWORK_DEPTH: u64 = 32;

/// One sweep point of the §5.3.1 evaluation.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Unique weight addresses per loop step.
    pub unique_per_step: u64,
    /// Weight-port width in bits (`u × 8`).
    pub port_bits: u64,
    /// Framework level word width (bits).
    pub word_bits: u32,
    /// Hierarchy level words fetched per port word *per framework
    /// instance* (consecutive accesses).
    pub words_per_port: u64,
    /// Framework instances operating in parallel.
    pub parallel: u64,
}

/// The four §5.3.1 sweep points.
///
/// The fetch schedule (`words_per_port`, `parallel`) follows the §5.3.1
/// bank discussion: "either two 128-bit banks (accessed consecutively) or
/// two 64-bit banks (working in parallel)"; "unrollings with 32 and 64
/// unique addresses need multiple banks for data parallelism".
pub fn sweep_points() -> Vec<SweepPoint> {
    vec![
        // 64-bit port: one 64-bit word per step group.
        SweepPoint { unique_per_step: 8, port_bits: 64, word_bits: 64, words_per_port: 1, parallel: 1 },
        // 128-bit port: two 64-bit words accessed consecutively.
        SweepPoint { unique_per_step: 16, port_bits: 128, word_bits: 64, words_per_port: 2, parallel: 1 },
        // 256-bit port: one 128-bit framework, two consecutive accesses.
        SweepPoint { unique_per_step: 32, port_bits: 256, word_bits: 128, words_per_port: 2, parallel: 1 },
        // 512-bit port: two parallel 64-bit frameworks, four consecutive
        // accesses each ("multiple banks for data parallelism").
        SweepPoint { unique_per_step: 64, port_bits: 512, word_bits: 64, words_per_port: 4, parallel: 2 },
    ]
}

/// Storage plan (areas) for one sweep point — Figure 9.
#[derive(Debug, Clone)]
pub struct WmemPlan {
    /// The sweep point.
    pub point: SweepPoint,
    /// Chip area of the dual-ported SRAM alternative (µm²).
    pub dp_sram_area: f64,
    /// Chip area of the framework configuration(s) (µm²).
    pub framework_area: f64,
}

/// Framework configuration for a sweep point (one instance). Like the
/// §5.3.2 case study, the off-chip interface is clocked faster than the
/// accelerator, delivering one level word of raw bandwidth per internal
/// cycle (1 MHz µC vs 250 kHz accelerator: ratio = word/32). The handshake
/// still limits the cadence to ~3 internal cycles per level word.
pub fn framework_config(p: &SweepPoint) -> HierarchyConfig {
    let ratio = (p.word_bits / 32) as f64;
    HierarchyConfig::builder()
        .offchip(32, 24, ratio)
        .level(p.word_bits, FRAMEWORK_DEPTH, 1, 2)
        .osr((p.port_bits / p.parallel) as u32, vec![(p.port_bits / p.parallel) as u32])
        .build()
        .expect("sweep framework config is valid")
}

/// Dual-ported SRAM banks sized to hold the largest layer at this sweep
/// point, respecting the macro depth limit.
fn dp_sram_banks(p: &SweepPoint) -> (u64, u32, u64) {
    let largest = tc_resnet8().iter().map(|l| l.weights()).max().unwrap();
    let words_needed = ceil_div(largest, p.unique_per_step);
    // Bank width: up to 128 bits per macro; port delivered by parallel
    // banks.
    let bank_width = p.port_bits.min(128) as u32;
    let width_banks = ceil_div(p.port_bits, bank_width as u64);
    // Depth per width-bank, split across further banks if above the limit.
    let mut depth = words_needed;
    let mut depth_banks = 1;
    while depth > DP_MACRO_MAX_DEPTH {
        depth = ceil_div(depth, 2);
        depth_banks *= 2;
    }
    // Round up to a power-of-two macro depth (compiler granularity).
    let macro_depth = depth.next_power_of_two();
    (width_banks * depth_banks, bank_width, macro_depth)
}

/// Compute the Figure 9 area comparison for all sweep points.
///
/// Fig 9 sizes both alternatives for *full data parallelism*: the port is
/// delivered spatially, so both the dual-ported SRAMs and the frameworks
/// instantiate `port_bits / word_bits` parallel banks ("the parallel
/// memory frameworks", §5.3.1).
pub fn fig9_areas() -> Vec<WmemPlan> {
    sweep_points()
        .into_iter()
        .map(|p| {
            let (banks, bank_width, macro_depth) = dp_sram_banks(&p);
            let dp_sram_area = banks as f64 * sram_area(bank_width, macro_depth, PortKind::Dual);
            let fw = framework_config(&p);
            let spatial_instances = ceil_div(p.port_bits, p.word_bits as u64);
            let framework_area = spatial_instances as f64 * hierarchy_area(&fw).total;
            WmemPlan { point: p, dp_sram_area, framework_area }
        })
        .collect()
}

/// Measure the steady-state supply cadence (internal cycles per level
/// word) of a framework configuration by streaming a long sequential
/// program through the simulator.
pub fn measure_supply_cadence(cfg: &HierarchyConfig) -> f64 {
    let mut h = Hierarchy::new(cfg).expect("valid config");
    let pack = (cfg.levels[0].word_width / cfg.offchip.data_width) as u64;
    let units_per_emit = cfg
        .osr
        .as_ref()
        .map(|o| (o.shifts[0] / cfg.offchip.data_width) as u64)
        .unwrap_or(pack);
    // 512 level words, aligned to the OSR emission size.
    let words = crate::util::round_up(512 * pack, units_per_emit.max(pack));
    h.load_program(&PatternProgram::sequential(0, words))
        .expect("sequential program");
    let stats = h.run().expect("sim").stats;
    stats.internal_cycles as f64 / (words / pack) as f64
}

/// Per-layer runtime under one sweep point — the Figure 10 model.
///
/// * compute steps: one MAC-array step per cycle, `weights/u` port words
///   each live for `x·u/64` steps;
/// * supply: `weights/u` port words, each needing `words_per_port`
///   hierarchy reads at the *measured* cadence, across `parallel`
///   instances;
/// * runtime = max(compute, supply) — no preloading (§5.3.1).
#[derive(Debug, Clone)]
pub struct LayerRuntime {
    /// Layer index.
    pub layer: usize,
    /// Ideal MAC steps.
    pub steps: u64,
    /// Weight-supply cycles.
    pub supply: u64,
    /// max(steps, supply).
    pub runtime: u64,
}

/// Compute Figure 10: per-layer runtimes and overall efficiency for one
/// sweep point. Returns (per-layer, overall efficiency).
pub fn fig10_runtimes(p: &SweepPoint) -> (Vec<LayerRuntime>, f64) {
    let cadence = measure_supply_cadence(&framework_config(p));
    let layers = tc_resnet8();
    let per: Vec<LayerRuntime> = layers
        .iter()
        .map(|l| {
            let port_words = ceil_div(l.weights(), p.unique_per_step);
            // Ideal steps: the 64-MAC array amortizes partial tiles across
            // the layer (weights·x MACs at 64 per cycle).
            let steps = ceil_div(l.weights() * l.x, 64);
            let supply = (port_words as f64 * p.words_per_port as f64 * cadence
                / p.parallel as f64)
                .ceil() as u64;
            LayerRuntime { layer: l.idx, steps, supply, runtime: steps.max(supply) }
        })
        .collect();
    let total_steps: u64 = per.iter().map(|r| r.steps).sum();
    let total_runtime: u64 = per.iter().map(|r| r.runtime).sum();
    (per, total_steps as f64 / total_runtime as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_ports_are_u_times_8() {
        for p in sweep_points() {
            assert_eq!(p.port_bits, p.unique_per_step * SWEEP_WEIGHT_BITS);
            assert_eq!(
                p.words_per_port * p.parallel * p.word_bits as u64,
                p.port_bits,
                "u={}: plan must assemble the full port",
                p.unique_per_step
            );
        }
    }

    #[test]
    fn layer11_needs_two_dp_banks_at_u8() {
        // §5.3.1: 2,592 words needed, macro capacity 2,048 -> two banks.
        let p = &sweep_points()[0];
        let (banks, width, depth) = dp_sram_banks(p);
        assert_eq!(width, 64);
        assert_eq!(banks, 2);
        assert!(depth >= 1_296 && depth <= 2_048);
    }

    #[test]
    fn fig9_framework_fraction_at_u8() {
        // §5.3.1: the framework occupies "only 6.5% of the chip area
        // compared to the dual-ported alternatives".
        let plans = fig9_areas();
        let p8 = &plans[0];
        let frac = p8.framework_area / p8.dp_sram_area;
        assert!(
            (0.03..0.10).contains(&frac),
            "u=8 framework fraction {frac:.3} (paper: 0.065)"
        );
    }

    #[test]
    fn fig9_overall_ratio_about_3x() {
        // §5.3.1: "the dual-ported SRAMs remain 3.1 times larger than the
        // parallel memory frameworks" (at the parallel sweep points).
        let plans = fig9_areas();
        let p64 = plans.last().unwrap();
        let ratio = p64.dp_sram_area / p64.framework_area;
        assert!((2.0..5.0).contains(&ratio), "u=64 ratio {ratio:.2} (paper: 3.1)");
    }

    #[test]
    fn fig9_dp_sram_growth_moderate() {
        // §5.3.1: "despite a 17.1% increase" across the sweep.
        let plans = fig9_areas();
        let first = plans.first().unwrap().dp_sram_area;
        let last = plans.last().unwrap().dp_sram_area;
        let growth = last / first - 1.0;
        assert!(
            (0.05..0.40).contains(&growth),
            "dp-sram growth {growth:.3} (paper: 0.171)"
        );
    }

    #[test]
    fn measured_cadence_is_about_three() {
        // The framework supplies one level word every ~3 internal cycles
        // (§5.3.2) when streaming sequentially with the depth-1 buffer.
        let p = &sweep_points()[0];
        let c = measure_supply_cadence(&framework_config(p));
        assert!((2.0..4.0).contains(&c), "cadence {c:.2}");
    }

    #[test]
    fn fig10_efficiency_shape() {
        // Efficiencies rise with unique addresses per step; the paper
        // reports 58.8 / 60.6 / 85.7 / 97.6 %.
        let effs: Vec<f64> = sweep_points().iter().map(|p| fig10_runtimes(p).1).collect();
        // Non-decreasing up to supply-rounding jitter (the first two sweep
        // points share the same effective fetch cadence, as in the paper
        // where they differ by only 1.8 pp).
        assert!(effs.windows(2).all(|w| w[1] >= w[0] - 0.01), "monotone: {effs:?}");
        assert!((0.45..0.75).contains(&effs[0]), "u=8 eff {:.3} (paper 0.588)", effs[0]);
        assert!((0.85..1.0).contains(&effs[3]), "u=64 eff {:.3} (paper 0.976)", effs[3]);
    }

    #[test]
    fn fig10_fc_layers_are_inefficient() {
        // §5.3.2: FC layers have "low efficiency" (no weight reuse).
        let (per, _) = fig10_runtimes(&sweep_points()[3]);
        for r in per.iter().filter(|r| r.layer == 8 || r.layer == 12) {
            assert!(r.supply > r.steps, "FC layer {} must be supply-bound", r.layer);
        }
    }
}

//! The UltraTrail case study (§5.3.2, Figures 11 and 12).
//!
//! UltraTrail is an ultra-low-power keyword-spotting accelerator: an 8×8
//! MAC array (64 units, 6-bit weights, 384-bit weight port) running the
//! TC-ResNet of Table 2 at 250 kHz against a 1 MHz 32-bit off-chip
//! interface — clocked low to meet the 100 ms real-time budget while
//! minimizing power.
//!
//! * **Baseline** (Fig 11a): three single-ported 1024×128-bit SRAM macros
//!   store the complete weight set (>70 % of chip area).
//! * **Hierarchy** (Fig 11b): one dual-ported 104×128-bit level plus a
//!   384-bit OSR streams weights on demand; the weight macros shrink by an
//!   order of magnitude, cutting total chip area by 62.2 % at a 6.2 %
//!   power increase (dual-ported leakage + streaming interface).

use super::wmem;
use crate::config::{HierarchyConfig, PortKind};
use crate::cost::{constants, hierarchy_area, run_power, sram_area, sram_leakage};
use crate::cost::{access_energy, AreaBreakdown};
use crate::mem::Hierarchy;
use crate::model::tc_resnet8;
use crate::model::LayerSpec;
use crate::pattern::PatternProgram;
use crate::sim::batch::Session;
use crate::sim::SimStats;
use crate::util::{ceil_div, par_map_indexed_with, round_up};
use crate::Result;

/// The UltraTrail accelerator model.
#[derive(Debug, Clone)]
pub struct UltraTrail {
    /// MAC array rows (output channels unrolled).
    pub uk: u64,
    /// MAC array columns (input channels unrolled).
    pub uc: u64,
    /// Weight precision in bits.
    pub weight_bits: u64,
    /// Accelerator clock (Hz).
    pub clock_hz: f64,
    /// The network it runs.
    pub layers: Vec<LayerSpec>,
}

impl Default for UltraTrail {
    fn default() -> Self {
        Self { uk: 8, uc: 8, weight_bits: 6, clock_hz: 250_000.0, layers: tc_resnet8() }
    }
}

/// Per-layer timing of one inference.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    /// Layer index.
    pub layer: usize,
    /// Ideal MAC-array steps (= cycles at 100 % efficiency).
    pub steps: u64,
    /// Weight-supply cycles through the hierarchy (0 for the baseline).
    pub supply: u64,
    /// Realized cycles: max(steps, supply).
    pub runtime: u64,
}

/// Complete case-study result (Fig 12 + headline numbers).
#[derive(Debug, Clone)]
pub struct CaseStudy {
    /// Baseline chip area (µm²).
    pub baseline_area: f64,
    /// Hierarchy-configuration chip area (µm²).
    pub hierarchy_area: f64,
    /// Area delta (negative = reduction), fraction.
    pub area_delta: f64,
    /// Weight-memory share of the baseline chip.
    pub baseline_wmem_share: f64,
    /// Baseline chip power (W).
    pub baseline_power: f64,
    /// Hierarchy chip power (W).
    pub hierarchy_power: f64,
    /// Power delta, fraction.
    pub power_delta: f64,
    /// Per-layer timing with the hierarchy.
    pub timing: Vec<LayerTiming>,
    /// Ideal total cycles (baseline).
    pub ideal_cycles: u64,
    /// Realized total cycles (hierarchy).
    pub realized_cycles: u64,
    /// Performance loss, fraction (paper: 0.024).
    pub perf_loss: f64,
    /// Inference latency with the hierarchy (s).
    pub latency_s: f64,
    /// Hierarchy area breakdown.
    pub wmem_breakdown: AreaBreakdown,
}

impl UltraTrail {
    /// 384-bit weight-port words of a layer: ceil(K/8)·ceil(C/8)·F.
    pub fn port_words(&self, l: &LayerSpec) -> u64 {
        ceil_div(l.k, self.uk) * ceil_div(l.c, self.uc) * l.f
    }

    /// Ideal MAC-array steps of a layer (each port word live for X steps).
    pub fn steps(&self, l: &LayerSpec) -> u64 {
        self.port_words(l) * l.x
    }

    /// Ideal cycles of one inference.
    pub fn ideal_cycles(&self) -> u64 {
        self.layers.iter().map(|l| self.steps(l)).sum()
    }

    /// The baseline weight memory: 3 × 1024×128-bit single-ported macros
    /// (Fig 11a).
    pub fn baseline_wmem_area(&self) -> f64 {
        3.0 * sram_area(128, 1024, PortKind::Single)
    }

    /// Baseline chip area.
    pub fn baseline_chip_area(&self) -> f64 {
        self.baseline_wmem_area() + constants().ut_rest_area
    }

    /// The hierarchy WMEM configuration (Fig 11b): 104×128-bit dual-ported
    /// level + 384-bit OSR, 1 MHz 32-bit off-chip interface, pipelined
    /// input buffer, preloading during preceding layers.
    pub fn hierarchy_wmem_config(&self, preload: bool) -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .ib_depth(8)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .preload(preload)
            .build()
            .expect("case-study config is valid")
    }

    /// Off-chip 32-bit units needed for a layer's weights, padded to the
    /// 384-bit OSR emission granularity.
    pub fn weight_units(&self, l: &LayerSpec) -> u64 {
        round_up(l.weights() * self.weight_bits, 384) / 32
    }

    /// Simulate the weight-supply time of one layer through a fresh
    /// hierarchy (the cold one-layer reference; the batched path is
    /// [`Self::layer_supplies`]).
    pub fn layer_supply(&self, l: &LayerSpec, cfg: &HierarchyConfig) -> Result<SimStats> {
        let mut h = Hierarchy::new(cfg)?;
        h.load_program(&PatternProgram::sequential(0, self.weight_units(l)))?;
        Ok(h.run()?.stats)
    }

    /// The weight-supply program of one layer (the per-layer access
    /// pattern the co-simulated hierarchy executes).
    pub fn layer_program(&self, l: &LayerSpec) -> PatternProgram {
        PatternProgram::sequential(0, self.weight_units(l))
    }

    /// Simulate every layer's weight supply, streaming layers through
    /// **one warm session per worker** (`threads`; `0` = all cores): each
    /// worker re-arms its hierarchy per layer instead of rebuilding it,
    /// mirroring the hardware, where one physical hierarchy is
    /// reprogrammed between layers. Warm-vs-cold determinism keeps the
    /// results identical to the serial cold path for any thread count;
    /// results merge by layer index and errors surface for the lowest
    /// failing layer index, as serially.
    pub fn layer_supplies(&self, cfg: &HierarchyConfig, threads: usize) -> Result<Vec<SimStats>> {
        par_map_indexed_with(
            self.layers.len(),
            threads,
            || Session::new(cfg),
            |session, i| match session {
                Ok(s) => Ok(s.run_program(&self.layer_program(&self.layers[i]))?.stats),
                // Session construction failed (invalid config): fall back
                // to the cold path so the error surfaces identically.
                Err(_) => self.layer_supply(&self.layers[i], cfg),
            },
        )
        .into_iter()
        .collect()
    }

    /// Run the full case study. The per-layer supply simulations fan out
    /// across all cores (see [`Self::layer_supplies`]); the result is
    /// deterministic regardless of thread count.
    pub fn case_study(&self, preload: bool) -> Result<CaseStudy> {
        let c = constants();
        let cfg = self.hierarchy_wmem_config(preload);

        // --- Timing ---
        let mut timing = Vec::new();
        let mut agg = SimStats::new(cfg.levels.len());
        let supplies = self.layer_supplies(&cfg, 0)?;
        for (l, stats) in self.layers.iter().zip(supplies.iter()) {
            let steps = self.steps(l);
            let supply = stats.internal_cycles;
            timing.push(LayerTiming { layer: l.idx, steps, supply, runtime: steps.max(supply) });
            // Aggregate activity for the power model.
            agg.internal_cycles += steps.max(supply);
            agg.offchip_reads += stats.offchip_reads;
            agg.cdc_transfers += stats.cdc_transfers;
            agg.osr_shifts += stats.osr_shifts;
            for i in 0..cfg.levels.len() {
                agg.level_reads[i] += stats.level_reads[i];
                agg.level_writes[i] += stats.level_writes[i];
            }
            agg.outputs += stats.outputs;
        }
        let ideal_cycles = self.ideal_cycles();
        let realized_cycles: u64 = timing.iter().map(|t| t.runtime).sum();
        let perf_loss = realized_cycles as f64 / ideal_cycles as f64 - 1.0;

        // --- Area (Fig 12a) ---
        let baseline_area = self.baseline_chip_area();
        let wmem_breakdown = hierarchy_area(&cfg);
        let hierarchy_chip = wmem_breakdown.total + c.ut_rest_area;
        let area_delta = hierarchy_chip / baseline_area - 1.0;
        let baseline_wmem_share = self.baseline_wmem_area() / baseline_area;

        // --- Power (Fig 12b) ---
        // Baseline: rest-of-chip + WMEM leakage + one 384-bit read per MAC
        // step (three 128-bit macros in parallel).
        let base_leak = 3.0 * sram_leakage(128, 1024, PortKind::Single);
        let e_rd = access_energy(128, 1024, PortKind::Single);
        let base_dyn_per_cycle = 3.0 * e_rd; // J per step
        let baseline_power = c.ut_rest_power + base_leak + base_dyn_per_cycle * self.clock_hz;
        // Hierarchy: rest-of-chip + framework activity over the realized
        // inference time.
        let p = run_power(&cfg, &agg, self.clock_hz);
        let hierarchy_power = c.ut_rest_power + p.total;
        let power_delta = hierarchy_power / baseline_power - 1.0;

        Ok(CaseStudy {
            baseline_area,
            hierarchy_area: hierarchy_chip,
            area_delta,
            baseline_wmem_share,
            baseline_power,
            hierarchy_power,
            power_delta,
            timing,
            ideal_cycles,
            realized_cycles,
            perf_loss,
            latency_s: realized_cycles as f64 / self.clock_hz,
            wmem_breakdown,
        })
    }
}

/// Convenience: the §5.3.1 sweep (Figs 9–10) plus the §5.3.2 case study.
pub fn full_evaluation(preload: bool) -> Result<(Vec<wmem::WmemPlan>, CaseStudy)> {
    Ok((wmem::fig9_areas(), UltraTrail::default().case_study(preload)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_set_fills_baseline_macros() {
        // Fig 11a: the complete 6-bit weight set occupies the three
        // 1024x128 macros almost exactly.
        let ut = UltraTrail::default();
        let bits: u64 = ut.layers.iter().map(|l| l.weights() * ut.weight_bits).sum();
        assert!(bits <= 3 * 1024 * 128);
        assert!(bits as f64 > 0.99 * (3 * 1024 * 128) as f64, "tight fit: {bits}");
    }

    #[test]
    fn baseline_wmem_share_above_70_percent() {
        // §5.3.2: "These macros alone occupy more than 70% of the
        // accelerator's chip area."
        let ut = UltraTrail::default();
        let share = ut.baseline_wmem_area() / ut.baseline_chip_area();
        assert!(share > 0.70, "share {share:.3}");
        assert!(share < 0.80, "share {share:.3} implausibly high");
    }

    #[test]
    fn area_reduction_62_percent() {
        // Headline: chip area reduced by 62.2 %.
        let cs = UltraTrail::default().case_study(true).unwrap();
        assert!(
            (-0.67..=-0.57).contains(&cs.area_delta),
            "area delta {:.3} (paper: -0.622)",
            cs.area_delta
        );
    }

    #[test]
    fn power_increase_about_6_percent() {
        // Fig 12b: power increases by 6.2 %.
        let cs = UltraTrail::default().case_study(true).unwrap();
        assert!(
            (0.02..0.12).contains(&cs.power_delta),
            "power delta {:.3} (paper: +0.062)",
            cs.power_delta
        );
    }

    #[test]
    fn performance_loss_small() {
        // Headline: performance loss minimized to 2.4 % (with preloading
        // using idle time between layers).
        let cs = UltraTrail::default().case_study(true).unwrap();
        assert!(
            (0.0..0.06).contains(&cs.perf_loss),
            "preloaded perf loss {:.4} (paper: 0.024)",
            cs.perf_loss
        );
        // Without preloading the loss grows but stays moderate.
        let cs_np = UltraTrail::default().case_study(false).unwrap();
        assert!(cs_np.perf_loss >= cs.perf_loss);
        assert!(cs_np.perf_loss < 0.35, "no-preload loss {:.3}", cs_np.perf_loss);
    }

    #[test]
    fn warm_layer_supplies_match_cold_per_layer() {
        // One warm session streaming all layers must reproduce the cold
        // fresh-hierarchy-per-layer stats exactly (preload on, like the
        // case study).
        let ut = UltraTrail::default();
        let cfg = ut.hierarchy_wmem_config(true);
        for threads in [1usize, 3] {
            let warm = ut.layer_supplies(&cfg, threads).unwrap();
            assert_eq!(warm.len(), ut.layers.len());
            for (l, w) in ut.layers.iter().zip(warm.iter()) {
                let cold = ut.layer_supply(l, &cfg).unwrap();
                assert_eq!(*w, cold, "layer {} diverged warm vs cold", l.idx);
            }
        }
    }

    #[test]
    fn real_time_budget_met() {
        // §5.3.2: 100 ms per inference at 250 kHz.
        let cs = UltraTrail::default().case_study(true).unwrap();
        assert!(cs.latency_s < 0.100, "latency {:.4}s exceeds 100ms", cs.latency_s);
    }

    #[test]
    fn layer11_is_the_streaming_bottleneck() {
        // §5.3.2: layer 11's short cycle length (4) strains the supply.
        let ut = UltraTrail::default();
        let cs = ut.case_study(false).unwrap();
        let t11 = cs.timing.iter().find(|t| t.layer == 11).unwrap();
        let ratio11 = t11.supply as f64 / t11.steps as f64;
        // Layer 0 (cycle length 98) has far more slack than layer 11.
        let t0 = cs.timing.iter().find(|t| t.layer == 0).unwrap();
        let ratio0 = t0.supply as f64 / t0.steps as f64;
        assert!(ratio11 > ratio0, "supply pressure: l11 {ratio11:.2} vs l0 {ratio0:.2}");
    }
}

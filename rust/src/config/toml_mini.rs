//! Minimal TOML-subset parser (offline substitute for the `toml` crate).
//!
//! Supported: `[table]` and `[[array-of-tables]]` headers, `key = value`
//! with integers, floats, booleans, strings, and homogeneous inline arrays
//! (`[1, 2, 3]`), plus `#` comments. This covers every config file the
//! repo ships.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// 64-bit integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// String (double-quoted in the source).
    Str(String),
    /// Inline array.
    Array(Vec<TomlValue>),
    /// Table (from `[name]` headers or the document root).
    Table(BTreeMap<String, TomlValue>),
    /// Array of tables (from `[[name]]` headers).
    TableArray(Vec<BTreeMap<String, TomlValue>>),
}

impl TomlValue {
    /// Integer accessor.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Unsigned accessor.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_int().and_then(|v| u64::try_from(v).ok())
    }

    /// Float accessor (accepts ints).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(v) => Some(v),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Table accessor.
    pub fn as_table(&self) -> Option<&BTreeMap<String, TomlValue>> {
        match self {
            TomlValue::Table(v) => Some(v),
            _ => None,
        }
    }

    /// Array-of-tables accessor.
    pub fn as_table_array(&self) -> Option<&[BTreeMap<String, TomlValue>]> {
        match self {
            TomlValue::TableArray(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a TOML-subset document into its root table.
pub fn parse(src: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut root: BTreeMap<String, TomlValue> = BTreeMap::new();
    // Where new keys go: None = root, Some((name, idx)) = table array elem,
    // Some((name, usize::MAX)) = plain table.
    let mut cursor: Option<(String, usize)> = None;

    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| Error::Parse(format!("line {}: {}", lineno + 1, msg));

        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim().to_string();
            let entry = root
                .entry(name.clone())
                .or_insert_with(|| TomlValue::TableArray(Vec::new()));
            match entry {
                TomlValue::TableArray(v) => {
                    v.push(BTreeMap::new());
                    cursor = Some((name, v.len() - 1));
                }
                _ => return Err(err("redefinition as table array")),
            }
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim().to_string();
            if root.contains_key(&name) {
                return Err(err("duplicate table"));
            }
            root.insert(name.clone(), TomlValue::Table(BTreeMap::new()));
            cursor = Some((name, usize::MAX));
        } else if let Some((k, v)) = line.split_once('=') {
            let key = k.trim().to_string();
            let value = parse_value(v.trim()).map_err(|m| err(&m))?;
            let target: &mut BTreeMap<String, TomlValue> = match &cursor {
                None => &mut root,
                Some((name, idx)) => match root.get_mut(name) {
                    Some(TomlValue::Table(t)) => t,
                    Some(TomlValue::TableArray(v)) => &mut v[*idx],
                    _ => return Err(err("internal cursor error")),
                },
            };
            if target.insert(key.clone(), value).is_some() {
                return Err(err(&format!("duplicate key {key:?}")));
            }
        } else {
            return Err(err(&format!("unparseable line {line:?}")));
        }
    }
    Ok(root)
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split on commas not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_root_keys() {
        let doc = parse("a = 1\nb = 2.5\nc = true\nd = \"hi\"\n").unwrap();
        assert_eq!(doc["a"].as_int(), Some(1));
        assert_eq!(doc["b"].as_f64(), Some(2.5));
        assert_eq!(doc["c"].as_bool(), Some(true));
        assert_eq!(doc["d"].as_str(), Some("hi"));
    }

    #[test]
    fn tables_and_table_arrays() {
        let src = r#"
# hierarchy example
[offchip]
data_width = 32
addr_width = 20

[[level]]
word_width = 32
ram_depth = 1024
ports = 1

[[level]]
word_width = 32
ram_depth = 128
ports = 2
"#;
        let doc = parse(src).unwrap();
        let off = doc["offchip"].as_table().unwrap();
        assert_eq!(off["data_width"].as_u64(), Some(32));
        let levels = doc["level"].as_table_array().unwrap();
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[1]["ram_depth"].as_u64(), Some(128));
    }

    #[test]
    fn arrays_and_underscored_ints() {
        let doc = parse("shifts = [32, 64, 384]\nbig = 1_024\n").unwrap();
        let arr = doc["shifts"].as_array().unwrap();
        assert_eq!(arr.iter().map(|v| v.as_u64().unwrap()).collect::<Vec<_>>(), vec![32, 64, 384]);
        assert_eq!(doc["big"].as_u64(), Some(1024));
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc = parse("a = \"x # y\" # trailing\n").unwrap();
        assert_eq!(doc["a"].as_str(), Some("x # y"));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("a = ").is_err());
        assert!(parse("nonsense").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        let e = parse("x = @@").unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("m = [[1, 2], [3, 4]]\n").unwrap();
        let outer = doc["m"].as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap()[1].as_int(), Some(2));
    }
}

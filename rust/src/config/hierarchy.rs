//! Hierarchy configuration types — the §4.1 SystemVerilog template
//! parameters, with the same validity constraints the paper states, plus
//! the pluggable per-level *kind* (§6 future work: double-buffered
//! levels).

use super::toml_mini::{self, TomlValue};
use crate::util::bitword::MAX_WIDTH;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Maximum number of hierarchy levels ("can range from one to five", §4.1).
pub const MAX_LEVELS: usize = 5;

/// Single- or dual-ported memory macro.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortKind {
    /// One shared read/write port; write-over-read arbitration applies.
    Single,
    /// Independent read and write ports (must not target the same address
    /// in the same cycle, §4.1.2).
    Dual,
}

impl PortKind {
    /// Number of ports.
    pub fn count(&self) -> u32 {
        match self {
            PortKind::Single => 1,
            PortKind::Dual => 2,
        }
    }
}

/// Behavioral kind of a hierarchy level — the single dispatch point every
/// level-dependent model (simulation, functional bounds, cost, DSE
/// enumeration, reporting) switches on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LevelKind {
    /// The §4.1.2 level: 1–2 banks of a single- or dual-ported macro
    /// driven by the Listing 1 MCU (write-enable toggle, write-over-read
    /// arbitration, optional resident window replay).
    Standard {
        /// Number of banks (1 or 2).
        banks: u32,
        /// Port configuration of each bank.
        ports: PortKind,
    },
    /// §6 future work: a ping-pong level built from two half-depth
    /// single-ported macros. One half drains toward the next level while
    /// the other fills from the previous one; the halves swap on a
    /// fill-complete / drain-empty handshake, so fill and drain overlap
    /// every cycle without dual-port macros and without the write-enable
    /// toggle. Drained slots are cleared, so the level always streams
    /// (it can never hold a resident window).
    DoubleBuffered,
}

impl LevelKind {
    /// Whether this kind can hold a pattern window resident and replay it
    /// (the Listing 1 reuse reads). Ping-pong halves clear as they drain,
    /// so a double-buffered level always streams.
    pub fn can_hold_resident_window(&self) -> bool {
        matches!(self, LevelKind::Standard { .. })
    }

    /// Whether this is a double-buffered (ping-pong) level.
    pub fn is_double_buffered(&self) -> bool {
        matches!(self, LevelKind::DoubleBuffered)
    }

    /// Short display label: `S`/`D` for single-/dual-ported standard
    /// levels, `B` for dual-banked standard levels, `P` for ping-pong.
    pub fn label(&self) -> char {
        match self {
            LevelKind::Standard { ports: PortKind::Dual, .. } => 'D',
            LevelKind::Standard { banks: 2, .. } => 'B',
            LevelKind::Standard { .. } => 'S',
            LevelKind::DoubleBuffered => 'P',
        }
    }

    /// The TOML `kind` key value.
    pub fn toml_name(&self) -> &'static str {
        match self {
            LevelKind::Standard { .. } => "standard",
            LevelKind::DoubleBuffered => "double_buffered",
        }
    }
}

/// Per-level storage protection scheme — an explorable DSE dimension
/// trading extra check-bit columns plus encode/decode logic on every
/// access against what a single-bit upset does to the run (see the
/// fault-injection contract in [`crate::mem`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Protection {
    /// Unprotected storage: a bit flip propagates silently unless the
    /// verify sink or deadlock guard happens to catch its consequences.
    None,
    /// One parity column per word: any single-bit flip is *detected* on
    /// read and the run is flagged, but the word cannot be repaired.
    Parity,
    /// Hamming SECDED (single-error-correct, double-error-detect): a
    /// single-bit flip is corrected in the decoder, leaving outputs
    /// bit-identical to the fault-free run.
    Secded,
}

impl Protection {
    /// Check bits appended to a `width`-bit word: 0 for `None`, one
    /// parity column, or the Hamming SECDED count — the smallest `r`
    /// with `2^r >= r + width + 1`, plus one overall-parity bit (7 for
    /// the common 32-bit word).
    pub fn check_bits(&self, width: u32) -> u32 {
        match self {
            Protection::None => 0,
            Protection::Parity => 1,
            Protection::Secded => {
                let mut r = 0u32;
                while (1u64 << r) < r as u64 + width as u64 + 1 {
                    r += 1;
                }
                r + 1
            }
        }
    }

    /// Short display marker appended to level descriptors (empty for
    /// unprotected levels, so pre-protection output is byte-identical).
    pub fn marker(&self) -> &'static str {
        match self {
            Protection::None => "",
            Protection::Parity => "p",
            Protection::Secded => "e",
        }
    }

    /// The TOML `protection` key value.
    pub fn toml_name(&self) -> &'static str {
        match self {
            Protection::None => "none",
            Protection::Parity => "parity",
            Protection::Secded => "secded",
        }
    }

    /// Parse a TOML `protection` key value.
    pub fn from_toml_name(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(Protection::None),
            "parity" => Ok(Protection::Parity),
            "secded" => Ok(Protection::Secded),
            other => Err(Error::Config(format!(
                "unknown protection {other:?} (expected \"none\", \"parity\" or \"secded\")"
            ))),
        }
    }
}

/// Off-chip interface parameters (§4.1 "Off-chip interface").
#[derive(Debug, Clone, PartialEq)]
pub struct OffchipConfig {
    /// Off-chip data word width in bits.
    pub data_width: u32,
    /// Off-chip address bus width in bits (bounds the address space).
    pub addr_width: u32,
    /// Read latency in *external* clock cycles (the case study uses 1).
    pub latency: u64,
    /// External (µC) clock frequency in Hz.
    pub external_hz: u64,
    /// Internal (accelerator) clock frequency in Hz.
    pub internal_hz: u64,
    /// Input-buffer entries: 1 = the paper's single register file with the
    /// full `buffer_full`/`reset_buffer` round-trip per word; >1 = FIFO
    /// extension with gray-code pointer synchronization (§4.1.1 "prevents
    /// potential blocking of the off-chip memory").
    pub ib_depth: u32,
}

impl Default for OffchipConfig {
    fn default() -> Self {
        Self { data_width: 32, addr_width: 20, latency: 1, external_hz: 1, internal_hz: 1, ib_depth: 1 }
    }
}

/// One hierarchy level (§4.1 "Hierarchy level configuration").
#[derive(Debug, Clone, PartialEq)]
pub struct LevelConfig {
    /// Memory macro name (cost-model lookup key; free-form).
    pub macro_name: String,
    /// Behavioral kind (standard banked level or ping-pong pair).
    pub kind: LevelKind,
    /// Word width of the macro in bits.
    pub word_width: u32,
    /// RAM depth: words per bank for standard levels; total words across
    /// both ping-pong halves for double-buffered levels (each half-depth
    /// macro holds `ram_depth / 2` words).
    pub ram_depth: u64,
    /// Storage protection of the level's macros (check-bit columns plus
    /// codec cost; see [`Protection`]). Purely a cost/robustness knob —
    /// it never changes cycle behavior.
    pub protection: Protection,
}

impl LevelConfig {
    /// Total capacity of the level in words (all banks / both halves).
    pub fn capacity_words(&self) -> u64 {
        match self.kind {
            LevelKind::Standard { banks, .. } => self.ram_depth * banks as u64,
            LevelKind::DoubleBuffered => self.ram_depth,
        }
    }

    /// Total capacity in bits.
    pub fn capacity_bits(&self) -> u64 {
        self.capacity_words() * self.word_width as u64
    }

    /// Depth of one ping-pong half (double-buffered levels only; a
    /// standard level has no halves and this returns half its depth).
    pub fn half_depth(&self) -> u64 {
        self.ram_depth / 2
    }

    /// Whether the level can service a read and a write in the same cycle:
    /// dual-ported, dual-banked with the accesses hitting different banks
    /// (checked at simulation time), or double-buffered (fill and drain
    /// target different half macros by construction).
    pub fn dual_capable(&self) -> bool {
        match self.kind {
            LevelKind::Standard { banks, ports } => ports == PortKind::Dual || banks == 2,
            LevelKind::DoubleBuffered => true,
        }
    }

    /// Compact display token, e.g. `512x32S` or `128x32P` (CLI tables,
    /// CSV exports and reports all share this format); protected levels
    /// gain a trailing marker, e.g. `512x32Sp` (parity) / `512x32Se`
    /// (SECDED).
    pub fn desc(&self) -> String {
        format!(
            "{}x{}{}{}",
            self.ram_depth,
            self.word_width,
            self.kind.label(),
            self.protection.marker()
        )
    }
}

/// OSR configuration (§4.1.5).
#[derive(Debug, Clone, PartialEq)]
pub struct OsrConfig {
    /// Register bit width (may exceed the last level's word width).
    pub width: u32,
    /// List of selectable shift widths in bits; `shift_select_i` indexes
    /// this list at runtime (0 = output disabled).
    pub shifts: Vec<u32>,
}

/// Complete framework configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct HierarchyConfig {
    /// Off-chip interface.
    pub offchip: OffchipConfig,
    /// Hierarchy levels, index 0 closest to off-chip memory (§4.1.2: the
    /// nomenclature is data-flow driven, contrary to CPU caches).
    pub levels: Vec<LevelConfig>,
    /// Optional output shift register.
    pub osr: Option<OsrConfig>,
    /// Enable preloading: the hierarchy begins fetching before the first
    /// output is requested (`disable_output_i` held during preload).
    pub preload: bool,
}

impl HierarchyConfig {
    /// Start building a config.
    pub fn builder() -> HierarchyBuilder {
        HierarchyBuilder::default()
    }

    /// The last (accelerator-facing) level.
    pub fn last_level(&self) -> &LevelConfig {
        self.levels.last().expect("validated: at least one level")
    }

    /// Compact level-stack description, e.g. `512x32S+128x32P`.
    pub fn stack_desc(&self) -> String {
        self.levels.iter().map(LevelConfig::desc).collect::<Vec<_>>().join("+")
    }

    /// Validate every constraint §4.1 states or implies.
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(Error::Config(m));
        if self.levels.is_empty() || self.levels.len() > MAX_LEVELS {
            return err(format!(
                "hierarchy depth must be 1..={MAX_LEVELS}, got {}",
                self.levels.len()
            ));
        }
        if self.offchip.data_width == 0 || self.offchip.data_width > MAX_WIDTH {
            return err(format!("off-chip data width {} out of range", self.offchip.data_width));
        }
        if self.offchip.addr_width == 0 || self.offchip.addr_width > 48 {
            return err(format!("off-chip addr width {} out of range", self.offchip.addr_width));
        }
        if self.offchip.external_hz == 0 || self.offchip.internal_hz == 0 {
            return err("clock frequencies must be positive".into());
        }
        if self.offchip.ib_depth == 0 || self.offchip.ib_depth > 16 {
            return err(format!("input-buffer depth {} out of range 1..=16", self.offchip.ib_depth));
        }
        for (i, l) in self.levels.iter().enumerate() {
            if l.word_width == 0 || l.word_width > 128 {
                return err(format!("level {i}: word width {} out of range 1..=128", l.word_width));
            }
            if l.ram_depth == 0 {
                return err(format!("level {i}: RAM depth must be > 0"));
            }
            match l.kind {
                LevelKind::Standard { banks, ports } => {
                    if !(1..=2).contains(&banks) {
                        return err(format!("level {i}: banks must be 1 or 2, got {banks}"));
                    }
                    if banks == 2 && ports == PortKind::Dual {
                        // "two single-ported banks emulate a dual-ported
                        // module; it is not reasonable to use more than two
                        // banks" — dual banks only make sense with
                        // single-ported macros.
                        return err(format!(
                            "level {i}: dual-banked levels must use single-ported macros"
                        ));
                    }
                }
                LevelKind::DoubleBuffered => {
                    if l.ram_depth < 2 || l.ram_depth % 2 != 0 {
                        return err(format!(
                            "level {i}: double-buffered depth {} must be even and >= 2 \
                             (two equal half-depth macros)",
                            l.ram_depth
                        ));
                    }
                }
            }
        }
        // Level word widths must be multiples of the off-chip width or vice
        // versa (the input buffer aligns by concatenation, §4.1.1), and
        // adjacent levels must share a word width (the OSR handles output
        // width conversion).
        let w0 = self.levels[0].word_width;
        let wo = self.offchip.data_width;
        if w0 % wo != 0 && wo % w0 != 0 {
            return err(format!(
                "level 0 word width {w0} incompatible with off-chip width {wo}"
            ));
        }
        for (i, pair) in self.levels.windows(2).enumerate() {
            if pair[0].word_width != pair[1].word_width {
                return err(format!(
                    "levels {i} and {} word widths differ ({} vs {}); width conversion \
                     happens in the input buffer and OSR only",
                    i + 1,
                    pair[0].word_width,
                    pair[1].word_width
                ));
            }
        }
        if let Some(osr) = &self.osr {
            let wl = self.last_level().word_width;
            if osr.width < wl {
                return err(format!(
                    "OSR width {} smaller than last level word width {wl}",
                    osr.width
                ));
            }
            if osr.width > MAX_WIDTH {
                return err(format!("OSR width {} exceeds max {MAX_WIDTH}", osr.width));
            }
            if osr.shifts.is_empty() {
                return err("OSR configured with empty shift list".into());
            }
            for &s in &osr.shifts {
                if s == 0 || s > osr.width {
                    return err(format!("OSR shift {s} out of range 1..={}", osr.width));
                }
            }
        }
        Ok(())
    }

    /// Parse from the TOML-subset config format (see `configs/*.toml`).
    pub fn from_toml(src: &str) -> Result<Self> {
        let doc = toml_mini::parse(src)?;
        Self::from_doc(&doc)
    }

    fn from_doc(doc: &BTreeMap<String, TomlValue>) -> Result<Self> {
        // Strict accessors: a *missing* key falls back to its default, but
        // a present-yet-malformed value is a config error — silently
        // substituting a default would mask typos in hand-written configs.
        fn need_u64(t: &BTreeMap<String, TomlValue>, k: &str) -> Result<u64> {
            t.get(k)
                .and_then(|v| v.as_u64())
                .ok_or_else(|| Error::Config(format!("missing or invalid integer key {k:?}")))
        }
        fn opt_u64(t: &BTreeMap<String, TomlValue>, k: &str) -> Result<Option<u64>> {
            match t.get(k) {
                None => Ok(None),
                Some(v) => match v.as_u64() {
                    Some(u) => Ok(Some(u)),
                    None => Err(Error::Config(format!(
                        "key {k:?} must be a non-negative integer, got {v:?}"
                    ))),
                },
            }
        }
        fn opt_str<'a>(t: &'a BTreeMap<String, TomlValue>, k: &str) -> Result<Option<&'a str>> {
            match t.get(k) {
                None => Ok(None),
                Some(v) => match v.as_str() {
                    Some(s) => Ok(Some(s)),
                    None => Err(Error::Config(format!("key {k:?} must be a string, got {v:?}"))),
                },
            }
        }
        // Narrowing must be checked, not `as`-truncated: a value like
        // 2^32 + 2 silently becoming 2 would re-introduce the masked-typo
        // behavior this parser rejects.
        fn to_u32(k: &str, v: u64) -> Result<u32> {
            u32::try_from(v)
                .map_err(|_| Error::Config(format!("key {k:?} value {v} does not fit in 32 bits")))
        }
        let mut offchip = OffchipConfig::default();
        if let Some(t) = doc.get("offchip").and_then(|v| v.as_table()) {
            if let Some(v) = opt_u64(t, "data_width")? {
                offchip.data_width = to_u32("data_width", v)?;
            }
            if let Some(v) = opt_u64(t, "addr_width")? {
                offchip.addr_width = to_u32("addr_width", v)?;
            }
            if let Some(v) = opt_u64(t, "latency")? {
                offchip.latency = v;
            }
            if let Some(v) = opt_u64(t, "external_hz")? {
                offchip.external_hz = v;
            }
            if let Some(v) = opt_u64(t, "internal_hz")? {
                offchip.internal_hz = v;
            }
            if let Some(v) = opt_u64(t, "ib_depth")? {
                offchip.ib_depth = to_u32("ib_depth", v)?;
            }
        }
        let level_tables = doc
            .get("level")
            .and_then(|v| v.as_table_array())
            .ok_or_else(|| Error::Config("config needs at least one [[level]]".into()))?;
        let mut levels = Vec::new();
        for t in level_tables {
            let word_width = to_u32("word_width", need_u64(t, "word_width")?)?;
            let ram_depth = need_u64(t, "ram_depth")?;
            let kind = match opt_str(t, "kind")?.unwrap_or("standard") {
                "standard" => {
                    let ports = match opt_u64(t, "ports")?.unwrap_or(1) {
                        1 => PortKind::Single,
                        2 => PortKind::Dual,
                        n => return Err(Error::Config(format!("ports must be 1 or 2, got {n}"))),
                    };
                    let banks = match opt_u64(t, "banks")? {
                        Some(b) => to_u32("banks", b)?,
                        None => 1,
                    };
                    LevelKind::Standard { banks, ports }
                }
                "double_buffered" => {
                    if t.contains_key("banks") || t.contains_key("ports") {
                        return Err(Error::Config(
                            "double-buffered levels take no banks/ports keys (always two \
                             single-ported half-depth macros)"
                                .into(),
                        ));
                    }
                    LevelKind::DoubleBuffered
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown level kind {other:?} (expected \"standard\" or \
                         \"double_buffered\")"
                    )))
                }
            };
            let protection = match opt_str(t, "protection")? {
                None => Protection::None,
                Some(s) => Protection::from_toml_name(s)?,
            };
            levels.push(LevelConfig {
                macro_name: opt_str(t, "macro")?.unwrap_or("generic_sram").to_string(),
                kind,
                word_width,
                ram_depth,
                protection,
            });
        }
        let osr = match doc.get("osr").and_then(|v| v.as_table()) {
            None => None,
            Some(t) => {
                let width = to_u32("width", need_u64(t, "width")?)?;
                let shifts = match t.get("shifts") {
                    None => vec![width],
                    Some(v) => {
                        let arr = v.as_array().ok_or_else(|| {
                            Error::Config("OSR shifts must be an array of integers".into())
                        })?;
                        let mut out = Vec::with_capacity(arr.len());
                        for e in arr {
                            let s = e.as_u64().ok_or_else(|| {
                                Error::Config(format!("OSR shift {e:?} is not an integer"))
                            })?;
                            out.push(to_u32("shifts", s)?);
                        }
                        out
                    }
                };
                Some(OsrConfig { width, shifts })
            }
        };
        let preload = match doc.get("preload") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| Error::Config(format!("preload must be a boolean, got {v:?}")))?,
        };
        let cfg = Self { offchip, levels, osr, preload };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize back to the TOML-subset format.
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        // Root-level keys must precede any table header.
        s.push_str(&format!("preload = {}\n\n", self.preload));
        s.push_str("[offchip]\n");
        s.push_str(&format!("data_width = {}\n", self.offchip.data_width));
        s.push_str(&format!("addr_width = {}\n", self.offchip.addr_width));
        s.push_str(&format!("latency = {}\n", self.offchip.latency));
        s.push_str(&format!("external_hz = {}\n", self.offchip.external_hz));
        s.push_str(&format!("internal_hz = {}\n", self.offchip.internal_hz));
        s.push_str(&format!("ib_depth = {}\n", self.offchip.ib_depth));
        for l in &self.levels {
            s.push_str("\n[[level]]\n");
            s.push_str(&format!("macro = \"{}\"\n", l.macro_name));
            s.push_str(&format!("kind = \"{}\"\n", l.kind.toml_name()));
            if let LevelKind::Standard { banks, ports } = l.kind {
                s.push_str(&format!("banks = {banks}\n"));
                s.push_str(&format!("ports = {}\n", ports.count()));
            }
            s.push_str(&format!("word_width = {}\n", l.word_width));
            s.push_str(&format!("ram_depth = {}\n", l.ram_depth));
            if l.protection != Protection::None {
                s.push_str(&format!("protection = \"{}\"\n", l.protection.toml_name()));
            }
        }
        if let Some(osr) = &self.osr {
            s.push_str("\n[osr]\n");
            s.push_str(&format!("width = {}\n", osr.width));
            let shifts: Vec<String> = osr.shifts.iter().map(|v| v.to_string()).collect();
            s.push_str(&format!("shifts = [{}]\n", shifts.join(", ")));
        }
        s
    }
}

/// Builder for [`HierarchyConfig`].
#[derive(Debug, Default)]
pub struct HierarchyBuilder {
    offchip: Option<OffchipConfig>,
    /// Pending input-buffer depth, applied at [`Self::build`] so the call
    /// order relative to [`Self::offchip`] does not matter.
    ib_depth: Option<u32>,
    /// Pending off-chip latency, applied at [`Self::build`].
    latency: Option<u64>,
    levels: Vec<LevelConfig>,
    osr: Option<OsrConfig>,
    preload: bool,
}

impl HierarchyBuilder {
    /// Off-chip interface: data width (bits), address width (bits), and
    /// external:internal clock ratio (>1 means the off-chip side is
    /// faster, as in the case study's 4:1).
    pub fn offchip(mut self, data_width: u32, addr_width: u32, clock_ratio: f64) -> Self {
        let (ext, int) = ratio_to_freqs(clock_ratio);
        self.offchip = Some(OffchipConfig {
            data_width,
            addr_width,
            latency: 1,
            external_hz: ext,
            internal_hz: int,
            ib_depth: 1,
        });
        self
    }

    /// Input-buffer FIFO depth (default 1 = the paper's single register).
    /// May be called before or after [`Self::offchip`]; the value is
    /// buffered and applied at [`Self::build`].
    pub fn ib_depth(mut self, depth: u32) -> Self {
        self.ib_depth = Some(depth);
        self
    }

    /// Off-chip read latency in external cycles. May be called before or
    /// after [`Self::offchip`]; the value is buffered and applied at
    /// [`Self::build`].
    pub fn offchip_latency(mut self, latency: u64) -> Self {
        self.latency = Some(latency);
        self
    }

    /// Append a standard hierarchy level: word width (bits), RAM depth
    /// (words per bank), bank count (1–2), port count (1–2).
    pub fn level(mut self, word_width: u32, ram_depth: u64, banks: u32, ports: u32) -> Self {
        self.levels.push(LevelConfig {
            macro_name: format!("sram_{ram_depth}x{word_width}"),
            kind: LevelKind::Standard {
                banks,
                ports: if ports >= 2 { PortKind::Dual } else { PortKind::Single },
            },
            word_width,
            ram_depth,
            protection: Protection::None,
        });
        self
    }

    /// Set the storage protection of the most recently appended level
    /// (no-op before the first `level*` call).
    pub fn protect(mut self, p: Protection) -> Self {
        if let Some(l) = self.levels.last_mut() {
            l.protection = p;
        }
        self
    }

    /// Append a double-buffered (ping-pong) level: word width (bits) and
    /// *total* depth in words (split into two half-depth single-ported
    /// macros; must be even).
    pub fn level_double_buffered(mut self, word_width: u32, total_depth: u64) -> Self {
        self.levels.push(LevelConfig {
            macro_name: format!("sram_pp_2x{}x{word_width}", total_depth / 2),
            kind: LevelKind::DoubleBuffered,
            word_width,
            ram_depth: total_depth,
            protection: Protection::None,
        });
        self
    }

    /// Configure the OSR with the given width and allowed shifts.
    pub fn osr(mut self, width: u32, shifts: Vec<u32>) -> Self {
        self.osr = Some(OsrConfig { width, shifts });
        self
    }

    /// Enable preloading (§5.2.1).
    pub fn preload(mut self, on: bool) -> Self {
        self.preload = on;
        self
    }

    /// Finish and validate.
    pub fn build(self) -> Result<HierarchyConfig> {
        let mut offchip = self.offchip.unwrap_or_default();
        if let Some(d) = self.ib_depth {
            offchip.ib_depth = d;
        }
        if let Some(l) = self.latency {
            offchip.latency = l;
        }
        let cfg = HierarchyConfig {
            offchip,
            levels: self.levels,
            osr: self.osr,
            preload: self.preload,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Turn a clock ratio into a pair of integral frequencies.
fn ratio_to_freqs(ratio: f64) -> (u64, u64) {
    assert!(ratio > 0.0, "clock ratio must be positive");
    // Express as a fraction with denominator up to 64.
    let mut best = (1u64, 1u64);
    let mut best_err = f64::INFINITY;
    for den in 1..=64u64 {
        let num = (ratio * den as f64).round().max(1.0) as u64;
        let err = (num as f64 / den as f64 - ratio).abs();
        if err < best_err {
            best_err = err;
            best = (num, den);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_level() -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level(32, 1024, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_and_capacity() {
        let cfg = two_level();
        assert_eq!(cfg.levels.len(), 2);
        assert_eq!(cfg.levels[0].capacity_words(), 1024);
        assert_eq!(cfg.levels[0].capacity_bits(), 1024 * 32);
        assert_eq!(
            cfg.last_level().kind,
            LevelKind::Standard { banks: 1, ports: PortKind::Dual }
        );
        assert!(cfg.last_level().dual_capable());
        assert_eq!(cfg.levels[0].kind.label(), 'S');
        assert_eq!(cfg.last_level().kind.label(), 'D');
    }

    #[test]
    fn double_buffered_level_builds() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap();
        let l = cfg.last_level();
        assert_eq!(l.kind, LevelKind::DoubleBuffered);
        assert_eq!(l.capacity_words(), 128, "total capacity spans both halves");
        assert_eq!(l.half_depth(), 64);
        assert!(l.dual_capable(), "fill and drain overlap by construction");
        assert_eq!(l.kind.label(), 'P');
        assert!(!l.kind.can_hold_resident_window());
    }

    #[test]
    fn double_buffered_depth_must_be_even() {
        assert!(HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level_double_buffered(32, 33)
            .build()
            .is_err());
        assert!(HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level_double_buffered(32, 0)
            .build()
            .is_err());
    }

    #[test]
    fn depth_limits() {
        let mut b = HierarchyConfig::builder().offchip(32, 20, 1.0);
        for _ in 0..6 {
            b = b.level(32, 64, 1, 1);
        }
        assert!(b.build().is_err(), "six levels rejected");
        assert!(HierarchyConfig::builder().offchip(32, 20, 1.0).build().is_err(), "zero levels rejected");
    }

    #[test]
    fn invalid_levels_rejected() {
        // 3 banks.
        assert!(HierarchyConfig::builder().offchip(32, 20, 1.0).level(32, 64, 3, 1).build().is_err());
        // dual-banked dual-ported.
        assert!(HierarchyConfig::builder().offchip(32, 20, 1.0).level(32, 64, 2, 2).build().is_err());
        // zero depth.
        assert!(HierarchyConfig::builder().offchip(32, 20, 1.0).level(32, 0, 1, 1).build().is_err());
        // width mismatch between levels.
        assert!(HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level(32, 64, 1, 1)
            .level(64, 64, 1, 2)
            .build()
            .is_err());
        // incompatible off-chip width (48 vs 32).
        assert!(HierarchyConfig::builder().offchip(48, 20, 1.0).level(32, 64, 1, 1).build().is_err());
    }

    #[test]
    fn osr_validation() {
        // OSR narrower than last level word width.
        assert!(HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level(128, 32, 1, 2)
            .osr(64, vec![32])
            .build()
            .is_err());
        // Case-study OSR: 384-bit from a 128-bit level.
        let cfg = HierarchyConfig::builder()
            .offchip(32, 20, 4.0)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .build()
            .unwrap();
        assert_eq!(cfg.osr.as_ref().unwrap().width, 384);
        // Zero shift rejected.
        assert!(HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level(32, 64, 1, 2)
            .osr(64, vec![0])
            .build()
            .is_err());
    }

    #[test]
    fn builder_offchip_tweaks_are_order_independent() {
        // ib_depth / offchip_latency used to be silently dropped when
        // called before .offchip(); both orders must now agree.
        let before = HierarchyConfig::builder()
            .ib_depth(8)
            .offchip_latency(3)
            .offchip(32, 20, 4.0)
            .level(32, 64, 1, 1)
            .build()
            .unwrap();
        let after = HierarchyConfig::builder()
            .offchip(32, 20, 4.0)
            .ib_depth(8)
            .offchip_latency(3)
            .level(32, 64, 1, 1)
            .build()
            .unwrap();
        assert_eq!(before, after);
        assert_eq!(before.offchip.ib_depth, 8);
        assert_eq!(before.offchip.latency, 3);
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 20, 4.0)
            .level(128, 104, 1, 2)
            .osr(384, vec![128, 384])
            .preload(true)
            .build()
            .unwrap();
        let s = cfg.to_toml();
        let back = HierarchyConfig::from_toml(&s).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn toml_roundtrip_double_buffered() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap();
        let s = cfg.to_toml();
        assert!(s.contains("kind = \"double_buffered\""), "{s}");
        let back = HierarchyConfig::from_toml(&s).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn protection_check_bits_and_markers() {
        assert_eq!(Protection::None.check_bits(32), 0);
        assert_eq!(Protection::Parity.check_bits(32), 1);
        // Hamming(39,32) plus the overall parity bit.
        assert_eq!(Protection::Secded.check_bits(32), 7);
        assert_eq!(Protection::Secded.check_bits(64), 8);
        assert_eq!(Protection::Secded.check_bits(1), 3);
        // Unprotected descriptors are byte-identical to the old format.
        let mut cfg = two_level();
        assert_eq!(cfg.levels[0].desc(), "1024x32S");
        cfg.levels[0].protection = Protection::Parity;
        cfg.levels[1].protection = Protection::Secded;
        assert_eq!(cfg.stack_desc(), "1024x32Sp+128x32De");
    }

    #[test]
    fn toml_roundtrip_protection() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 20, 1.0)
            .level(32, 512, 1, 1)
            .protect(Protection::Secded)
            .level_double_buffered(32, 128)
            .protect(Protection::Parity)
            .build()
            .unwrap();
        let s = cfg.to_toml();
        assert!(s.contains("protection = \"secded\""), "{s}");
        assert!(s.contains("protection = \"parity\""), "{s}");
        let back = HierarchyConfig::from_toml(&s).unwrap();
        assert_eq!(cfg, back);
        // Unprotected levels emit no protection key (byte-stable TOML).
        let plain = two_level().to_toml();
        assert!(!plain.contains("protection"), "{plain}");
        // Unknown protection values are config errors.
        assert!(HierarchyConfig::from_toml(
            "[[level]]\nword_width = 32\nram_depth = 64\nprotection = \"crc\"\n"
        )
        .is_err());
    }

    #[test]
    fn toml_kind_errors() {
        // Unknown kind.
        assert!(HierarchyConfig::from_toml(
            "[[level]]\nkind = \"triple_buffered\"\nword_width = 32\nram_depth = 64\n"
        )
        .is_err());
        // banks/ports on a double-buffered level.
        assert!(HierarchyConfig::from_toml(
            "[[level]]\nkind = \"double_buffered\"\nbanks = 2\nword_width = 32\nram_depth = 64\n"
        )
        .is_err());
    }

    #[test]
    fn toml_invalid_values_error_instead_of_defaulting() {
        // A present-but-malformed `banks` must be a config error, not a
        // silent fallback to 1.
        assert!(HierarchyConfig::from_toml(
            "[[level]]\nbanks = \"two\"\nword_width = 32\nram_depth = 64\n"
        )
        .is_err());
        // Same for ports and the offchip integers.
        assert!(HierarchyConfig::from_toml(
            "[[level]]\nports = true\nword_width = 32\nram_depth = 64\n"
        )
        .is_err());
        assert!(HierarchyConfig::from_toml(
            "[offchip]\ndata_width = \"wide\"\n\n[[level]]\nword_width = 32\nram_depth = 64\n"
        )
        .is_err());
        // Out-of-u32-range values are rejected, not silently truncated
        // (2^32 + 2 must not become banks = 2).
        assert!(HierarchyConfig::from_toml(
            "[[level]]\nbanks = 4294967298\nword_width = 32\nram_depth = 64\n"
        )
        .is_err());
        // Missing banks still defaults to 1.
        let cfg = HierarchyConfig::from_toml("[[level]]\nword_width = 32\nram_depth = 64\n")
            .unwrap();
        assert_eq!(
            cfg.levels[0].kind,
            LevelKind::Standard { banks: 1, ports: PortKind::Single }
        );
        // Malformed preload is an error too.
        assert!(HierarchyConfig::from_toml(
            "preload = 1\n\n[[level]]\nword_width = 32\nram_depth = 64\n"
        )
        .is_err());
    }

    #[test]
    fn toml_missing_level_errors() {
        assert!(HierarchyConfig::from_toml("[offchip]\ndata_width = 32\n").is_err());
    }

    #[test]
    fn clock_ratio_fractions() {
        let (e, i) = ratio_to_freqs(4.0);
        assert_eq!(e / i, 4);
        let (e, i) = ratio_to_freqs(0.5);
        assert_eq!((e, i), (1, 2));
        let (e, i) = ratio_to_freqs(1.5);
        assert_eq!(e * 2, i * 3);
    }
}

//! Configuration system.
//!
//! Mirrors the SystemVerilog template parameters of §4.1: off-chip
//! interface (data width, address width), hierarchy depth (1–5), per-level
//! configuration (memory macro, level kind, word width, RAM depth), and
//! the optional OSR (bit width + available shifts). The per-level
//! [`LevelKind`] selects the datapath behavior: a standard banked level
//! (1–2 banks, single/dual ported) or a double-buffered ping-pong pair
//! (§6 future work).
//!
//! Configs can be built programmatically ([`HierarchyConfig::builder`]) or
//! loaded from a TOML-subset file ([`toml_mini`], an in-tree parser — the
//! build environment has no `toml` crate). `configs/` in the repo root
//! ships the paper's evaluation configurations.

pub mod hierarchy;
pub mod toml_mini;

pub use hierarchy::{
    HierarchyBuilder, HierarchyConfig, LevelConfig, LevelKind, OffchipConfig, OsrConfig,
    PortKind, Protection, MAX_LEVELS,
};
pub use toml_mini::{parse as parse_toml, TomlValue};

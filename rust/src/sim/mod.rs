//! Cycle-simulation substrate.
//!
//! The paper's framework straddles two clock domains (§4.1.3, Figure 3):
//! the input buffer runs on the off-chip µC clock (`external_clk_i`) while
//! the hierarchy runs on the accelerator clock (`internal_clk_i`). The
//! UltraTrail case study clocks them at 1 MHz and 250 kHz respectively.
//!
//! [`ClockPair`] schedules edges of both domains on a common time base;
//! [`SimStats`] aggregates per-run counters; [`trace`] captures signal
//! waveforms and can render them as VCD for inspection (Fig 4 style);
//! [`engine`] is the stage-based simulation engine that drives a
//! composition of [`engine::Stage`]s (the hierarchy, or any future core)
//! with deterministic clock interleaving, deadlock detection, output
//! verification, and waveform capture; [`batch`] layers warm-reusable
//! sessions on top of it — many programs executed back-to-back on one
//! hierarchy whose storage is re-armed, never reallocated; [`fault`]
//! schedules deterministic seeded upsets (bit flips, stuck-at cells,
//! delayed/dropped deliveries) into any stateful component and
//! aggregates AVF-style vulnerability across campaign sweeps.

pub mod batch;
pub mod clock;
pub mod engine;
pub mod fault;
pub mod stats;
pub mod trace;

pub use batch::Session;
pub use clock::{ClockDomain, ClockPair, Edge};
pub use fault::{
    run_campaign, run_campaign_protected, FaultCampaignStats, FaultComponent, FaultEvent,
    FaultKind, FaultOutcome, FaultPlan, FaultReport, FaultSite, FaultState, Tally,
};
pub use engine::{
    BudgetOutcome, Core, CycleCtx, Engine, EngineRun, Horizon, OutputSink, OutputWord, Stage,
    StreamSpec,
};
pub use stats::SimStats;
pub use trace::{Waveform, WaveformProbe};

//! Per-run simulation statistics.
//!
//! Everything the evaluation section reports is derived from these
//! counters: clock cycles to produce N outputs (Figs 5, 6, 8, 10), off-chip
//! access counts (energy model input), port-conflict stalls, and the
//! initialization (fill) phase length that preloading hides (§5.2.1).

/// Counters accumulated over one simulation run.
///
/// ## Equality
///
/// `PartialEq` compares the **simulation semantics** only: the
/// fast-forward diagnostics (`skipped_cycles`, `ff_jumps`) are excluded,
/// so a fast-forwarded run and a `force_naive` run of the same program
/// compare equal — which is exactly the bit-identity the engine
/// guarantees (see [`crate::sim::engine`]) and what the differential
/// tests assert.
#[derive(Debug, Default, Clone)]
pub struct SimStats {
    /// Internal (accelerator-domain) cycles elapsed.
    pub internal_cycles: u64,
    /// External (off-chip-domain) cycles elapsed.
    pub external_cycles: u64,
    /// Data words delivered to the accelerator (or OSR outputs if an OSR
    /// is configured).
    pub outputs: u64,
    /// Words fetched from the off-chip memory.
    pub offchip_reads: u64,
    /// Per-level word writes (index = hierarchy level).
    pub level_writes: Vec<u64>,
    /// Per-level word reads.
    pub level_reads: Vec<u64>,
    /// Per-level cycles in which a ready read was postponed by the
    /// write-over-read policy (single-ported conflict, Fig 4).
    pub write_over_read_stalls: Vec<u64>,
    /// Per-level cycles in which a write had to wait (no empty slot or no
    /// upstream data).
    pub write_waits: Vec<u64>,
    /// Cycles the output port idled while outputs were still pending.
    pub output_stalls: u64,
    /// Internal cycle at which the first output was produced (fill /
    /// initialization latency; preloading removes it from the run).
    pub first_output_cycle: Option<u64>,
    /// OSR shifts executed.
    pub osr_shifts: u64,
    /// Words transferred across the CDC (input buffer -> level 0).
    pub cdc_transfers: u64,
    /// Internal cycles the engine fast-forwarded through in closed form
    /// instead of ticking (event-horizon skips; see
    /// [`crate::sim::engine`]). Diagnostics only — excluded from
    /// `PartialEq`, zero under `force_naive`.
    pub skipped_cycles: u64,
    /// Fast-forward jumps the engine performed. Diagnostics only —
    /// excluded from `PartialEq` like `skipped_cycles` (a budget or
    /// checkpoint boundary may split one naive-equivalent span into two
    /// jumps).
    pub ff_jumps: u64,
}

impl PartialEq for SimStats {
    /// Simulation-semantics equality (see the type docs): every counter
    /// except the fast-forward diagnostics. Destructured so a newly added
    /// counter must be classified here explicitly.
    fn eq(&self, other: &Self) -> bool {
        let Self {
            internal_cycles,
            external_cycles,
            outputs,
            offchip_reads,
            level_writes,
            level_reads,
            write_over_read_stalls,
            write_waits,
            output_stalls,
            first_output_cycle,
            osr_shifts,
            cdc_transfers,
            skipped_cycles: _,
            ff_jumps: _,
        } = self;
        *internal_cycles == other.internal_cycles
            && *external_cycles == other.external_cycles
            && *outputs == other.outputs
            && *offchip_reads == other.offchip_reads
            && *level_writes == other.level_writes
            && *level_reads == other.level_reads
            && *write_over_read_stalls == other.write_over_read_stalls
            && *write_waits == other.write_waits
            && *output_stalls == other.output_stalls
            && *first_output_cycle == other.first_output_cycle
            && *osr_shifts == other.osr_shifts
            && *cdc_transfers == other.cdc_transfers
    }
}

impl SimStats {
    /// Serialize for the checkpoint wire format (destructured so a newly
    /// added counter must be encoded here explicitly). The fast-forward
    /// diagnostics are carried too: a restored run reports the same
    /// diagnostics an uninterrupted one would.
    pub(crate) fn wire_write(&self, w: &mut crate::util::frame::ByteWriter) {
        let Self {
            internal_cycles,
            external_cycles,
            outputs,
            offchip_reads,
            level_writes,
            level_reads,
            write_over_read_stalls,
            write_waits,
            output_stalls,
            first_output_cycle,
            osr_shifts,
            cdc_transfers,
            skipped_cycles,
            ff_jumps,
        } = self;
        w.put_u64(*internal_cycles);
        w.put_u64(*external_cycles);
        w.put_u64(*outputs);
        w.put_u64(*offchip_reads);
        for counts in [level_writes, level_reads, write_over_read_stalls, write_waits] {
            w.put_u32(counts.len() as u32);
            for c in counts {
                w.put_u64(*c);
            }
        }
        w.put_u64(*output_stalls);
        w.put_bool(first_output_cycle.is_some());
        w.put_u64(first_output_cycle.unwrap_or(0));
        w.put_u64(*osr_shifts);
        w.put_u64(*cdc_transfers);
        w.put_u64(*skipped_cycles);
        w.put_u64(*ff_jumps);
    }

    /// Checked decode of [`Self::wire_write`] output.
    pub(crate) fn wire_read(r: &mut crate::util::frame::ByteReader<'_>) -> crate::Result<Self> {
        let internal_cycles = r.get_u64()?;
        let external_cycles = r.get_u64()?;
        let outputs = r.get_u64()?;
        let offchip_reads = r.get_u64()?;
        let mut vecs = [Vec::new(), Vec::new(), Vec::new(), Vec::new()];
        for v in &mut vecs {
            let n = r.get_count(8)?;
            v.reserve(n);
            for _ in 0..n {
                v.push(r.get_u64()?);
            }
        }
        let [level_writes, level_reads, write_over_read_stalls, write_waits] = vecs;
        let output_stalls = r.get_u64()?;
        let has_first = r.get_bool()?;
        let first_raw = r.get_u64()?;
        Ok(Self {
            internal_cycles,
            external_cycles,
            outputs,
            offchip_reads,
            level_writes,
            level_reads,
            write_over_read_stalls,
            write_waits,
            output_stalls,
            first_output_cycle: has_first.then_some(first_raw),
            osr_shifts: r.get_u64()?,
            cdc_transfers: r.get_u64()?,
            skipped_cycles: r.get_u64()?,
            ff_jumps: r.get_u64()?,
        })
    }

    /// Create stats sized for `levels` hierarchy levels.
    pub fn new(levels: usize) -> Self {
        Self {
            level_writes: vec![0; levels],
            level_reads: vec![0; levels],
            write_over_read_stalls: vec![0; levels],
            write_waits: vec![0; levels],
            ..Default::default()
        }
    }

    /// Zero every counter in place, re-sizing the per-level vectors for
    /// `levels` hierarchy levels. Equivalent to `*self =
    /// SimStats::new(levels)` but keeps the vector allocations — the
    /// warm-session re-arm path calls this once per program load.
    pub fn reset(&mut self, levels: usize) {
        self.internal_cycles = 0;
        self.external_cycles = 0;
        self.outputs = 0;
        self.offchip_reads = 0;
        reset_counts(&mut self.level_writes, levels);
        reset_counts(&mut self.level_reads, levels);
        reset_counts(&mut self.write_over_read_stalls, levels);
        reset_counts(&mut self.write_waits, levels);
        self.output_stalls = 0;
        self.first_output_cycle = None;
        self.osr_shifts = 0;
        self.cdc_transfers = 0;
        self.skipped_cycles = 0;
        self.ff_jumps = 0;
    }

    /// Outputs per internal cycle — the paper's efficiency metric
    /// (Fig 10: "100 % represents one data word output in each clock
    /// cycle").
    pub fn efficiency(&self) -> f64 {
        if self.internal_cycles == 0 {
            return 0.0;
        }
        self.outputs as f64 / self.internal_cycles as f64
    }

    /// Efficiency ignoring the initial fill phase (what preloading
    /// achieves, §5.2.1).
    pub fn steady_state_efficiency(&self) -> f64 {
        match self.first_output_cycle {
            None => 0.0,
            Some(f) => {
                let active = self.internal_cycles.saturating_sub(f);
                if active == 0 {
                    0.0
                } else {
                    self.outputs as f64 / active as f64
                }
            }
        }
    }

    /// Average off-chip reads per output — data-reuse effectiveness.
    pub fn offchip_reads_per_output(&self) -> f64 {
        if self.outputs == 0 {
            return 0.0;
        }
        self.offchip_reads as f64 / self.outputs as f64
    }
}

/// Zero a counter vector in place at the given length (keeps capacity).
fn reset_counts(v: &mut Vec<u64>, n: usize) {
    v.clear();
    v.resize(n, 0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_matches_fresh() {
        let mut s = SimStats::new(2);
        s.internal_cycles = 7;
        s.level_writes[1] = 3;
        s.first_output_cycle = Some(4);
        s.skipped_cycles = 9;
        s.ff_jumps = 2;
        s.reset(3);
        assert_eq!(s, SimStats::new(3));
        assert_eq!(s.skipped_cycles, 0, "reset zeroes the ff diagnostics");
        assert_eq!(s.ff_jumps, 0);
        s.reset(1);
        assert_eq!(s, SimStats::new(1));
    }

    #[test]
    fn equality_ignores_ff_diagnostics() {
        // A fast-forwarded run and a naive run of the same program differ
        // only in the skip accounting; they must compare equal.
        let mut a = SimStats::new(1);
        a.internal_cycles = 100;
        let mut b = a.clone();
        b.skipped_cycles = 64;
        b.ff_jumps = 3;
        assert_eq!(a, b);
        b.internal_cycles = 101;
        assert_ne!(a, b, "semantic counters still compare");
    }

    #[test]
    fn efficiency_metrics() {
        let mut s = SimStats::new(2);
        s.internal_cycles = 200;
        s.outputs = 100;
        s.first_output_cycle = Some(100);
        assert!((s.efficiency() - 0.5).abs() < 1e-12);
        assert!((s.steady_state_efficiency() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_guards() {
        let s = SimStats::new(1);
        assert_eq!(s.efficiency(), 0.0);
        assert_eq!(s.steady_state_efficiency(), 0.0);
        assert_eq!(s.offchip_reads_per_output(), 0.0);
    }

    #[test]
    fn reuse_metric() {
        let mut s = SimStats::new(1);
        s.outputs = 1000;
        s.offchip_reads = 100;
        assert!((s.offchip_reads_per_output() - 0.1).abs() < 1e-12);
    }
}

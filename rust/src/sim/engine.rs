//! The stage-based simulation engine.
//!
//! Before this layer existed, `mem::Hierarchy` owned *everything*: the
//! two-domain clock interleaving, the deadlock guard, the stats lifetime,
//! the end-to-end output verifier, output collection, waveform capture —
//! and the per-cycle datapath scheduling, all tangled into one `run`
//! loop. This module extracts the reusable simulation machinery so the
//! hierarchy (and any future core: new level kinds, batched co-simulation
//! front-ends) is a thin composition:
//!
//! * [`Stage`] — the contract one datapath component satisfies: hooks for
//!   the two clock-domain edges plus the elastic-port handshake
//!   (`ready_out` = "a word is presented downstream", `ready_in` = "a
//!   word of this width can be latched"). `mem::{Level, InputBuffer,
//!   Osr, OffChipMemory}` all implement it. Data *movement* between
//!   stages stays in the composing core's scheduler — exactly like RTL,
//!   where the enclosing module owns the port wiring while each
//!   submodule owns its edge behavior.
//! * [`Core`] — a composition of stages the engine can drive: one
//!   callback per clock-domain edge plus program-size queries.
//! * [`Engine`] — owns the [`ClockPair`] edge interleaving, the
//!   [`SimStats`] lifetime, the no-progress deadlock guard, the preload
//!   phase, the [`OutputSink`] (verification + collection), and waveform
//!   storage. `Engine::run` reproduces the exact per-edge schedule the
//!   monolithic `Hierarchy::run` had, so cycle counts are unchanged.
//! * [`OutputSink`] — the engine-owned output port: verifies every
//!   emitted word against the expected shifted-cyclic unit stream and
//!   the deterministic payload function ([`StreamSpec`]), tracks
//!   progress, and (optionally) collects outputs using pooled address
//!   buffers so steady-state collection does not allocate per output.
//!
//! ## Determinism guarantee
//!
//! The engine is single-threaded and consumes no ambient state (no time,
//! no RNG): given the same `Core` state and the same [`StreamSpec`], the
//! edge schedule, stats, and output stream are bit-for-bit reproducible.
//! This is what `dse::pool` builds on — each worker drives its own
//! engine, and a parallel sweep is indistinguishable from a serial one.
//!
//! ## Event-horizon fast-forward
//!
//! Stall-heavy configurations (deep off-chip latency, a depth-1 input
//! buffer) spend most of their edges doing nothing: the whole hierarchy
//! is waiting out an off-chip read that is still `k` external cycles
//! away. The engine skips those spans in O(1) instead of ticking through
//! them, while staying **bit-identical** to the naive loop:
//!
//! * Each [`Stage`] reports a *quiescence horizon*
//!   ([`Stage::quiescent_for`]): how many upcoming edges in its own clock
//!   domain provably cannot change its registered state, absent port
//!   handshakes. A drained CDC synchronizer or a released write-enable
//!   toggle promises `u64::MAX`; a mid-flight flop promises `0`.
//! * The composing [`Core`] folds the per-stage horizons together with
//!   the port-handshake picture into a whole-core [`Horizon`]: either
//!   `Active` (the next edge may change state) or `Quiescent` with the
//!   external-cycle index of the next wake-up event (typically the
//!   in-flight off-chip delivery), or no wake-up at all.
//! * The engine turns a quiescent horizon into a bulk jump: it advances
//!   the [`ClockPair`] in closed form
//!   ([`ClockPair::skip_to_external_cycle`] /
//!   [`ClockPair::skip_internal_edges`]), bulk-advances the cycle
//!   counters and the per-cycle `output_stalls` tick, and caps the jump
//!   at the run's budget target, the no-progress watermark, and (during
//!   preload) the saturation window — so budget exits, deadlock
//!   diagnostics, and preload termination land on **exactly** the edge
//!   the naive loop would have stopped on.
//!
//! A quiescent edge is by definition a no-op on component state, so a
//! skipped span leaves every stage register, checkpoint, and waveform
//! change-list identical to ticking through it (inactive cycles record
//! only unchanged zero strobes, which the sparse waveform deduplicates).
//! Only `SimStats::skipped_cycles` / `SimStats::ff_jumps` reveal that a
//! jump happened, and those are excluded from stats equality.
//!
//! **What a stage may promise:** only state it fully owns, conditioned on
//! its *current* inputs — "absent handshakes" is safe because any
//! handshake implies another part of the core was active, which the
//! composition checks first. A stage must never under-report (claim a
//! longer dead span than real): in debug builds, runs with
//! [`Engine::set_force_naive`] validate every claimed-quiescent edge
//! against the executed edge and panic on a state change, which is how
//! the differential test suite polices the contract across the whole
//! config matrix. Over-reporting activity (claiming `Active` while dead)
//! merely costs performance.
//!
//! [`Engine::set_force_naive`] keeps the tick-per-cycle loop available as
//! the differential-testing oracle and for A/B wall-clock measurements
//! (`benches/engine_throughput.rs`).

use crate::sim::{ClockDomain, ClockPair, SimStats, Waveform};
use crate::util::bitword::Word;
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};

/// One word delivered to the accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct OutputWord {
    /// Source off-chip addresses (LSB-first sub-words).
    pub addrs: Vec<u64>,
    /// Payload bits.
    pub word: Word,
}

impl OutputWord {
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self { addrs, word } = self;
        w.put_u32(addrs.len() as u32);
        for a in addrs {
            w.put_u64(*a);
        }
        word.wire_write(w);
    }

    pub(crate) fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        let n = r.get_count(8)?;
        let mut addrs = Vec::with_capacity(n);
        for _ in 0..n {
            addrs.push(r.get_u64()?);
        }
        Ok(Self { addrs, word: Word::wire_read(r)? })
    }
}

/// Progress guard: a run with no output progress for this many internal
/// cycles is declared deadlocked (a scheduling bug, not a configuration
/// property — valid configurations always make progress).
pub const DEADLOCK_LIMIT: u64 = 200_000;

/// The per-component stage contract (see module docs).
///
/// All methods have no-op defaults so a stage only implements the hooks
/// that apply to its clock domain and ports.
pub trait Stage {
    /// Internal (accelerator-domain) clock edge: registered state the
    /// stage updates on its own, e.g. the input buffer's CDC
    /// synchronizer shift.
    fn on_internal_edge(&mut self) {}

    /// External (off-chip-domain) clock edge for self-contained stages.
    /// Stages whose external behavior needs bus access (the input
    /// buffer's fill engine talking to the off-chip memory) are driven
    /// by the core's scheduler instead.
    fn on_external_edge(&mut self, _ext_cycle: u64) {}

    /// Port handshake: the stage presents a word to its downstream
    /// consumer this cycle.
    fn ready_out(&self) -> bool {
        false
    }

    /// Port handshake: the stage can latch an incoming word of `width`
    /// bits this cycle.
    fn ready_in(&self, _width: u32) -> bool {
        false
    }

    /// Quiescence horizon: the number of upcoming edges in this stage's
    /// own clock domain(s) during which its observable state provably
    /// cannot change, **assuming no port handshake fires** (handshakes
    /// are the composing core's concern and checked there). `0` means the
    /// very next edge may change state; `u64::MAX` means the stage is
    /// inert until an input arrives (e.g. its edge hooks are no-ops, or a
    /// synchronizer has fully settled).
    ///
    /// The contract is one-sided: a stage must never claim a longer dead
    /// span than real (the engine skips edges on the strength of it; see
    /// the module docs for how debug builds validate this), while
    /// reporting `0` is always sound — it merely disables skipping. The
    /// default is therefore `0`.
    fn quiescent_for(&self) -> u64 {
        0
    }

    /// Fault-injection hook (see [`crate::sim::fault`]): perturb the
    /// stage's stored state as `site` directs, returning whether any
    /// stored bit actually changed (`false` = the site is vacant or out
    /// of range, i.e. the upset landed in storage the run is not using).
    /// The default ignores every fault — a stage without the hook simply
    /// has no injectable state — and a stage with no *scheduled* faults
    /// is never called, so the hook is provably inert on fault-free runs.
    fn inject(&mut self, _site: &crate::sim::fault::FaultSite) -> bool {
        false
    }
}

/// How long a [`Core`]'s observable state provably cannot change — the
/// composed per-stage quiescence picture the engine turns into a bulk
/// clock jump (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Horizon {
    /// The next edge may change state: tick normally.
    Active,
    /// No edge changes any component state until the wake-up event; only
    /// the closed-form per-cycle counters (cycle counts, output-stall
    /// ticks) advance.
    Quiescent {
        /// Cycle index of the external edge at which state can next
        /// change (typically the in-flight off-chip delivery); `None` if
        /// no upcoming edge can ever change state (nothing in flight,
        /// nothing to issue — the engine then runs straight into the
        /// budget exit or the no-progress diagnostic).
        until_ext: Option<u64>,
        /// Whether the core's output port is enabled: skipped internal
        /// cycles then accrue `output_stalls` in closed form, exactly as
        /// the ticked loop would.
        output_gated: bool,
    },
}

/// Expected-output-stream specification: the shifted-cyclic unit stream
/// (in off-chip units) plus the deterministic payload function, used by
/// the engine's verifier.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    /// First off-chip address of the stream.
    pub start_address: u64,
    /// Address stride between consecutive units.
    pub stride: u64,
    /// Pattern cycle length in off-chip units.
    pub cycle_length: u64,
    /// Inter-cycle shift in off-chip units.
    pub inter_cycle_shift: u64,
    /// Completed cycles before each shift is applied.
    pub skip_shift: u64,
    /// Off-chip word width in bits (one unit).
    pub sub_width: u32,
    /// Total off-chip units the program emits.
    pub total_units: u64,
    /// Deterministic payload for an address (the end-to-end integrity
    /// check's ground truth).
    pub payload: fn(u64, u32) -> Word,
}

impl StreamSpec {
    /// An idle spec (no program loaded): zero units expected.
    pub fn idle(sub_width: u32, payload: fn(u64, u32) -> Word) -> Self {
        Self {
            start_address: 0,
            stride: 1,
            cycle_length: 1,
            inter_cycle_shift: 1,
            skip_shift: 0,
            sub_width,
            total_units: 0,
            payload,
        }
    }
}

/// Incremental expected-unit-stream generator (shifted-cyclic in off-chip
/// units), mirroring `AccessPattern::stream` without allocation.
#[derive(Debug, Clone, PartialEq, Eq)]
struct VerifyState {
    l: u64,
    s: u64,
    k: u64,
    ptr: u64,
    offset: u64,
    skips: u64,
}

impl VerifyState {
    fn from_spec(spec: &StreamSpec) -> Self {
        Self {
            l: spec.cycle_length,
            s: spec.inter_cycle_shift,
            k: spec.skip_shift,
            ptr: 0,
            offset: 0,
            skips: 0,
        }
    }

    fn next_unit(&mut self) -> u64 {
        let u = self.offset + self.ptr;
        self.ptr += 1;
        if self.ptr == self.l {
            self.ptr = 0;
            self.skips += 1;
            if self.skips > self.k {
                self.skips = 0;
                self.offset += self.s;
            }
        }
        u
    }

    fn wire_write(&self, w: &mut ByteWriter) {
        let Self { l, s, k, ptr, offset, skips } = self;
        w.put_u64(*l);
        w.put_u64(*s);
        w.put_u64(*k);
        w.put_u64(*ptr);
        w.put_u64(*offset);
        w.put_u64(*skips);
    }

    fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self {
            l: r.get_u64()?,
            s: r.get_u64()?,
            k: r.get_u64()?,
            ptr: r.get_u64()?,
            offset: r.get_u64()?,
            skips: r.get_u64()?,
        })
    }
}

/// Upper bound on pooled collection buffers kept across runs.
const ADDR_POOL_CAP: usize = 4_096;

/// The engine-owned output port: progress tracking, end-to-end
/// verification, and pooled collection.
#[derive(Debug)]
pub struct OutputSink {
    spec: StreamSpec,
    verify: bool,
    collect: bool,
    verify_state: VerifyState,
    units_out: u64,
    collected: Vec<OutputWord>,
    /// Recycled address buffers for collected outputs (no per-output
    /// allocation in steady state once the pool is warm).
    addr_pool: Vec<Vec<u64>>,
}

impl OutputSink {
    fn new(spec: StreamSpec) -> Self {
        let verify_state = VerifyState::from_spec(&spec);
        Self {
            spec,
            verify: true,
            collect: false,
            verify_state,
            units_out: 0,
            collected: Vec::new(),
            addr_pool: Vec::new(),
        }
    }

    /// Re-arm for a new program: reset progress and the verifier, recycle
    /// any collected buffers into the pool (in place — re-arming allocates
    /// nothing). Verify/collect switches are sticky across programs (they
    /// are operator settings, not program state).
    fn arm(&mut self, spec: StreamSpec) {
        self.verify_state = VerifyState::from_spec(&spec);
        self.spec = spec;
        self.units_out = 0;
        for ow in self.collected.drain(..) {
            if self.addr_pool.len() >= ADDR_POOL_CAP {
                break;
            }
            self.addr_pool.push(ow.addrs);
        }
    }

    /// Off-chip units emitted so far.
    pub fn units_out(&self) -> u64 {
        self.units_out
    }

    /// Whether all programmed units have been emitted.
    pub fn complete(&self) -> bool {
        self.units_out >= self.spec.total_units
    }

    /// Return output buffers to the allocation pool (callers that consume
    /// `RunResult::outputs` in a loop can hand the vectors back to keep
    /// collection allocation-free across runs).
    pub fn recycle(&mut self, outputs: Vec<OutputWord>) {
        for ow in outputs {
            if self.addr_pool.len() >= ADDR_POOL_CAP {
                break;
            }
            self.addr_pool.push(ow.addrs);
        }
    }

    fn take_collected(&mut self) -> Vec<OutputWord> {
        std::mem::take(&mut self.collected)
    }

    /// Capture the sink's program-progress state (verifier cursor, unit
    /// counter, collected outputs), plus the capture-time verify/collect
    /// switches as a compatibility key: the cursor and the collected list
    /// are only meaningful under the same settings, so a restore onto a
    /// sink with different switches is refused upstream
    /// ([`crate::mem::Hierarchy::restore`]). The switches themselves and
    /// the buffer pool stay session resources — restore never changes
    /// them.
    fn snapshot(&self) -> SinkCheckpoint {
        SinkCheckpoint {
            verify: self.verify,
            collect: self.collect,
            verify_state: self.verify_state.clone(),
            units_out: self.units_out,
            collected: self.collected.clone(),
        }
    }

    /// Restore a [`SinkCheckpoint`] taken on an identically armed sink
    /// (the switch-compatibility check happens upstream).
    fn restore(&mut self, ck: &SinkCheckpoint) {
        self.verify_state.clone_from(&ck.verify_state);
        self.units_out = ck.units_out;
        self.collected.clone_from(&ck.collected);
    }

    /// Record an emitted output word; verify its addresses against the
    /// expected pattern stream and its payload against the payload
    /// function. Allocation-free unless collection is enabled (and then
    /// pooled).
    pub fn emit(
        &mut self,
        addrs: &[u64],
        word: Word,
        cycle: u64,
        stats: &mut SimStats,
    ) -> Result<()> {
        let w_off = self.spec.sub_width;
        if self.verify {
            for (j, &addr) in addrs.iter().enumerate() {
                let unit = self.verify_state.next_unit();
                let expect_addr = self.spec.start_address + unit * self.spec.stride;
                if addr != expect_addr {
                    return Err(Error::Integrity {
                        cycle,
                        msg: format!(
                            "output unit {} address {addr:#x} != expected {expect_addr:#x}",
                            self.units_out + j as u64
                        ),
                    });
                }
                let expect_payload = (self.spec.payload)(addr, w_off);
                if word.bits(j as u32 * w_off, w_off) != expect_payload {
                    return Err(Error::Integrity {
                        cycle,
                        msg: format!("payload corruption at address {addr:#x}"),
                    });
                }
            }
        }
        self.units_out += addrs.len() as u64;
        stats.outputs += 1;
        if stats.first_output_cycle.is_none() {
            stats.first_output_cycle = Some(cycle);
        }
        if self.collect {
            let mut buf = self.addr_pool.pop().unwrap_or_default();
            buf.clear();
            buf.extend_from_slice(addrs);
            self.collected.push(OutputWord { addrs: buf, word });
        }
        Ok(())
    }
}

/// Per-internal-cycle context handed to [`Core::internal_edge`].
pub struct CycleCtx<'a> {
    /// Internal cycle index (0-based).
    pub cycle: u64,
    /// Run counters.
    pub stats: &'a mut SimStats,
    /// The output port (emission, progress queries).
    pub sink: &'a mut OutputSink,
    /// Waveform storage, if capture is attached; cores record their
    /// strobes through their registered probes.
    pub wave: Option<&'a mut Waveform>,
}

/// A composition of [`Stage`]s the engine can drive.
pub trait Core {
    /// One external (off-chip-domain) clock edge: fill engines, off-chip
    /// request/response stepping.
    fn external_edge(&mut self, ext_cycle: u64);

    /// One internal (accelerator-domain) clock edge: the datapath
    /// schedule. Emitted outputs go through `ctx.sink`.
    fn internal_edge(&mut self, ctx: &mut CycleCtx<'_>) -> Result<()>;

    /// Gate the output port (`disable_output_i`); the engine holds
    /// outputs disabled during the preload phase.
    fn set_output_enabled(&mut self, on: bool);

    /// Total off-chip units the loaded program emits.
    fn total_units(&self) -> u64;

    /// End-of-run counter flush (counters that live inside components,
    /// e.g. off-chip read totals).
    fn flush_stats(&mut self, stats: &mut SimStats);

    /// The core's composed quiescence horizon (see [`Horizon`] and the
    /// module docs). `sink_complete` is whether the output sink has
    /// emitted every programmed unit (it gates emission paths);
    /// `next_ext_cycle` is the cycle index of the next external edge
    /// (for comparing against in-flight deadlines). The default never
    /// fast-forwards, which is always sound.
    fn horizon(&self, sink_complete: bool, next_ext_cycle: u64) -> Horizon {
        let _ = (sink_complete, next_ext_cycle);
        Horizon::Active
    }

    /// Whether the most recently executed edge (either domain) changed
    /// any component state. Backs the debug validation of claimed
    /// horizons (module docs); the conservative default pairs with the
    /// default `horizon`.
    fn last_edge_active(&self) -> bool {
        true
    }

    /// Upper bound, in **external** cycles, on the handshake round trip
    /// of one input word: issue-to-delivery latency plus per-sub-word
    /// transfer and handshake-reset slack. The engine derives the preload
    /// saturation window from it (see [`Engine::run_budget`]'s preload
    /// phase).
    fn handshake_round_trip_ext(&self) -> u64 {
        2
    }
}

/// Captured output-sink run state (part of [`EngineCheckpoint`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SinkCheckpoint {
    /// Verify switch at capture time (compatibility key, not restored).
    verify: bool,
    /// Collect switch at capture time (compatibility key, not restored).
    collect: bool,
    verify_state: VerifyState,
    units_out: u64,
    collected: Vec<OutputWord>,
}

impl SinkCheckpoint {
    fn wire_write(&self, w: &mut ByteWriter) {
        let Self { verify, collect, verify_state, units_out, collected } = self;
        w.put_bool(*verify);
        w.put_bool(*collect);
        verify_state.wire_write(w);
        w.put_u64(*units_out);
        w.put_u32(collected.len() as u32);
        for ow in collected {
            ow.wire_write(w);
        }
    }

    fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        let verify = r.get_bool()?;
        let collect = r.get_bool()?;
        let verify_state = VerifyState::wire_read(r)?;
        let units_out = r.get_u64()?;
        let n = r.get_count(8)?;
        let mut collected = Vec::with_capacity(n);
        for _ in 0..n {
            collected.push(OutputWord::wire_read(r)?);
        }
        Ok(Self { verify, collect, verify_state, units_out, collected })
    }
}

/// Captured engine state at an internal-cycle boundary: the clock-pair
/// positions, the full [`SimStats`], the output sink's progress, and the
/// deadlock-guard watermark (so the no-progress window spans a
/// suspend/resume boundary exactly as it would an uninterrupted run).
/// Together with the core components' checkpoints this is everything a
/// suspended run needs to continue bit-identically on any engine armed
/// for the same program — see
/// [`Hierarchy::snapshot`](crate::mem::Hierarchy::snapshot).
///
/// The verify/collect switches are recorded as a **compatibility key**
/// (see [`Self::captured_verify`]/[`Self::captured_collect`]) but never
/// restored — they are operator settings that belong to the session, like
/// the deadlock limit. Waveform storage is not captured at all (capture
/// across a suspend/resume boundary is unsupported).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineCheckpoint {
    clocks: ClockPair,
    stats: SimStats,
    sink: SinkCheckpoint,
    last_progress_cycle: u64,
    last_units: u64,
}

impl EngineCheckpoint {
    /// Internal cycles consumed at the capture point.
    pub fn internal_cycles(&self) -> u64 {
        self.stats.internal_cycles
    }

    /// Off-chip units emitted at the capture point.
    pub fn units_out(&self) -> u64 {
        self.sink.units_out
    }

    /// The verify switch at capture time (the compatibility key a restore
    /// target must match).
    pub fn captured_verify(&self) -> bool {
        self.sink.verify
    }

    /// The collect switch at capture time (the compatibility key a
    /// restore target must match).
    pub fn captured_collect(&self) -> bool {
        self.sink.collect
    }

    /// Serialize for the checkpoint wire format (destructured so a newly
    /// added field must be encoded here explicitly).
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self { clocks, stats, sink, last_progress_cycle, last_units } = self;
        clocks.wire_write(w);
        stats.wire_write(w);
        sink.wire_write(w);
        w.put_u64(*last_progress_cycle);
        w.put_u64(*last_units);
    }

    /// Checked decode of [`Self::wire_write`] output.
    pub(crate) fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self {
            clocks: ClockPair::wire_read(r)?,
            stats: SimStats::wire_read(r)?,
            sink: SinkCheckpoint::wire_read(r)?,
            last_progress_cycle: r.get_u64()?,
            last_units: r.get_u64()?,
        })
    }
}

/// Result of one engine run.
#[derive(Debug)]
pub struct EngineRun {
    /// Counters for the (post-preload) run.
    pub stats: SimStats,
    /// Internal cycles spent in the preload phase (0 if preload
    /// disabled).
    pub preload_cycles: u64,
    /// Collected outputs (only if collection was enabled).
    pub outputs: Vec<OutputWord>,
}

/// Outcome of a cycle-budgeted run ([`Engine::run_budget`]).
#[derive(Debug)]
pub enum BudgetOutcome {
    /// The program completed within the budget; the run is exactly what an
    /// unbudgeted [`Engine::run`] would have produced.
    Complete(EngineRun),
    /// The budget expired first; the run is suspended mid-program (the
    /// caller may keep stepping or re-arm).
    Partial {
        /// Internal cycles consumed so far.
        cycles: u64,
        /// Off-chip units emitted so far.
        units_out: u64,
    },
}

/// The simulation engine (see module docs).
#[derive(Debug)]
pub struct Engine {
    clocks: ClockPair,
    stats: SimStats,
    sink: OutputSink,
    wave: Option<Waveform>,
    deadlock_limit: u64,
    /// Deadlock-guard watermark: internal cycle of the last output
    /// progress. Program state (reset by [`Self::arm`], captured by
    /// [`EngineCheckpoint`]), so the no-progress window spans budgeted
    /// continuations and suspend/resume boundaries like an uninterrupted
    /// run.
    last_progress_cycle: u64,
    /// Deadlock-guard watermark: units emitted at the last progress.
    last_units: u64,
    /// Disable event-horizon fast-forward and tick every edge (the
    /// differential-testing oracle). An operator setting like the
    /// verify/collect switches: it survives re-arming, is not part of
    /// checkpoints, and — by construction — has no effect on results.
    force_naive: bool,
}

impl Engine {
    /// New engine for a core with `levels` hierarchy levels.
    pub fn new(clocks: ClockPair, levels: usize, spec: StreamSpec) -> Self {
        Self {
            clocks,
            stats: SimStats::new(levels),
            sink: OutputSink::new(spec),
            wave: None,
            deadlock_limit: DEADLOCK_LIMIT,
            last_progress_cycle: 0,
            last_units: 0,
            force_naive: false,
        }
    }

    /// Re-arm for a freshly loaded program: new clocks, zeroed stats, and
    /// a reset output sink. Waveform storage and the verify/collect
    /// switches survive re-arming, and so do every buffer allocation: the
    /// stats vectors are zeroed in place and collected output buffers are
    /// recycled into the sink's pool, so a warm session re-arms without
    /// touching the allocator.
    pub fn arm(&mut self, clocks: ClockPair, levels: usize, spec: StreamSpec) {
        self.clocks = clocks;
        self.stats.reset(levels);
        self.sink.arm(spec);
        self.last_progress_cycle = 0;
        self.last_units = 0;
    }

    /// Force the naive tick-per-cycle loop, disabling event-horizon
    /// fast-forward (off by default — fast-forward is bit-identical; this
    /// switch is the differential-testing oracle and the A/B baseline for
    /// wall-clock measurements). In debug builds the naive loop also
    /// validates every claimed quiescence horizon (see the module docs).
    pub fn set_force_naive(&mut self, on: bool) {
        self.force_naive = on;
    }

    /// Whether the naive tick-per-cycle loop is forced.
    pub fn force_naive(&self) -> bool {
        self.force_naive
    }

    /// Override the no-progress deadlock window (default
    /// [`DEADLOCK_LIMIT`]). An operator setting like the verify/collect
    /// switches — session state, never checkpointed. Fault campaigns
    /// tighten it so runs that hang (e.g. a dropped off-chip delivery)
    /// fail fast instead of spinning the full default window.
    pub fn set_deadlock_limit(&mut self, limit: u64) {
        self.deadlock_limit = limit.max(1);
    }

    /// Enable/disable end-to-end data verification (on by default; turn
    /// off for performance measurements).
    pub fn set_verify(&mut self, on: bool) {
        self.sink.verify = on;
    }

    /// Whether end-to-end data verification is enabled.
    pub fn verifying(&self) -> bool {
        self.sink.verify
    }

    /// Capture the engine's run state (clocks, stats, sink progress); see
    /// [`EngineCheckpoint`] for what is and is not included.
    pub fn snapshot(&self) -> EngineCheckpoint {
        EngineCheckpoint {
            clocks: self.clocks.clone(),
            stats: self.stats.clone(),
            sink: self.sink.snapshot(),
            last_progress_cycle: self.last_progress_cycle,
            last_units: self.last_units,
        }
    }

    /// Restore an [`EngineCheckpoint`] taken on an engine armed for the
    /// same program. Reuses the live allocations (stats vectors, collected
    /// output buffers) where possible.
    pub fn restore(&mut self, ck: &EngineCheckpoint) {
        self.clocks.clone_from(&ck.clocks);
        self.stats.clone_from(&ck.stats);
        self.sink.restore(&ck.sink);
        self.last_progress_cycle = ck.last_progress_cycle;
        self.last_units = ck.last_units;
    }

    /// Enable output collection (off by default).
    pub fn set_collect(&mut self, on: bool) {
        self.sink.collect = on;
    }

    /// Whether output collection is enabled.
    pub fn collecting(&self) -> bool {
        self.sink.collect
    }

    /// Attach waveform storage (probes are registered by the core).
    pub fn attach_waveform(&mut self, wave: Waveform) {
        self.wave = Some(wave);
    }

    /// Take the recorded waveform (if any).
    pub fn take_waveform(&mut self) -> Option<Waveform> {
        self.wave.take()
    }

    /// The accumulated stats (e.g. mid-run).
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// The output sink (progress queries, buffer recycling).
    pub fn sink_mut(&mut self) -> &mut OutputSink {
        &mut self.sink
    }

    /// Off-chip units emitted so far.
    pub fn units_out(&self) -> u64 {
        self.sink.units_out()
    }

    /// One internal clock edge of `core`; advances the deadlock-guard
    /// watermark whenever the edge produced output progress.
    fn internal_tick(&mut self, core: &mut impl Core) -> Result<()> {
        let cycle = self.stats.internal_cycles;
        self.stats.internal_cycles += 1;
        let mut ctx = CycleCtx {
            cycle,
            stats: &mut self.stats,
            sink: &mut self.sink,
            wave: self.wave.as_mut(),
        };
        core.internal_edge(&mut ctx)?;
        if self.sink.units_out() > self.last_units {
            self.last_units = self.sink.units_out();
            self.last_progress_cycle = self.stats.internal_cycles;
        }
        Ok(())
    }

    /// One external clock edge of `core`.
    fn external_tick(&mut self, core: &mut impl Core, ext_cycle: u64) {
        self.stats.external_cycles += 1;
        core.external_edge(ext_cycle);
    }

    /// Run until all outputs are produced. If `preload` is set, first
    /// runs a fill phase with outputs disabled (not counted in
    /// `stats.internal_cycles`).
    pub fn run(&mut self, core: &mut impl Core, preload: bool) -> Result<EngineRun> {
        match self.run_budget(core, preload, u64::MAX)? {
            BudgetOutcome::Complete(r) => Ok(r),
            BudgetOutcome::Partial { .. } => unreachable!("unbounded budget cannot expire"),
        }
    }

    /// The no-progress diagnostic, shared by every driving loop
    /// (`run`/`run_budget`/`step_cycles`): the watermark is engine state
    /// (advanced by `internal_tick`, reset by `arm`, part of the
    /// checkpoint), so the window spans budgeted continuations and
    /// suspend/resume boundaries exactly like an uninterrupted run.
    fn check_deadlock(&self, core: &impl Core) -> Result<()> {
        if self.stats.internal_cycles - self.last_progress_cycle > self.deadlock_limit {
            return Err(Error::Integrity {
                cycle: self.stats.internal_cycles,
                msg: format!(
                    "no output progress for {} cycles ({}/{} units emitted)",
                    self.deadlock_limit,
                    self.sink.units_out(),
                    core.total_units()
                ),
            });
        }
        Ok(())
    }

    /// Attempt one event-horizon jump, bounded by `cap` internal edges
    /// (the caller's budget / watermark / saturation-window allowance).
    /// Returns the internal edges skipped; `0` means tick normally.
    ///
    /// When the horizon (not the cap) bounds the jump, the skip lands
    /// right before the external wake-up edge; when the cap bounds it —
    /// including ties — the skip stops exactly after the `cap`-th
    /// internal edge, because that is where the naive loop stops (it
    /// never consumes the external edges scheduled *after* its last
    /// internal tick).
    fn fast_forward(&mut self, core: &impl Core, cap: u64) -> u64 {
        if self.force_naive || cap == 0 {
            return 0;
        }
        let (until_ext, output_gated) =
            match core.horizon(self.sink.complete(), self.clocks.external_cycles()) {
                Horizon::Active => return 0,
                Horizon::Quiescent { until_ext, output_gated } => (until_ext, output_gated),
            };
        let avail = match until_ext {
            Some(c) => self.clocks.internal_edges_before_external(c),
            None => u64::MAX,
        };
        let (n_ext, n_int) = match until_ext {
            Some(c) if avail < cap => {
                if c <= self.clocks.external_cycles() {
                    return 0; // wake-up is the very next edge
                }
                self.clocks.skip_to_external_cycle(c)
            }
            _ => (self.clocks.skip_internal_edges(cap), cap),
        };
        if n_ext + n_int == 0 {
            return 0;
        }
        self.stats.internal_cycles += n_int;
        self.stats.external_cycles += n_ext;
        self.stats.skipped_cycles += n_int;
        self.stats.ff_jumps += 1;
        if output_gated && !self.sink.complete() {
            // The ticked loop would have counted every one of these
            // internal cycles as an output stall.
            self.stats.output_stalls += n_int;
        }
        n_int
    }

    /// Whether the naive oracle should validate the upcoming edge against
    /// a claimed quiescence horizon (debug builds only): if this returns
    /// true, the edge about to execute was claimed dead, and
    /// [`Core::last_edge_active`] must come back false afterwards — the
    /// check both driving loops run through
    /// [`Self::assert_claim_held`].
    fn claims_quiescent(&self, core: &impl Core) -> bool {
        cfg!(debug_assertions)
            && self.force_naive
            && !matches!(
                core.horizon(self.sink.complete(), self.clocks.external_cycles()),
                Horizon::Active
            )
    }

    /// Second half of the naive-oracle horizon validation (see
    /// [`Self::claims_quiescent`]).
    fn assert_claim_held(claimed_quiescent: bool, core: &impl Core) {
        debug_assert!(
            !claimed_quiescent || !core.last_edge_active(),
            "a stage under-reported its quiescence horizon: \
             a claimed-dead edge changed state"
        );
    }

    /// Drive `core` until every output is produced or `int_target`
    /// internal cycles have elapsed, fast-forwarding through quiescent
    /// spans (see the module docs) unless `force_naive` is set. The
    /// shared inner loop of [`Self::run_budget`] and
    /// [`Self::step_cycles`].
    fn drive(&mut self, core: &mut impl Core, int_target: u64) -> Result<()> {
        while self.sink.units_out() < core.total_units()
            && self.stats.internal_cycles < int_target
        {
            let budget_rem = int_target - self.stats.internal_cycles;
            // Internal cycles until the no-progress diagnostic fires; the
            // jump is capped there so a fast-forwarded deadlock reports
            // the same cycle the ticked loop reports.
            let guard_rem = (self.last_progress_cycle + self.deadlock_limit + 1)
                .saturating_sub(self.stats.internal_cycles);
            if self.fast_forward(core, budget_rem.min(guard_rem)) > 0 {
                self.check_deadlock(core)?;
                continue;
            }
            let claimed_quiescent = self.claims_quiescent(core);
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.external_tick(core, edge.cycle),
                ClockDomain::Internal => {
                    self.internal_tick(core)?;
                    self.check_deadlock(core)?;
                }
            }
            Self::assert_claim_held(claimed_quiescent, core);
        }
        Ok(())
    }

    /// Like [`Self::run`] but stops after `budget` internal cycles if the
    /// program has not completed by then (the successive-halving screening
    /// primitive). When the program *does* complete within the budget the
    /// returned [`EngineRun`] is bit-identical to what a plain `run` would
    /// have produced: the edge schedule is the same and the budget check
    /// never fires before completion.
    pub fn run_budget(
        &mut self,
        core: &mut impl Core,
        preload: bool,
        budget: u64,
    ) -> Result<BudgetOutcome> {
        let mut preload_cycles = 0;
        if preload {
            preload_cycles = self.run_preload(core)?;
        }
        let target = self.stats.internal_cycles.saturating_add(budget);
        self.drive(core, target)?;
        if self.sink.units_out() < core.total_units() {
            return Ok(BudgetOutcome::Partial {
                cycles: self.stats.internal_cycles,
                units_out: self.sink.units_out(),
            });
        }
        core.flush_stats(&mut self.stats);
        Ok(BudgetOutcome::Complete(EngineRun {
            stats: self.stats.clone(),
            preload_cycles,
            outputs: self.sink.take_collected(),
        }))
    }

    /// Preload phase: outputs disabled, run until the hierarchy saturates
    /// (no write commits for a full saturation window). Preload cycles
    /// are not part of the measured run (§5.2.1: idle time between layers
    /// is used for preloading).
    fn run_preload(&mut self, core: &mut impl Core) -> Result<u64> {
        core.set_output_enabled(false);
        // Saturation window: the preload is done only after no write has
        // committed for a full handshake round trip — the time a word
        // requested at the deadline would still need to land. Derived
        // from the core's configured round trip (off-chip latency +
        // per-sub-word transfer + handshake reset, in external cycles)
        // converted through the clock ratio, plus CDC-synchronizer and
        // write-commit slack (2 sync flops + commit + margin = 4), with
        // the legacy 8-edge window as the floor. A fixed window of 8 —
        // the old magic number — under-measured deep-latency or
        // slow-external configs: words still in flight off-chip were
        // mistaken for saturation.
        let window = self
            .clocks
            .internal_span_of_external(core.handshake_round_trip_ext())
            .saturating_add(4)
            .max(8);
        let mut idle_internal = 0u64;
        let mut cycles = 0u64;
        let saved_internal = self.stats.internal_cycles;
        // Like the cycle counters, the fast-forward diagnostics describe
        // the *measured* run: skips spent saturating the hierarchy are
        // rolled back with the rest of the preload accounting below (the
        // wall-clock win still shows — it just is not part of the run's
        // stats, so `skipped_cycles` can never exceed `internal_cycles`).
        let saved_skipped = self.stats.skipped_cycles;
        let saved_jumps = self.stats.ff_jumps;
        while idle_internal < window {
            // A quiescent span is by definition write-free, so it
            // advances the idle window in bulk; the cap makes the loop
            // exit (or the saturation diagnostic fire) on exactly the
            // edge the ticked loop stops on.
            let window_rem = window - idle_internal;
            let guard_rem = (self.deadlock_limit + 1).saturating_sub(cycles);
            let skipped = self.fast_forward(core, window_rem.min(guard_rem));
            if skipped > 0 {
                cycles += skipped;
                idle_internal += skipped;
                if cycles > self.deadlock_limit {
                    return Err(Error::Integrity {
                        cycle: cycles,
                        msg: "preload did not saturate".into(),
                    });
                }
                continue;
            }
            let claimed_quiescent = self.claims_quiescent(core);
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.external_tick(core, edge.cycle),
                ClockDomain::Internal => {
                    let writes_before: u64 = self.stats.level_writes.iter().sum();
                    self.internal_tick(core)?;
                    let writes_after: u64 = self.stats.level_writes.iter().sum();
                    cycles += 1;
                    if writes_after > writes_before {
                        idle_internal = 0;
                    } else {
                        idle_internal += 1;
                    }
                    if cycles > self.deadlock_limit {
                        return Err(Error::Integrity {
                            cycle: cycles,
                            msg: "preload did not saturate".into(),
                        });
                    }
                }
            }
            Self::assert_claim_held(claimed_quiescent, core);
        }
        self.stats.internal_cycles = saved_internal;
        self.stats.external_cycles = 0;
        self.stats.skipped_cycles = saved_skipped;
        self.stats.ff_jumps = saved_jumps;
        core.set_output_enabled(true);
        Ok(cycles)
    }

    /// Run exactly `n` internal cycles (micro-stepping for tests and
    /// waveform capture); external edges are interleaved per the clock
    /// ratio. Returns the units emitted so far. Routed through the same
    /// no-progress watermark as [`Self::run_budget`]: a mis-armed
    /// micro-stepped run fails with the `Integrity` diagnostic instead of
    /// silently spinning until `n` is exhausted.
    pub fn step_cycles(&mut self, core: &mut impl Core, n: u64) -> Result<u64> {
        let target = self.stats.internal_cycles.saturating_add(n);
        self.drive(core, target)?;
        Ok(self.sink.units_out())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::offchip::payload_for;

    fn spec(total: u64) -> StreamSpec {
        StreamSpec {
            start_address: 0,
            stride: 1,
            cycle_length: 4,
            inter_cycle_shift: 0,
            skip_shift: 0,
            sub_width: 32,
            total_units: total,
            payload: payload_for,
        }
    }

    /// A trivial core: emits one correct unit every `cadence` internal
    /// cycles.
    struct CountingCore {
        total: u64,
        cadence: u64,
        tick: u64,
        next_unit: u64,
        enabled: bool,
        wrong_payload: bool,
    }

    impl CountingCore {
        fn new(total: u64, cadence: u64) -> Self {
            Self { total, cadence, tick: 0, next_unit: 0, enabled: true, wrong_payload: false }
        }
    }

    impl Core for CountingCore {
        fn external_edge(&mut self, _ext_cycle: u64) {}

        fn internal_edge(&mut self, ctx: &mut CycleCtx<'_>) -> Result<()> {
            self.tick += 1;
            if self.enabled && self.tick % self.cadence == 0 && !ctx.sink.complete() {
                let addr = self.next_unit % 4; // cyclic l=4 stream
                self.next_unit += 1;
                let word = if self.wrong_payload {
                    Word::zero(32)
                } else {
                    payload_for(addr, 32)
                };
                ctx.sink.emit(&[addr], word, ctx.cycle, ctx.stats)?;
            }
            Ok(())
        }

        fn set_output_enabled(&mut self, on: bool) {
            self.enabled = on;
        }

        fn total_units(&self) -> u64 {
            self.total
        }

        fn flush_stats(&mut self, _stats: &mut SimStats) {}
    }

    #[test]
    fn engine_runs_core_to_completion() {
        let mut core = CountingCore::new(16, 2);
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(16));
        let r = eng.run(&mut core, false).unwrap();
        assert_eq!(r.stats.outputs, 16);
        assert_eq!(r.stats.internal_cycles, 32, "one emission every 2 cycles");
        assert_eq!(r.preload_cycles, 0);
    }

    #[test]
    fn budgeted_run_partials_then_completes_identically() {
        // 16 units at one emission per 2 cycles = 32 cycles total.
        let mut core = CountingCore::new(16, 2);
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(16));
        match eng.run_budget(&mut core, false, 10).unwrap() {
            BudgetOutcome::Partial { cycles, units_out } => {
                assert_eq!(cycles, 10);
                assert_eq!(units_out, 5);
            }
            other => panic!("expected partial, got {other:?}"),
        }
        // A fresh, fully-budgeted run matches a plain run bit for bit.
        let mut core_a = CountingCore::new(16, 2);
        let mut eng_a = Engine::new(ClockPair::synchronous(), 0, spec(16));
        let a = match eng_a.run_budget(&mut core_a, false, 1_000).unwrap() {
            BudgetOutcome::Complete(r) => r,
            other => panic!("expected complete, got {other:?}"),
        };
        let mut core_b = CountingCore::new(16, 2);
        let mut eng_b = Engine::new(ClockPair::synchronous(), 0, spec(16));
        let b = eng_b.run(&mut core_b, false).unwrap();
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.preload_cycles, b.preload_cycles);
    }

    #[test]
    fn engine_detects_payload_corruption() {
        let mut core = CountingCore::new(8, 1);
        core.wrong_payload = true;
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(8));
        match eng.run(&mut core, false) {
            Err(Error::Integrity { msg, .. }) => {
                assert!(msg.contains("payload corruption"), "{msg}")
            }
            other => panic!("expected integrity error, got {other:?}"),
        }
    }

    /// A toy single-word fetch pipeline shaped like the off-chip path:
    /// request on an external edge, deliver `latency` external cycles
    /// later, two-flop sync into the internal domain, emit, handshake
    /// reset — and an exact [`Horizon`] report for the in-flight dead
    /// span. The engine-level differential harness for the fast-forward
    /// bookkeeping (budget exits, watermark, stall accounting, clocks).
    struct PipelineCore {
        total: u64,
        latency: u64,
        inflight: Option<u64>,
        fetched: u64,
        queue: bool,
        meta: bool,
        synced: bool,
        resetting: bool,
        enabled: bool,
        active: bool,
        emitted: u64,
    }

    impl PipelineCore {
        fn new(total: u64, latency: u64) -> Self {
            Self {
                total,
                latency: latency.max(1),
                inflight: None,
                fetched: 0,
                queue: false,
                meta: false,
                synced: false,
                resetting: false,
                enabled: true,
                active: true,
                emitted: 0,
            }
        }
    }

    impl Core for PipelineCore {
        fn external_edge(&mut self, ext_cycle: u64) {
            let mut acted = false;
            if self.resetting {
                self.resetting = false;
                acted = true;
            }
            if !self.queue {
                if let Some(at) = self.inflight {
                    if at <= ext_cycle {
                        self.inflight = None;
                        self.queue = true;
                        acted = true;
                    }
                }
            }
            if self.inflight.is_none() && !self.queue && self.fetched < self.total {
                self.inflight = Some(ext_cycle + self.latency);
                self.fetched += 1;
                acted = true;
            }
            self.active = acted;
        }

        fn internal_edge(&mut self, ctx: &mut CycleCtx<'_>) -> Result<()> {
            let mut active = self.synced != self.meta || self.meta != self.queue;
            self.synced = self.meta;
            self.meta = self.queue;
            if self.enabled && self.synced && self.queue && !ctx.sink.complete() {
                self.queue = false;
                self.resetting = true;
                self.meta = false;
                self.synced = false;
                let addr = self.emitted % 4; // cyclic l=4 stream
                self.emitted += 1;
                ctx.stats.level_writes[0] += 1;
                ctx.sink.emit(&[addr], payload_for(addr, 32), ctx.cycle, ctx.stats)?;
                active = true;
            } else if self.enabled && !ctx.sink.complete() {
                ctx.stats.output_stalls += 1;
            }
            self.active = active;
            Ok(())
        }

        fn set_output_enabled(&mut self, on: bool) {
            self.enabled = on;
        }

        fn total_units(&self) -> u64 {
            self.total
        }

        fn flush_stats(&mut self, _stats: &mut SimStats) {}

        fn horizon(&self, sink_complete: bool, next_ext_cycle: u64) -> Horizon {
            if self.active {
                return Horizon::Active;
            }
            let settled = self.synced == self.meta && self.meta == self.queue;
            if !settled || self.resetting {
                return Horizon::Active;
            }
            if self.enabled && !sink_complete && self.synced && self.queue {
                return Horizon::Active;
            }
            if self.inflight.is_none() && !self.queue && self.fetched < self.total {
                return Horizon::Active; // a request issues next edge
            }
            match self.inflight {
                Some(t) if !self.queue => {
                    if t <= next_ext_cycle {
                        Horizon::Active
                    } else {
                        Horizon::Quiescent { until_ext: Some(t), output_gated: self.enabled }
                    }
                }
                _ => Horizon::Quiescent { until_ext: None, output_gated: self.enabled },
            }
        }

        fn last_edge_active(&self) -> bool {
            self.active
        }

        fn handshake_round_trip_ext(&self) -> u64 {
            self.latency + 2
        }
    }

    /// Drive one (mode, clocks, latency, budget-plan) combination to its
    /// outcome; returns everything observable.
    fn pipeline_run(
        clocks: ClockPair,
        total: u64,
        latency: u64,
        budgets: &[u64],
        naive: bool,
    ) -> (Vec<String>, SimStats, u64, u64) {
        let mut core = PipelineCore::new(total, latency);
        let mut eng = Engine::new(clocks, 1, spec(total));
        eng.set_force_naive(naive);
        eng.deadlock_limit = 5_000; // keep failure cases fast
        let mut outcomes = Vec::new();
        for &b in budgets {
            match eng.run_budget(&mut core, false, b) {
                Ok(BudgetOutcome::Complete(r)) => {
                    outcomes.push(format!("complete@{}", r.stats.internal_cycles));
                    break;
                }
                Ok(BudgetOutcome::Partial { cycles, units_out }) => {
                    outcomes.push(format!("partial@{cycles}/{units_out}"));
                }
                Err(e) => {
                    outcomes.push(format!("err:{e}"));
                    break;
                }
            }
        }
        let skipped = eng.stats().skipped_cycles;
        let jumps = eng.stats().ff_jumps;
        (outcomes, eng.stats().clone(), skipped, jumps)
    }

    #[test]
    fn fast_forward_matches_naive_pipeline() {
        // Every (clock ratio × latency × budget slicing) must produce
        // identical outcomes, stats, and edge positions in both modes —
        // and the naive leg runs the debug horizon validation.
        let ratios: &[(u64, u64)] = &[(1, 1), (4, 1), (1, 4), (3, 7)];
        let plans: &[&[u64]] = &[&[u64::MAX], &[7, u64::MAX], &[1, 2, 3, u64::MAX]];
        for &(e_hz, i_hz) in ratios {
            for latency in [1u64, 3, 16, 64] {
                for plan in plans {
                    let cp = ClockPair::from_freqs(e_hz, i_hz);
                    let (oa, sa, skipped, _) =
                        pipeline_run(cp.clone(), 12, latency, plan, false);
                    let (ob, sb, none_skipped, _) = pipeline_run(cp, 12, latency, plan, true);
                    assert_eq!(oa, ob, "{e_hz}:{i_hz} lat={latency} plan={plan:?}");
                    assert_eq!(sa, sb, "{e_hz}:{i_hz} lat={latency} plan={plan:?}");
                    assert_eq!(none_skipped, 0, "force_naive must never skip");
                    if latency >= 16 {
                        assert!(
                            skipped > 0,
                            "stall-heavy span must fast-forward ({e_hz}:{i_hz} lat={latency})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fast_forward_deadlock_matches_naive() {
        // A delivered word nobody consumes: both modes must report the
        // no-progress diagnostic at the identical cycle — the fast path
        // jumps straight to it instead of spinning.
        for naive in [false, true] {
            let mut core = PipelineCore::new(8, 4);
            core.enabled = false; // nothing ever emits
            let mut eng = Engine::new(ClockPair::synchronous(), 1, spec(8));
            eng.set_force_naive(naive);
            eng.deadlock_limit = 1_000;
            match eng.run(&mut core, false) {
                Err(Error::Integrity { cycle, msg }) => {
                    assert_eq!(cycle, 1_001, "naive={naive}");
                    assert!(msg.contains("no output progress"), "{msg}");
                }
                other => panic!("expected deadlock, got {other:?}"),
            }
        }
    }

    #[test]
    fn step_cycles_hits_deadlock_guard() {
        // The micro-stepping path shares the watermark: a mis-armed run
        // fails with the Integrity diagnostic instead of spinning until
        // the caller's n is exhausted.
        let mut core = CountingCore::new(8, 1);
        core.enabled = false;
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(8));
        eng.deadlock_limit = 500;
        match eng.step_cycles(&mut core, 10_000) {
            Err(Error::Integrity { cycle, msg }) => {
                assert_eq!(cycle, 501);
                assert!(msg.contains("no output progress"), "{msg}");
            }
            other => panic!("expected deadlock error, got {other:?}"),
        }
    }

    #[test]
    fn step_cycles_fast_forward_matches_naive() {
        // Micro-stepping through a stall span in odd-sized steps lands on
        // the same cycle/unit positions as the ticked loop.
        for &(e_hz, i_hz) in &[(1u64, 1u64), (4, 1), (1, 4)] {
            let mut trace_a = Vec::new();
            let mut trace_b = Vec::new();
            for (naive, trace) in [(false, &mut trace_a), (true, &mut trace_b)] {
                let mut core = PipelineCore::new(6, 16);
                let mut eng = Engine::new(ClockPair::from_freqs(e_hz, i_hz), 1, spec(6));
                eng.set_force_naive(naive);
                for step in [1u64, 3, 17, 40, 200, 1_000] {
                    let units = eng.step_cycles(&mut core, step).unwrap();
                    trace.push((eng.stats().internal_cycles, eng.stats().external_cycles, units));
                }
            }
            assert_eq!(trace_a, trace_b, "{e_hz}:{i_hz}");
        }
    }

    #[test]
    fn engine_deadlock_guard_fires() {
        // A core that never emits: the guard must trip rather than spin
        // forever.
        let mut core = CountingCore::new(8, 1);
        core.enabled = false;
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(8));
        eng.deadlock_limit = 1_000; // keep the test fast
        match eng.run(&mut core, false) {
            Err(Error::Integrity { msg, .. }) => {
                assert!(msg.contains("no output progress"), "{msg}")
            }
            other => panic!("expected deadlock error, got {other:?}"),
        }
    }

    #[test]
    fn sink_collection_pools_buffers() {
        let mut sink = OutputSink::new(spec(64));
        sink.collect = true;
        sink.verify = false;
        let mut stats = SimStats::new(0);
        for i in 0..4 {
            sink.emit(&[i, i + 1], Word::zero(64), i, &mut stats).unwrap();
        }
        let outs = sink.take_collected();
        assert_eq!(outs.len(), 4);
        assert_eq!(outs[2].addrs, vec![2, 3]);
        // Recycle and re-emit: buffers come from the pool.
        sink.recycle(outs);
        assert_eq!(sink.addr_pool.len(), 4);
        sink.emit(&[9], Word::zero(32), 9, &mut stats).unwrap();
        assert_eq!(sink.addr_pool.len(), 3, "one pooled buffer reused");
        assert_eq!(sink.take_collected()[0].addrs, vec![9]);
    }

    #[test]
    fn sink_verifies_address_stream() {
        let mut sink = OutputSink::new(spec(8));
        let mut stats = SimStats::new(0);
        // Expected stream is 0,1,2,3,0,1,... — unit 1 out of order fails.
        sink.emit(&[0], payload_for(0, 32), 0, &mut stats).unwrap();
        let err = sink.emit(&[3], payload_for(3, 32), 1, &mut stats).unwrap_err();
        assert!(err.to_string().contains("expected"), "{err}");
    }
}

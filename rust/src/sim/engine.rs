//! The stage-based simulation engine.
//!
//! Before this layer existed, `mem::Hierarchy` owned *everything*: the
//! two-domain clock interleaving, the deadlock guard, the stats lifetime,
//! the end-to-end output verifier, output collection, waveform capture —
//! and the per-cycle datapath scheduling, all tangled into one `run`
//! loop. This module extracts the reusable simulation machinery so the
//! hierarchy (and any future core: new level kinds, batched co-simulation
//! front-ends) is a thin composition:
//!
//! * [`Stage`] — the contract one datapath component satisfies: hooks for
//!   the two clock-domain edges plus the elastic-port handshake
//!   (`ready_out` = "a word is presented downstream", `ready_in` = "a
//!   word of this width can be latched"). `mem::{Level, InputBuffer,
//!   Osr, OffChipMemory}` all implement it. Data *movement* between
//!   stages stays in the composing core's scheduler — exactly like RTL,
//!   where the enclosing module owns the port wiring while each
//!   submodule owns its edge behavior.
//! * [`Core`] — a composition of stages the engine can drive: one
//!   callback per clock-domain edge plus program-size queries.
//! * [`Engine`] — owns the [`ClockPair`] edge interleaving, the
//!   [`SimStats`] lifetime, the no-progress deadlock guard, the preload
//!   phase, the [`OutputSink`] (verification + collection), and waveform
//!   storage. `Engine::run` reproduces the exact per-edge schedule the
//!   monolithic `Hierarchy::run` had, so cycle counts are unchanged.
//! * [`OutputSink`] — the engine-owned output port: verifies every
//!   emitted word against the expected shifted-cyclic unit stream and
//!   the deterministic payload function ([`StreamSpec`]), tracks
//!   progress, and (optionally) collects outputs using pooled address
//!   buffers so steady-state collection does not allocate per output.
//!
//! ## Determinism guarantee
//!
//! The engine is single-threaded and consumes no ambient state (no time,
//! no RNG): given the same `Core` state and the same [`StreamSpec`], the
//! edge schedule, stats, and output stream are bit-for-bit reproducible.
//! This is what `dse::pool` builds on — each worker drives its own
//! engine, and a parallel sweep is indistinguishable from a serial one.

use crate::sim::{ClockDomain, ClockPair, SimStats, Waveform};
use crate::util::bitword::Word;
use crate::{Error, Result};

/// One word delivered to the accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct OutputWord {
    /// Source off-chip addresses (LSB-first sub-words).
    pub addrs: Vec<u64>,
    /// Payload bits.
    pub word: Word,
}

/// Progress guard: a run with no output progress for this many internal
/// cycles is declared deadlocked (a scheduling bug, not a configuration
/// property — valid configurations always make progress).
pub const DEADLOCK_LIMIT: u64 = 200_000;

/// The per-component stage contract (see module docs).
///
/// All methods have no-op defaults so a stage only implements the hooks
/// that apply to its clock domain and ports.
pub trait Stage {
    /// Internal (accelerator-domain) clock edge: registered state the
    /// stage updates on its own, e.g. the input buffer's CDC
    /// synchronizer shift.
    fn on_internal_edge(&mut self) {}

    /// External (off-chip-domain) clock edge for self-contained stages.
    /// Stages whose external behavior needs bus access (the input
    /// buffer's fill engine talking to the off-chip memory) are driven
    /// by the core's scheduler instead.
    fn on_external_edge(&mut self, _ext_cycle: u64) {}

    /// Port handshake: the stage presents a word to its downstream
    /// consumer this cycle.
    fn ready_out(&self) -> bool {
        false
    }

    /// Port handshake: the stage can latch an incoming word of `width`
    /// bits this cycle.
    fn ready_in(&self, _width: u32) -> bool {
        false
    }
}

/// Expected-output-stream specification: the shifted-cyclic unit stream
/// (in off-chip units) plus the deterministic payload function, used by
/// the engine's verifier.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    /// First off-chip address of the stream.
    pub start_address: u64,
    /// Address stride between consecutive units.
    pub stride: u64,
    /// Pattern cycle length in off-chip units.
    pub cycle_length: u64,
    /// Inter-cycle shift in off-chip units.
    pub inter_cycle_shift: u64,
    /// Completed cycles before each shift is applied.
    pub skip_shift: u64,
    /// Off-chip word width in bits (one unit).
    pub sub_width: u32,
    /// Total off-chip units the program emits.
    pub total_units: u64,
    /// Deterministic payload for an address (the end-to-end integrity
    /// check's ground truth).
    pub payload: fn(u64, u32) -> Word,
}

impl StreamSpec {
    /// An idle spec (no program loaded): zero units expected.
    pub fn idle(sub_width: u32, payload: fn(u64, u32) -> Word) -> Self {
        Self {
            start_address: 0,
            stride: 1,
            cycle_length: 1,
            inter_cycle_shift: 1,
            skip_shift: 0,
            sub_width,
            total_units: 0,
            payload,
        }
    }
}

/// Incremental expected-unit-stream generator (shifted-cyclic in off-chip
/// units), mirroring `AccessPattern::stream` without allocation.
#[derive(Debug, Clone, PartialEq, Eq)]
struct VerifyState {
    l: u64,
    s: u64,
    k: u64,
    ptr: u64,
    offset: u64,
    skips: u64,
}

impl VerifyState {
    fn from_spec(spec: &StreamSpec) -> Self {
        Self {
            l: spec.cycle_length,
            s: spec.inter_cycle_shift,
            k: spec.skip_shift,
            ptr: 0,
            offset: 0,
            skips: 0,
        }
    }

    fn next_unit(&mut self) -> u64 {
        let u = self.offset + self.ptr;
        self.ptr += 1;
        if self.ptr == self.l {
            self.ptr = 0;
            self.skips += 1;
            if self.skips > self.k {
                self.skips = 0;
                self.offset += self.s;
            }
        }
        u
    }
}

/// Upper bound on pooled collection buffers kept across runs.
const ADDR_POOL_CAP: usize = 4_096;

/// The engine-owned output port: progress tracking, end-to-end
/// verification, and pooled collection.
#[derive(Debug)]
pub struct OutputSink {
    spec: StreamSpec,
    verify: bool,
    collect: bool,
    verify_state: VerifyState,
    units_out: u64,
    collected: Vec<OutputWord>,
    /// Recycled address buffers for collected outputs (no per-output
    /// allocation in steady state once the pool is warm).
    addr_pool: Vec<Vec<u64>>,
}

impl OutputSink {
    fn new(spec: StreamSpec) -> Self {
        let verify_state = VerifyState::from_spec(&spec);
        Self {
            spec,
            verify: true,
            collect: false,
            verify_state,
            units_out: 0,
            collected: Vec::new(),
            addr_pool: Vec::new(),
        }
    }

    /// Re-arm for a new program: reset progress and the verifier, recycle
    /// any collected buffers into the pool (in place — re-arming allocates
    /// nothing). Verify/collect switches are sticky across programs (they
    /// are operator settings, not program state).
    fn arm(&mut self, spec: StreamSpec) {
        self.verify_state = VerifyState::from_spec(&spec);
        self.spec = spec;
        self.units_out = 0;
        for ow in self.collected.drain(..) {
            if self.addr_pool.len() >= ADDR_POOL_CAP {
                break;
            }
            self.addr_pool.push(ow.addrs);
        }
    }

    /// Off-chip units emitted so far.
    pub fn units_out(&self) -> u64 {
        self.units_out
    }

    /// Whether all programmed units have been emitted.
    pub fn complete(&self) -> bool {
        self.units_out >= self.spec.total_units
    }

    /// Return output buffers to the allocation pool (callers that consume
    /// `RunResult::outputs` in a loop can hand the vectors back to keep
    /// collection allocation-free across runs).
    pub fn recycle(&mut self, outputs: Vec<OutputWord>) {
        for ow in outputs {
            if self.addr_pool.len() >= ADDR_POOL_CAP {
                break;
            }
            self.addr_pool.push(ow.addrs);
        }
    }

    fn take_collected(&mut self) -> Vec<OutputWord> {
        std::mem::take(&mut self.collected)
    }

    /// Capture the sink's program-progress state (verifier cursor, unit
    /// counter, collected outputs), plus the capture-time verify/collect
    /// switches as a compatibility key: the cursor and the collected list
    /// are only meaningful under the same settings, so a restore onto a
    /// sink with different switches is refused upstream
    /// ([`crate::mem::Hierarchy::restore`]). The switches themselves and
    /// the buffer pool stay session resources — restore never changes
    /// them.
    fn snapshot(&self) -> SinkCheckpoint {
        SinkCheckpoint {
            verify: self.verify,
            collect: self.collect,
            verify_state: self.verify_state.clone(),
            units_out: self.units_out,
            collected: self.collected.clone(),
        }
    }

    /// Restore a [`SinkCheckpoint`] taken on an identically armed sink
    /// (the switch-compatibility check happens upstream).
    fn restore(&mut self, ck: &SinkCheckpoint) {
        self.verify_state.clone_from(&ck.verify_state);
        self.units_out = ck.units_out;
        self.collected.clone_from(&ck.collected);
    }

    /// Record an emitted output word; verify its addresses against the
    /// expected pattern stream and its payload against the payload
    /// function. Allocation-free unless collection is enabled (and then
    /// pooled).
    pub fn emit(
        &mut self,
        addrs: &[u64],
        word: Word,
        cycle: u64,
        stats: &mut SimStats,
    ) -> Result<()> {
        let w_off = self.spec.sub_width;
        if self.verify {
            for (j, &addr) in addrs.iter().enumerate() {
                let unit = self.verify_state.next_unit();
                let expect_addr = self.spec.start_address + unit * self.spec.stride;
                if addr != expect_addr {
                    return Err(Error::Integrity {
                        cycle,
                        msg: format!(
                            "output unit {} address {addr:#x} != expected {expect_addr:#x}",
                            self.units_out + j as u64
                        ),
                    });
                }
                let expect_payload = (self.spec.payload)(addr, w_off);
                if word.bits(j as u32 * w_off, w_off) != expect_payload {
                    return Err(Error::Integrity {
                        cycle,
                        msg: format!("payload corruption at address {addr:#x}"),
                    });
                }
            }
        }
        self.units_out += addrs.len() as u64;
        stats.outputs += 1;
        if stats.first_output_cycle.is_none() {
            stats.first_output_cycle = Some(cycle);
        }
        if self.collect {
            let mut buf = self.addr_pool.pop().unwrap_or_default();
            buf.clear();
            buf.extend_from_slice(addrs);
            self.collected.push(OutputWord { addrs: buf, word });
        }
        Ok(())
    }
}

/// Per-internal-cycle context handed to [`Core::internal_edge`].
pub struct CycleCtx<'a> {
    /// Internal cycle index (0-based).
    pub cycle: u64,
    /// Run counters.
    pub stats: &'a mut SimStats,
    /// The output port (emission, progress queries).
    pub sink: &'a mut OutputSink,
    /// Waveform storage, if capture is attached; cores record their
    /// strobes through their registered probes.
    pub wave: Option<&'a mut Waveform>,
}

/// A composition of [`Stage`]s the engine can drive.
pub trait Core {
    /// One external (off-chip-domain) clock edge: fill engines, off-chip
    /// request/response stepping.
    fn external_edge(&mut self, ext_cycle: u64);

    /// One internal (accelerator-domain) clock edge: the datapath
    /// schedule. Emitted outputs go through `ctx.sink`.
    fn internal_edge(&mut self, ctx: &mut CycleCtx<'_>) -> Result<()>;

    /// Gate the output port (`disable_output_i`); the engine holds
    /// outputs disabled during the preload phase.
    fn set_output_enabled(&mut self, on: bool);

    /// Total off-chip units the loaded program emits.
    fn total_units(&self) -> u64;

    /// End-of-run counter flush (counters that live inside components,
    /// e.g. off-chip read totals).
    fn flush_stats(&mut self, stats: &mut SimStats);
}

/// Captured output-sink run state (part of [`EngineCheckpoint`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SinkCheckpoint {
    /// Verify switch at capture time (compatibility key, not restored).
    verify: bool,
    /// Collect switch at capture time (compatibility key, not restored).
    collect: bool,
    verify_state: VerifyState,
    units_out: u64,
    collected: Vec<OutputWord>,
}

/// Captured engine state at an internal-cycle boundary: the clock-pair
/// positions, the full [`SimStats`], the output sink's progress, and the
/// deadlock-guard watermark (so the no-progress window spans a
/// suspend/resume boundary exactly as it would an uninterrupted run).
/// Together with the core components' checkpoints this is everything a
/// suspended run needs to continue bit-identically on any engine armed
/// for the same program — see
/// [`Hierarchy::snapshot`](crate::mem::Hierarchy::snapshot).
///
/// The verify/collect switches are recorded as a **compatibility key**
/// (see [`Self::captured_verify`]/[`Self::captured_collect`]) but never
/// restored — they are operator settings that belong to the session, like
/// the deadlock limit. Waveform storage is not captured at all (capture
/// across a suspend/resume boundary is unsupported).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineCheckpoint {
    clocks: ClockPair,
    stats: SimStats,
    sink: SinkCheckpoint,
    last_progress_cycle: u64,
    last_units: u64,
}

impl EngineCheckpoint {
    /// Internal cycles consumed at the capture point.
    pub fn internal_cycles(&self) -> u64 {
        self.stats.internal_cycles
    }

    /// Off-chip units emitted at the capture point.
    pub fn units_out(&self) -> u64 {
        self.sink.units_out
    }

    /// The verify switch at capture time (the compatibility key a restore
    /// target must match).
    pub fn captured_verify(&self) -> bool {
        self.sink.verify
    }

    /// The collect switch at capture time (the compatibility key a
    /// restore target must match).
    pub fn captured_collect(&self) -> bool {
        self.sink.collect
    }
}

/// Result of one engine run.
#[derive(Debug)]
pub struct EngineRun {
    /// Counters for the (post-preload) run.
    pub stats: SimStats,
    /// Internal cycles spent in the preload phase (0 if preload
    /// disabled).
    pub preload_cycles: u64,
    /// Collected outputs (only if collection was enabled).
    pub outputs: Vec<OutputWord>,
}

/// Outcome of a cycle-budgeted run ([`Engine::run_budget`]).
#[derive(Debug)]
pub enum BudgetOutcome {
    /// The program completed within the budget; the run is exactly what an
    /// unbudgeted [`Engine::run`] would have produced.
    Complete(EngineRun),
    /// The budget expired first; the run is suspended mid-program (the
    /// caller may keep stepping or re-arm).
    Partial {
        /// Internal cycles consumed so far.
        cycles: u64,
        /// Off-chip units emitted so far.
        units_out: u64,
    },
}

/// The simulation engine (see module docs).
#[derive(Debug)]
pub struct Engine {
    clocks: ClockPair,
    stats: SimStats,
    sink: OutputSink,
    wave: Option<Waveform>,
    deadlock_limit: u64,
    /// Deadlock-guard watermark: internal cycle of the last output
    /// progress. Program state (reset by [`Self::arm`], captured by
    /// [`EngineCheckpoint`]), so the no-progress window spans budgeted
    /// continuations and suspend/resume boundaries like an uninterrupted
    /// run.
    last_progress_cycle: u64,
    /// Deadlock-guard watermark: units emitted at the last progress.
    last_units: u64,
}

impl Engine {
    /// New engine for a core with `levels` hierarchy levels.
    pub fn new(clocks: ClockPair, levels: usize, spec: StreamSpec) -> Self {
        Self {
            clocks,
            stats: SimStats::new(levels),
            sink: OutputSink::new(spec),
            wave: None,
            deadlock_limit: DEADLOCK_LIMIT,
            last_progress_cycle: 0,
            last_units: 0,
        }
    }

    /// Re-arm for a freshly loaded program: new clocks, zeroed stats, and
    /// a reset output sink. Waveform storage and the verify/collect
    /// switches survive re-arming, and so do every buffer allocation: the
    /// stats vectors are zeroed in place and collected output buffers are
    /// recycled into the sink's pool, so a warm session re-arms without
    /// touching the allocator.
    pub fn arm(&mut self, clocks: ClockPair, levels: usize, spec: StreamSpec) {
        self.clocks = clocks;
        self.stats.reset(levels);
        self.sink.arm(spec);
        self.last_progress_cycle = 0;
        self.last_units = 0;
    }

    /// Enable/disable end-to-end data verification (on by default; turn
    /// off for performance measurements).
    pub fn set_verify(&mut self, on: bool) {
        self.sink.verify = on;
    }

    /// Whether end-to-end data verification is enabled.
    pub fn verifying(&self) -> bool {
        self.sink.verify
    }

    /// Capture the engine's run state (clocks, stats, sink progress); see
    /// [`EngineCheckpoint`] for what is and is not included.
    pub fn snapshot(&self) -> EngineCheckpoint {
        EngineCheckpoint {
            clocks: self.clocks.clone(),
            stats: self.stats.clone(),
            sink: self.sink.snapshot(),
            last_progress_cycle: self.last_progress_cycle,
            last_units: self.last_units,
        }
    }

    /// Restore an [`EngineCheckpoint`] taken on an engine armed for the
    /// same program. Reuses the live allocations (stats vectors, collected
    /// output buffers) where possible.
    pub fn restore(&mut self, ck: &EngineCheckpoint) {
        self.clocks.clone_from(&ck.clocks);
        self.stats.clone_from(&ck.stats);
        self.sink.restore(&ck.sink);
        self.last_progress_cycle = ck.last_progress_cycle;
        self.last_units = ck.last_units;
    }

    /// Enable output collection (off by default).
    pub fn set_collect(&mut self, on: bool) {
        self.sink.collect = on;
    }

    /// Whether output collection is enabled.
    pub fn collecting(&self) -> bool {
        self.sink.collect
    }

    /// Attach waveform storage (probes are registered by the core).
    pub fn attach_waveform(&mut self, wave: Waveform) {
        self.wave = Some(wave);
    }

    /// Take the recorded waveform (if any).
    pub fn take_waveform(&mut self) -> Option<Waveform> {
        self.wave.take()
    }

    /// The accumulated stats (e.g. mid-run).
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// The output sink (progress queries, buffer recycling).
    pub fn sink_mut(&mut self) -> &mut OutputSink {
        &mut self.sink
    }

    /// Off-chip units emitted so far.
    pub fn units_out(&self) -> u64 {
        self.sink.units_out()
    }

    /// One internal clock edge of `core`; advances the deadlock-guard
    /// watermark whenever the edge produced output progress.
    fn internal_tick(&mut self, core: &mut impl Core) -> Result<()> {
        let cycle = self.stats.internal_cycles;
        self.stats.internal_cycles += 1;
        let mut ctx = CycleCtx {
            cycle,
            stats: &mut self.stats,
            sink: &mut self.sink,
            wave: self.wave.as_mut(),
        };
        core.internal_edge(&mut ctx)?;
        if self.sink.units_out() > self.last_units {
            self.last_units = self.sink.units_out();
            self.last_progress_cycle = self.stats.internal_cycles;
        }
        Ok(())
    }

    /// One external clock edge of `core`.
    fn external_tick(&mut self, core: &mut impl Core, ext_cycle: u64) {
        self.stats.external_cycles += 1;
        core.external_edge(ext_cycle);
    }

    /// Run until all outputs are produced. If `preload` is set, first
    /// runs a fill phase with outputs disabled (not counted in
    /// `stats.internal_cycles`).
    pub fn run(&mut self, core: &mut impl Core, preload: bool) -> Result<EngineRun> {
        match self.run_budget(core, preload, u64::MAX)? {
            BudgetOutcome::Complete(r) => Ok(r),
            BudgetOutcome::Partial { .. } => unreachable!("unbounded budget cannot expire"),
        }
    }

    /// Like [`Self::run`] but stops after `budget` internal cycles if the
    /// program has not completed by then (the successive-halving screening
    /// primitive). When the program *does* complete within the budget the
    /// returned [`EngineRun`] is bit-identical to what a plain `run` would
    /// have produced: the edge schedule is the same and the budget check
    /// never fires before completion.
    pub fn run_budget(
        &mut self,
        core: &mut impl Core,
        preload: bool,
        budget: u64,
    ) -> Result<BudgetOutcome> {
        let mut preload_cycles = 0;
        if preload {
            preload_cycles = self.run_preload(core)?;
        }
        let target = self.stats.internal_cycles.saturating_add(budget);
        while self.sink.units_out() < core.total_units() && self.stats.internal_cycles < target {
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.external_tick(core, edge.cycle),
                ClockDomain::Internal => {
                    self.internal_tick(core)?;
                    // The watermark is engine state (advanced by
                    // `internal_tick`, reset by `arm`, part of the
                    // checkpoint), so the no-progress window spans
                    // budgeted continuations and suspend/resume
                    // boundaries exactly like an uninterrupted run.
                    if self.stats.internal_cycles - self.last_progress_cycle
                        > self.deadlock_limit
                    {
                        return Err(Error::Integrity {
                            cycle: self.stats.internal_cycles,
                            msg: format!(
                                "no output progress for {} cycles ({}/{} units emitted)",
                                self.deadlock_limit,
                                self.sink.units_out(),
                                core.total_units()
                            ),
                        });
                    }
                }
            }
        }
        if self.sink.units_out() < core.total_units() {
            return Ok(BudgetOutcome::Partial {
                cycles: self.stats.internal_cycles,
                units_out: self.sink.units_out(),
            });
        }
        core.flush_stats(&mut self.stats);
        Ok(BudgetOutcome::Complete(EngineRun {
            stats: self.stats.clone(),
            preload_cycles,
            outputs: self.sink.take_collected(),
        }))
    }

    /// Preload phase: outputs disabled, run until the hierarchy saturates
    /// (no write commits for a full handshake round-trip). Preload cycles
    /// are not part of the measured run (§5.2.1: idle time between layers
    /// is used for preloading).
    fn run_preload(&mut self, core: &mut impl Core) -> Result<u64> {
        core.set_output_enabled(false);
        let mut idle_internal = 0u64;
        let mut cycles = 0u64;
        let saved_internal = self.stats.internal_cycles;
        while idle_internal < 8 {
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.external_tick(core, edge.cycle),
                ClockDomain::Internal => {
                    let writes_before: u64 = self.stats.level_writes.iter().sum();
                    self.internal_tick(core)?;
                    let writes_after: u64 = self.stats.level_writes.iter().sum();
                    cycles += 1;
                    if writes_after > writes_before {
                        idle_internal = 0;
                    } else {
                        idle_internal += 1;
                    }
                    if cycles > self.deadlock_limit {
                        return Err(Error::Integrity {
                            cycle: cycles,
                            msg: "preload did not saturate".into(),
                        });
                    }
                }
            }
        }
        self.stats.internal_cycles = saved_internal;
        self.stats.external_cycles = 0;
        core.set_output_enabled(true);
        Ok(cycles)
    }

    /// Run exactly `n` internal cycles (micro-stepping for tests and
    /// waveform capture); external edges are interleaved per the clock
    /// ratio. Returns the units emitted so far.
    pub fn step_cycles(&mut self, core: &mut impl Core, n: u64) -> Result<u64> {
        let target = self.stats.internal_cycles + n;
        while self.stats.internal_cycles < target && self.sink.units_out() < core.total_units() {
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.external_tick(core, edge.cycle),
                ClockDomain::Internal => self.internal_tick(core)?,
            }
        }
        Ok(self.sink.units_out())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::offchip::payload_for;

    fn spec(total: u64) -> StreamSpec {
        StreamSpec {
            start_address: 0,
            stride: 1,
            cycle_length: 4,
            inter_cycle_shift: 0,
            skip_shift: 0,
            sub_width: 32,
            total_units: total,
            payload: payload_for,
        }
    }

    /// A trivial core: emits one correct unit every `cadence` internal
    /// cycles.
    struct CountingCore {
        total: u64,
        cadence: u64,
        tick: u64,
        next_unit: u64,
        enabled: bool,
        wrong_payload: bool,
    }

    impl CountingCore {
        fn new(total: u64, cadence: u64) -> Self {
            Self { total, cadence, tick: 0, next_unit: 0, enabled: true, wrong_payload: false }
        }
    }

    impl Core for CountingCore {
        fn external_edge(&mut self, _ext_cycle: u64) {}

        fn internal_edge(&mut self, ctx: &mut CycleCtx<'_>) -> Result<()> {
            self.tick += 1;
            if self.enabled && self.tick % self.cadence == 0 && !ctx.sink.complete() {
                let addr = self.next_unit % 4; // cyclic l=4 stream
                self.next_unit += 1;
                let word = if self.wrong_payload {
                    Word::zero(32)
                } else {
                    payload_for(addr, 32)
                };
                ctx.sink.emit(&[addr], word, ctx.cycle, ctx.stats)?;
            }
            Ok(())
        }

        fn set_output_enabled(&mut self, on: bool) {
            self.enabled = on;
        }

        fn total_units(&self) -> u64 {
            self.total
        }

        fn flush_stats(&mut self, _stats: &mut SimStats) {}
    }

    #[test]
    fn engine_runs_core_to_completion() {
        let mut core = CountingCore::new(16, 2);
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(16));
        let r = eng.run(&mut core, false).unwrap();
        assert_eq!(r.stats.outputs, 16);
        assert_eq!(r.stats.internal_cycles, 32, "one emission every 2 cycles");
        assert_eq!(r.preload_cycles, 0);
    }

    #[test]
    fn budgeted_run_partials_then_completes_identically() {
        // 16 units at one emission per 2 cycles = 32 cycles total.
        let mut core = CountingCore::new(16, 2);
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(16));
        match eng.run_budget(&mut core, false, 10).unwrap() {
            BudgetOutcome::Partial { cycles, units_out } => {
                assert_eq!(cycles, 10);
                assert_eq!(units_out, 5);
            }
            other => panic!("expected partial, got {other:?}"),
        }
        // A fresh, fully-budgeted run matches a plain run bit for bit.
        let mut core_a = CountingCore::new(16, 2);
        let mut eng_a = Engine::new(ClockPair::synchronous(), 0, spec(16));
        let a = match eng_a.run_budget(&mut core_a, false, 1_000).unwrap() {
            BudgetOutcome::Complete(r) => r,
            other => panic!("expected complete, got {other:?}"),
        };
        let mut core_b = CountingCore::new(16, 2);
        let mut eng_b = Engine::new(ClockPair::synchronous(), 0, spec(16));
        let b = eng_b.run(&mut core_b, false).unwrap();
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.preload_cycles, b.preload_cycles);
    }

    #[test]
    fn engine_detects_payload_corruption() {
        let mut core = CountingCore::new(8, 1);
        core.wrong_payload = true;
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(8));
        match eng.run(&mut core, false) {
            Err(Error::Integrity { msg, .. }) => {
                assert!(msg.contains("payload corruption"), "{msg}")
            }
            other => panic!("expected integrity error, got {other:?}"),
        }
    }

    #[test]
    fn engine_deadlock_guard_fires() {
        // A core that never emits: the guard must trip rather than spin
        // forever.
        let mut core = CountingCore::new(8, 1);
        core.enabled = false;
        let mut eng = Engine::new(ClockPair::synchronous(), 0, spec(8));
        eng.deadlock_limit = 1_000; // keep the test fast
        match eng.run(&mut core, false) {
            Err(Error::Integrity { msg, .. }) => {
                assert!(msg.contains("no output progress"), "{msg}")
            }
            other => panic!("expected deadlock error, got {other:?}"),
        }
    }

    #[test]
    fn sink_collection_pools_buffers() {
        let mut sink = OutputSink::new(spec(64));
        sink.collect = true;
        sink.verify = false;
        let mut stats = SimStats::new(0);
        for i in 0..4 {
            sink.emit(&[i, i + 1], Word::zero(64), i, &mut stats).unwrap();
        }
        let outs = sink.take_collected();
        assert_eq!(outs.len(), 4);
        assert_eq!(outs[2].addrs, vec![2, 3]);
        // Recycle and re-emit: buffers come from the pool.
        sink.recycle(outs);
        assert_eq!(sink.addr_pool.len(), 4);
        sink.emit(&[9], Word::zero(32), 9, &mut stats).unwrap();
        assert_eq!(sink.addr_pool.len(), 3, "one pooled buffer reused");
        assert_eq!(sink.take_collected()[0].addrs, vec![9]);
    }

    #[test]
    fn sink_verifies_address_stream() {
        let mut sink = OutputSink::new(spec(8));
        let mut stats = SimStats::new(0);
        // Expected stream is 0,1,2,3,0,1,... — unit 1 out of order fails.
        sink.emit(&[0], payload_for(0, 32), 0, &mut stats).unwrap();
        let err = sink.emit(&[3], payload_for(3, 32), 1, &mut stats).unwrap_err();
        assert!(err.to_string().contains("expected"), "{err}");
    }
}

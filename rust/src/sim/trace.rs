//! Waveform capture — the simulator's equivalent of the paper's Figure 4.
//!
//! A [`Waveform`] records named scalar signals per internal cycle and can
//! render them as a VCD file (viewable in GTKWave) or as ASCII art for the
//! report binaries.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Handle to a registered signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaveformProbe(usize);

/// Recorded multi-signal waveform.
#[derive(Debug, Default)]
pub struct Waveform {
    names: Vec<String>,
    widths: Vec<u32>,
    /// changes[i] = (time, value) list for signal i, sparse.
    changes: Vec<Vec<(u64, u64)>>,
    max_time: u64,
}

impl Waveform {
    /// New empty waveform.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a signal of `width` bits; returns its probe handle.
    pub fn probe(&mut self, name: &str, width: u32) -> WaveformProbe {
        self.names.push(name.to_string());
        self.widths.push(width);
        self.changes.push(Vec::new());
        WaveformProbe(self.names.len() - 1)
    }

    /// Record signal value at `time` (only stored if it changed).
    pub fn record(&mut self, probe: WaveformProbe, time: u64, value: u64) {
        self.max_time = self.max_time.max(time);
        let ch = &mut self.changes[probe.0];
        if ch.last().map(|&(_, v)| v) != Some(value) {
            ch.push((time, value));
        }
    }

    /// Value of a signal at `time` (last change at or before `time`).
    pub fn value_at(&self, probe: WaveformProbe, time: u64) -> Option<u64> {
        let ch = &self.changes[probe.0];
        match ch.binary_search_by_key(&time, |&(t, _)| t) {
            Ok(i) => Some(ch[i].1),
            Err(0) => None,
            Err(i) => Some(ch[i - 1].1),
        }
    }

    /// Render as VCD (IEEE 1364). Timescale is one internal clock cycle.
    pub fn to_vcd(&self, module: &str) -> String {
        let mut s = String::new();
        s.push_str("$date memhier simulation $end\n");
        s.push_str("$timescale 1 ns $end\n");
        let _ = writeln!(s, "$scope module {module} $end");
        let ids: Vec<String> = (0..self.names.len())
            .map(|i| {
                // Printable VCD identifier characters start at '!'.
                let c = char::from_u32(33 + (i as u32 % 90)).unwrap();
                if i < 90 { c.to_string() } else { format!("{c}{}", i / 90) }
            })
            .collect();
        for (i, name) in self.names.iter().enumerate() {
            let _ = writeln!(s, "$var wire {} {} {} $end", self.widths[i], ids[i], name);
        }
        s.push_str("$upscope $end\n$enddefinitions $end\n");
        // Merge changes by time.
        let mut by_time: BTreeMap<u64, Vec<(usize, u64)>> = BTreeMap::new();
        for (i, ch) in self.changes.iter().enumerate() {
            for &(t, v) in ch {
                by_time.entry(t).or_default().push((i, v));
            }
        }
        for (t, evs) in by_time {
            let _ = writeln!(s, "#{t}");
            for (i, v) in evs {
                if self.widths[i] == 1 {
                    let _ = writeln!(s, "{}{}", v & 1, ids[i]);
                } else {
                    let _ = writeln!(s, "b{v:b} {}", ids[i]);
                }
            }
        }
        s
    }

    /// Compact ASCII rendering over `[t0, t1)` — used by the
    /// `report waveform` command to reproduce the shape of Figure 4.
    pub fn to_ascii(&self, t0: u64, t1: u64) -> String {
        let mut out = String::new();
        let name_w = self.names.iter().map(|n| n.len()).max().unwrap_or(0);
        for (i, name) in self.names.iter().enumerate() {
            let _ = write!(out, "{name:>name_w$} ");
            for t in t0..t1 {
                let v = self.value_at(WaveformProbe(i), t);
                match v {
                    None => out.push('.'),
                    Some(v) if self.widths[i] == 1 => out.push(if v == 1 { '#' } else { '_' }),
                    Some(v) => {
                        let _ = write!(out, "{:>2}|", v % 100);
                    }
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut w = Waveform::new();
        let p = w.probe("read_write", 1);
        w.record(p, 0, 0);
        w.record(p, 3, 1);
        w.record(p, 5, 0);
        assert_eq!(w.value_at(p, 0), Some(0));
        assert_eq!(w.value_at(p, 2), Some(0));
        assert_eq!(w.value_at(p, 3), Some(1));
        assert_eq!(w.value_at(p, 4), Some(1));
        assert_eq!(w.value_at(p, 9), Some(0));
    }

    #[test]
    fn deduplicates_unchanged_values() {
        let mut w = Waveform::new();
        let p = w.probe("sig", 8);
        w.record(p, 0, 5);
        w.record(p, 1, 5);
        w.record(p, 2, 6);
        assert_eq!(w.changes[p.0].len(), 2);
    }

    #[test]
    fn vcd_structure() {
        let mut w = Waveform::new();
        let a = w.probe("we", 1);
        let b = w.probe("addr", 16);
        w.record(a, 0, 1);
        w.record(b, 0, 9);
        w.record(a, 1, 0);
        let vcd = w.to_vcd("hier");
        assert!(vcd.contains("$var wire 1"));
        assert!(vcd.contains("$var wire 16"));
        assert!(vcd.contains("#0"));
        assert!(vcd.contains("#1"));
        assert!(vcd.contains("b1001 "));
    }

    #[test]
    fn ascii_render() {
        let mut w = Waveform::new();
        let p = w.probe("we", 1);
        w.record(p, 0, 0);
        w.record(p, 2, 1);
        let art = w.to_ascii(0, 4);
        assert!(art.contains("we"));
        assert!(art.contains("__##"));
    }
}

//! Batched co-simulation sessions: many programs, one warm hierarchy.
//!
//! The paper's framework is per-layer reconfigurable — the same physical
//! hierarchy executes a different access pattern for every DNN layer —
//! but a naive simulator tears the whole model down per program. A
//! [`Session`] keeps one [`Hierarchy`] alive across program loads: every
//! component (levels, input buffer, OSR, off-chip model, stats, output
//! sink) is re-armed in place by `load_program`, so the allocator is out
//! of the steady-state loop entirely. [`Session::rearm`] additionally
//! swaps the *configuration* in place, which is what lets one session
//! score an entire DSE candidate stream.
//!
//! ## Determinism guarantee
//!
//! A warm session is observationally identical to a cold one: for any
//! program sequence, `run_program` returns bit-for-bit the same
//! [`SimStats`](crate::sim::SimStats) and output words a freshly
//! constructed `Hierarchy` would return for each program in isolation.
//! The `warm_session` integration tests assert this for every pattern
//! family; `dse` and `coordinator::server` rely on it.

use crate::config::HierarchyConfig;
use crate::mem::{BudgetedRun, Hierarchy, HierarchyCheckpoint, OutputWord, RunResult};
use crate::pattern::PatternProgram;
use crate::Result;

/// A warm-reusable simulation session (see module docs).
///
/// The verify/collect switches are **session-owned** state: the session
/// remembers the values set through [`Session::set_verify`] /
/// [`Session::set_collect`] and re-asserts them on every
/// [`Session::rearm`]. A caller that flips the switches directly on the
/// borrowed [`Session::hierarchy`] (as a one-off for a single run) cannot
/// silently leak the setting into later candidates — the next re-arm
/// restores the session's values.
pub struct Session {
    h: Hierarchy,
    programs_run: u64,
    /// Session-owned verify switch, re-asserted on re-arm.
    verify: bool,
    /// Session-owned collect switch, re-asserted on re-arm.
    collect: bool,
}

impl Session {
    /// Open a session for `cfg`.
    pub fn new(cfg: &HierarchyConfig) -> Result<Self> {
        let h = Hierarchy::new(cfg)?;
        let (verify, collect) = (h.verify_enabled(), h.collect_enabled());
        Ok(Self { h, programs_run: 0, verify, collect })
    }

    /// Wrap an existing hierarchy (adopts its verify/collect settings and
    /// any warmth it already has).
    pub fn from_hierarchy(h: Hierarchy) -> Self {
        let (verify, collect) = (h.verify_enabled(), h.collect_enabled());
        Self { h, programs_run: 0, verify, collect }
    }

    /// Re-configure the session in place (no reallocation of reusable
    /// storage); the next `run_program` simulates under `cfg`. The
    /// session's verify/collect settings are re-asserted, undoing any
    /// transient per-run override made directly on the hierarchy.
    pub fn rearm(&mut self, cfg: &HierarchyConfig) -> Result<()> {
        self.h.rearm(cfg)?;
        self.h.set_verify(self.verify);
        self.h.set_collect(self.collect);
        Ok(())
    }

    /// Enable/disable end-to-end data verification (sticky across
    /// programs and re-arms).
    pub fn set_verify(&mut self, on: bool) {
        self.verify = on;
        self.h.set_verify(on);
    }

    /// Enable output collection (sticky across programs and re-arms).
    pub fn set_collect(&mut self, on: bool) {
        self.collect = on;
        self.h.set_collect(on);
    }

    /// Run one program on the warm hierarchy to completion.
    pub fn run_program(&mut self, prog: &PatternProgram) -> Result<RunResult> {
        self.h.load_program(prog)?;
        let r = self.h.run()?;
        self.programs_run += 1;
        Ok(r)
    }

    /// Run one program with a cycle budget (successive-halving
    /// screening); only completed runs count toward `programs_run`.
    pub fn run_program_budgeted(
        &mut self,
        prog: &PatternProgram,
        budget: u64,
    ) -> Result<BudgetedRun> {
        self.h.load_program(prog)?;
        let r = self.h.run_budgeted(budget)?;
        if matches!(r, BudgetedRun::Complete(_)) {
            self.programs_run += 1;
        }
        Ok(r)
    }

    /// Run a batch of programs back-to-back; per-program results in
    /// order. Fails fast on the first erroring program.
    pub fn run_batch(&mut self, progs: &[PatternProgram]) -> Result<Vec<RunResult>> {
        progs.iter().map(|p| self.run_program(p)).collect()
    }

    /// Capture the session's loaded program state as a checkpoint — the
    /// session-handoff primitive the serving tier's speculative warmer
    /// uses to park a pre-simulated hierarchy (wire-encodable via
    /// [`crate::mem::wire`]) for another session to adopt. Errors if no
    /// program is loaded.
    pub fn snapshot(&self) -> Result<HierarchyCheckpoint> {
        self.h.snapshot()
    }

    /// Adopt a parked checkpoint: re-arm to its configuration, load
    /// `workload`, and restore the captured state. After this call the
    /// session continues bit-identically to the session that took the
    /// snapshot (see [`crate::mem::HierarchyCheckpoint`]).
    pub fn resume(&mut self, ck: &HierarchyCheckpoint, workload: &PatternProgram) -> Result<()> {
        self.rearm(ck.config())?;
        self.h.load_program(workload)?;
        self.h.restore(ck)
    }

    /// Hand consumed output buffers back to the collection pool so
    /// repeated collected runs stay allocation-free.
    pub fn recycle_outputs(&mut self, outputs: Vec<OutputWord>) {
        self.h.recycle_outputs(outputs);
    }

    /// Programs completed on this session so far.
    pub fn programs_run(&self) -> u64 {
        self.programs_run
    }

    /// Direct access to the underlying hierarchy (waveforms, stepping,
    /// fault injection).
    pub fn hierarchy(&mut self) -> &mut Hierarchy {
        &mut self.h
    }

    /// The active configuration.
    pub fn config(&self) -> &HierarchyConfig {
        self.h.config()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_level() -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap()
    }

    #[test]
    fn batch_results_match_isolated_runs() {
        let cfg = two_level();
        let progs = vec![
            PatternProgram::cyclic(0, 64).with_outputs(640),
            PatternProgram::sequential(100, 200),
            PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        ];
        let mut session = Session::new(&cfg).unwrap();
        let batch = session.run_batch(&progs).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(session.programs_run(), 3);
        for (p, r) in progs.iter().zip(batch.iter()) {
            let mut fresh = Hierarchy::new(&cfg).unwrap();
            fresh.load_program(p).unwrap();
            let f = fresh.run().unwrap();
            assert_eq!(r.stats, f.stats, "warm batch diverged on {p:?}");
        }
    }

    #[test]
    fn budgeted_screening_counts_only_completions() {
        let cfg = two_level();
        let mut session = Session::new(&cfg).unwrap();
        let slow = PatternProgram::cyclic(0, 64).with_outputs(6_400);
        match session.run_program_budgeted(&slow, 100).unwrap() {
            BudgetedRun::Partial { units_out, .. } => assert!(units_out < 6_400),
            other => panic!("expected partial, got {other:?}"),
        }
        assert_eq!(session.programs_run(), 0);
        match session.run_program_budgeted(&slow, u64::MAX).unwrap() {
            BudgetedRun::Complete(r) => assert_eq!(r.stats.outputs, 6_400),
            other => panic!("expected complete, got {other:?}"),
        }
        assert_eq!(session.programs_run(), 1);
    }

    #[test]
    fn rearm_restores_session_verify_and_collect() {
        // A transient override made directly on the hierarchy (the DSE
        // screening paths used to do this and leak it) is undone by the
        // next re-arm: the session's own settings win.
        let cfg = two_level();
        let mut session = Session::new(&cfg).unwrap();
        session.set_collect(true);
        assert!(session.hierarchy().verify_enabled(), "verify defaults on");
        session.hierarchy().set_verify(false);
        session.hierarchy().set_collect(false);
        session.rearm(&cfg).unwrap();
        assert!(session.hierarchy().verify_enabled(), "rearm must restore verify");
        assert!(session.hierarchy().collect_enabled(), "rearm must restore collect");
        // And the restored verify sink actually checks data: an injected
        // bit flip must surface as an integrity error.
        let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
        session.hierarchy().load_program(&prog).unwrap();
        session.hierarchy().step_cycles(120).unwrap();
        assert!(session.hierarchy().inject_bit_flip(1, 5, 7), "slot 5 must be occupied");
        assert!(session.hierarchy().run().is_err(), "corruption must be caught");
        // Session-level settings survive re-arms by design.
        session.set_verify(false);
        session.rearm(&cfg).unwrap();
        assert!(!session.hierarchy().verify_enabled(), "session-owned value sticks");
    }

    #[test]
    fn rearm_switches_configuration() {
        let a = two_level();
        let b = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 64, 1, 2)
            .build()
            .unwrap();
        let prog = PatternProgram::cyclic(0, 32).with_outputs(320);
        let mut session = Session::new(&a).unwrap();
        let ra = session.run_program(&prog).unwrap();
        session.rearm(&b).unwrap();
        let rb = session.run_program(&prog).unwrap();
        // The single-level config has no second-level pipeline stage, so
        // the runs must differ — proving the re-arm took effect...
        assert_ne!(ra.stats.level_writes, rb.stats.level_writes);
        // ...while matching a cold simulation of the same config.
        let mut fresh = Hierarchy::new(&b).unwrap();
        fresh.load_program(&prog).unwrap();
        assert_eq!(rb.stats, fresh.run().unwrap().stats);
    }
}

//! Batched co-simulation sessions: many programs, one warm hierarchy.
//!
//! The paper's framework is per-layer reconfigurable — the same physical
//! hierarchy executes a different access pattern for every DNN layer —
//! but a naive simulator tears the whole model down per program. A
//! [`Session`] keeps one [`Hierarchy`] alive across program loads: every
//! component (levels, input buffer, OSR, off-chip model, stats, output
//! sink) is re-armed in place by `load_program`, so the allocator is out
//! of the steady-state loop entirely. [`Session::rearm`] additionally
//! swaps the *configuration* in place, which is what lets one session
//! score an entire DSE candidate stream.
//!
//! ## Determinism guarantee
//!
//! A warm session is observationally identical to a cold one: for any
//! program sequence, `run_program` returns bit-for-bit the same
//! [`SimStats`](crate::sim::SimStats) and output words a freshly
//! constructed `Hierarchy` would return for each program in isolation.
//! The `warm_session` integration tests assert this for every pattern
//! family; `dse` and `coordinator::server` rely on it.

use crate::config::HierarchyConfig;
use crate::mem::{BudgetedRun, Hierarchy, OutputWord, RunResult};
use crate::pattern::PatternProgram;
use crate::Result;

/// A warm-reusable simulation session (see module docs).
pub struct Session {
    h: Hierarchy,
    programs_run: u64,
}

impl Session {
    /// Open a session for `cfg`.
    pub fn new(cfg: &HierarchyConfig) -> Result<Self> {
        Ok(Self { h: Hierarchy::new(cfg)?, programs_run: 0 })
    }

    /// Wrap an existing hierarchy (keeps its verify/collect settings and
    /// any warmth it already has).
    pub fn from_hierarchy(h: Hierarchy) -> Self {
        Self { h, programs_run: 0 }
    }

    /// Re-configure the session in place (no reallocation of reusable
    /// storage); the next `run_program` simulates under `cfg`.
    pub fn rearm(&mut self, cfg: &HierarchyConfig) -> Result<()> {
        self.h.rearm(cfg)
    }

    /// Enable/disable end-to-end data verification (sticky across
    /// programs).
    pub fn set_verify(&mut self, on: bool) {
        self.h.set_verify(on);
    }

    /// Enable output collection (sticky across programs).
    pub fn set_collect(&mut self, on: bool) {
        self.h.set_collect(on);
    }

    /// Run one program on the warm hierarchy to completion.
    pub fn run_program(&mut self, prog: &PatternProgram) -> Result<RunResult> {
        self.h.load_program(prog)?;
        let r = self.h.run()?;
        self.programs_run += 1;
        Ok(r)
    }

    /// Run one program with a cycle budget (successive-halving
    /// screening); only completed runs count toward `programs_run`.
    pub fn run_program_budgeted(
        &mut self,
        prog: &PatternProgram,
        budget: u64,
    ) -> Result<BudgetedRun> {
        self.h.load_program(prog)?;
        let r = self.h.run_budgeted(budget)?;
        if matches!(r, BudgetedRun::Complete(_)) {
            self.programs_run += 1;
        }
        Ok(r)
    }

    /// Run a batch of programs back-to-back; per-program results in
    /// order. Fails fast on the first erroring program.
    pub fn run_batch(&mut self, progs: &[PatternProgram]) -> Result<Vec<RunResult>> {
        progs.iter().map(|p| self.run_program(p)).collect()
    }

    /// Hand consumed output buffers back to the collection pool so
    /// repeated collected runs stay allocation-free.
    pub fn recycle_outputs(&mut self, outputs: Vec<OutputWord>) {
        self.h.recycle_outputs(outputs);
    }

    /// Programs completed on this session so far.
    pub fn programs_run(&self) -> u64 {
        self.programs_run
    }

    /// Direct access to the underlying hierarchy (waveforms, stepping,
    /// fault injection).
    pub fn hierarchy(&mut self) -> &mut Hierarchy {
        &mut self.h
    }

    /// The active configuration.
    pub fn config(&self) -> &HierarchyConfig {
        self.h.config()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_level() -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap()
    }

    #[test]
    fn batch_results_match_isolated_runs() {
        let cfg = two_level();
        let progs = vec![
            PatternProgram::cyclic(0, 64).with_outputs(640),
            PatternProgram::sequential(100, 200),
            PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(960),
        ];
        let mut session = Session::new(&cfg).unwrap();
        let batch = session.run_batch(&progs).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(session.programs_run(), 3);
        for (p, r) in progs.iter().zip(batch.iter()) {
            let mut fresh = Hierarchy::new(&cfg).unwrap();
            fresh.load_program(p).unwrap();
            let f = fresh.run().unwrap();
            assert_eq!(r.stats, f.stats, "warm batch diverged on {p:?}");
        }
    }

    #[test]
    fn budgeted_screening_counts_only_completions() {
        let cfg = two_level();
        let mut session = Session::new(&cfg).unwrap();
        let slow = PatternProgram::cyclic(0, 64).with_outputs(6_400);
        match session.run_program_budgeted(&slow, 100).unwrap() {
            BudgetedRun::Partial { units_out, .. } => assert!(units_out < 6_400),
            other => panic!("expected partial, got {other:?}"),
        }
        assert_eq!(session.programs_run(), 0);
        match session.run_program_budgeted(&slow, u64::MAX).unwrap() {
            BudgetedRun::Complete(r) => assert_eq!(r.stats.outputs, 6_400),
            other => panic!("expected complete, got {other:?}"),
        }
        assert_eq!(session.programs_run(), 1);
    }

    #[test]
    fn rearm_switches_configuration() {
        let a = two_level();
        let b = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 64, 1, 2)
            .build()
            .unwrap();
        let prog = PatternProgram::cyclic(0, 32).with_outputs(320);
        let mut session = Session::new(&a).unwrap();
        let ra = session.run_program(&prog).unwrap();
        session.rearm(&b).unwrap();
        let rb = session.run_program(&prog).unwrap();
        // The single-level config has no second-level pipeline stage, so
        // the runs must differ — proving the re-arm took effect...
        assert_ne!(ra.stats.level_writes, rb.stats.level_writes);
        // ...while matching a cold simulation of the same config.
        let mut fresh = Hierarchy::new(&b).unwrap();
        fresh.load_program(&prog).unwrap();
        assert_eq!(rb.stats, fresh.run().unwrap().stats);
    }
}

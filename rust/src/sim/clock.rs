//! Two-domain clock scheduling.
//!
//! Simulation time advances on a common base (the GCD of both periods);
//! each [`ClockDomain`] fires an edge every `period` base ticks. The
//! hierarchy steps on internal edges; the input buffer and off-chip
//! interface step on external edges. When both domains fire on the same
//! base tick, the *external* domain is stepped first — data crossing the
//! CDC still needs an explicit synchronizer cycle in the receiving domain
//! (modelled in `mem::input_buffer`), mirroring the paper's metastability
//! discussion.

use crate::util::gcd;

/// Identifies one of the two clock domains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockDomain {
    /// Off-chip µC clock (`external_clk_i`).
    External,
    /// Accelerator clock (`internal_clk_i`).
    Internal,
}

/// An edge event produced by [`ClockPair::next_edge`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    /// Which domain fired.
    pub domain: ClockDomain,
    /// Absolute time in base ticks.
    pub time: u64,
    /// Cycle index within the firing domain (0-based).
    pub cycle: u64,
}

/// Scheduler for a pair of free-running clocks described by their
/// frequencies in Hz.
///
/// The pair is plain registered state (`PartialEq`, `Clone`): capturing it
/// and restoring the copy later resumes the edge schedule exactly where it
/// stopped, which is what makes mid-run simulation checkpoints
/// ([`crate::mem::hierarchy::HierarchyCheckpoint`]) possible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClockPair {
    ext_period: u64,
    int_period: u64,
    ext_next: u64,
    int_next: u64,
    ext_cycle: u64,
    int_cycle: u64,
}

impl ClockPair {
    /// Build from frequencies (Hz). Periods are normalized by their GCD so
    /// base ticks stay small.
    pub fn from_freqs(external_hz: u64, internal_hz: u64) -> Self {
        assert!(external_hz > 0 && internal_hz > 0, "frequencies must be positive");
        // period ∝ 1/f — scale by the other frequency to stay integral.
        let ext_period = internal_hz;
        let int_period = external_hz;
        let g = gcd(ext_period, int_period);
        Self {
            ext_period: ext_period / g,
            int_period: int_period / g,
            ext_next: 0,
            int_next: 0,
            ext_cycle: 0,
            int_cycle: 0,
        }
    }

    /// 1:1 clocks (the §5.2 performance experiments assume the off-chip
    /// interface keeps pace with the accelerator).
    pub fn synchronous() -> Self {
        Self::from_freqs(1, 1)
    }

    /// Serialize for the checkpoint wire format (destructured so a newly
    /// added register must be encoded here explicitly).
    pub(crate) fn wire_write(&self, w: &mut crate::util::frame::ByteWriter) {
        let Self { ext_period, int_period, ext_next, int_next, ext_cycle, int_cycle } = self;
        w.put_u64(*ext_period);
        w.put_u64(*int_period);
        w.put_u64(*ext_next);
        w.put_u64(*int_next);
        w.put_u64(*ext_cycle);
        w.put_u64(*int_cycle);
    }

    /// Checked decode: zero periods are rejected (a legitimately captured
    /// pair always has positive, GCD-normalized periods; a zero period
    /// would stall the edge schedule forever).
    pub(crate) fn wire_read(r: &mut crate::util::frame::ByteReader<'_>) -> crate::Result<Self> {
        let ck = Self {
            ext_period: r.get_u64()?,
            int_period: r.get_u64()?,
            ext_next: r.get_u64()?,
            int_next: r.get_u64()?,
            ext_cycle: r.get_u64()?,
            int_cycle: r.get_u64()?,
        };
        if ck.ext_period == 0 || ck.int_period == 0 {
            return Err(crate::Error::Parse("wire: clock period must be positive".into()));
        }
        Ok(ck)
    }

    /// Ratio of external to internal frequency.
    pub fn ratio(&self) -> f64 {
        self.int_period as f64 / self.ext_period as f64
    }

    /// Produce the next clock edge in time order. On ties the external
    /// domain fires first (see module docs).
    pub fn next_edge(&mut self) -> Edge {
        if self.ext_next <= self.int_next {
            let e = Edge { domain: ClockDomain::External, time: self.ext_next, cycle: self.ext_cycle };
            self.ext_next += self.ext_period;
            self.ext_cycle += 1;
            e
        } else {
            let e = Edge { domain: ClockDomain::Internal, time: self.int_next, cycle: self.int_cycle };
            self.int_next += self.int_period;
            self.int_cycle += 1;
            e
        }
    }

    /// Number of internal edges that will fire strictly before the
    /// external edge with cycle index `c` (on a time tie the external
    /// domain fires first, see module docs). Pure query — the schedule is
    /// not advanced. This is how the engine's event-horizon fast-forward
    /// sizes a bulk skip that ends at an external wake-up event.
    pub fn internal_edges_before_external(&self, c: u64) -> u64 {
        debug_assert!(c >= self.ext_cycle, "external cycle {c} already fired");
        // The external edge with cycle index c fires at time c * period
        // (ext_next tracks ext_cycle * ext_period exactly).
        let t = c * self.ext_period;
        if self.int_next >= t {
            0
        } else {
            (t - self.int_next).div_ceil(self.int_period)
        }
    }

    /// Bulk-advance the schedule so the *next* edge is the external edge
    /// with cycle index `c`: consumes every earlier external edge and
    /// every internal edge firing strictly before time `c × ext_period`,
    /// exactly as repeated [`Self::next_edge`] calls would. Returns the
    /// `(external, internal)` edge counts consumed. O(1).
    pub fn skip_to_external_cycle(&mut self, c: u64) -> (u64, u64) {
        debug_assert!(c >= self.ext_cycle, "external cycle {c} already fired");
        let ints = self.internal_edges_before_external(c);
        let exts = c - self.ext_cycle;
        self.ext_cycle = c;
        self.ext_next = c * self.ext_period;
        self.int_cycle += ints;
        self.int_next += ints * self.int_period;
        (exts, ints)
    }

    /// Bulk-advance the schedule through exactly `n` internal edges plus
    /// every external edge scheduled before them (time ties fire external
    /// first), exactly as repeated [`Self::next_edge`] calls until the
    /// n-th internal edge would. Returns the external edges consumed.
    /// O(1).
    pub fn skip_internal_edges(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        // Firing time of the n-th upcoming internal edge.
        let t_n = self.int_next + (n - 1) * self.int_period;
        // External edges with time <= t_n fire before it (tie -> ext).
        let exts =
            if self.ext_next > t_n { 0 } else { (t_n - self.ext_next) / self.ext_period + 1 };
        self.int_cycle += n;
        self.int_next = t_n + self.int_period;
        self.ext_cycle += exts;
        self.ext_next += exts * self.ext_period;
        exts
    }

    /// Internal cycles spanned by `n` external cycles, rounded up — the
    /// clock-ratio conversion behind the preload saturation window.
    pub fn internal_span_of_external(&self, n: u64) -> u64 {
        (n * self.ext_period).div_ceil(self.int_period)
    }

    /// Internal cycles elapsed so far.
    pub fn internal_cycles(&self) -> u64 {
        self.int_cycle
    }

    /// External cycles elapsed so far.
    pub fn external_cycles(&self) -> u64 {
        self.ext_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(cp: &mut ClockPair, n: usize) -> Vec<(ClockDomain, u64)> {
        (0..n).map(|_| { let e = cp.next_edge(); (e.domain, e.time) }).collect()
    }

    #[test]
    fn synchronous_interleaves_ext_first() {
        let mut cp = ClockPair::synchronous();
        let edges = collect(&mut cp, 6);
        assert_eq!(
            edges,
            vec![
                (ClockDomain::External, 0),
                (ClockDomain::Internal, 0),
                (ClockDomain::External, 1),
                (ClockDomain::Internal, 1),
                (ClockDomain::External, 2),
                (ClockDomain::Internal, 2),
            ]
        );
    }

    #[test]
    fn case_study_ratio_4_to_1() {
        // 1 MHz external, 250 kHz internal (§5.3.2).
        let mut cp = ClockPair::from_freqs(1_000_000, 250_000);
        assert!((cp.ratio() - 4.0).abs() < 1e-12);
        let mut ext_between_int = 0;
        let mut counts = Vec::new();
        for _ in 0..40 {
            match cp.next_edge().domain {
                ClockDomain::External => ext_between_int += 1,
                ClockDomain::Internal => {
                    counts.push(ext_between_int);
                    ext_between_int = 0;
                }
            }
        }
        // Every internal cycle sees exactly 4 external edges (first window
        // includes the t=0 tie).
        assert!(counts.iter().all(|&c| c == 4 || c == 1), "got {counts:?}");
        assert_eq!(counts.iter().filter(|&&c| c == 4).count() + 1, counts.len());
    }

    #[test]
    fn slow_external_clock() {
        // External at half the internal rate: two internal edges per external.
        let mut cp = ClockPair::from_freqs(1, 2);
        let edges = collect(&mut cp, 9);
        let internals = edges.iter().filter(|(d, _)| *d == ClockDomain::Internal).count();
        let externals = edges.len() - internals;
        assert!(internals >= 2 * externals - 2, "{edges:?}");
    }

    #[test]
    fn same_tick_fires_external_before_internal() {
        // 4:1 ratio: both domains coincide every 4th external edge; the
        // external edge must come out first on every coincidence (CDC
        // data still needs the synchronizer cycle in the receiving
        // domain).
        let mut cp = ClockPair::from_freqs(4, 1);
        let mut last: Option<Edge> = None;
        for _ in 0..64 {
            let e = cp.next_edge();
            if let Some(prev) = last {
                if prev.time == e.time {
                    assert_eq!(prev.domain, ClockDomain::External, "tie at t={}", e.time);
                    assert_eq!(e.domain, ClockDomain::Internal);
                }
            }
            last = Some(e);
        }
    }

    #[test]
    fn gcd_normalization_keeps_base_ticks_small() {
        // 1 MHz : 250 kHz normalizes to periods 1 : 4 — edge times are
        // small integers, not raw Hz-scaled products.
        let mut cp = ClockPair::from_freqs(1_000_000, 250_000);
        let mut ext_times = Vec::new();
        let mut int_times = Vec::new();
        for _ in 0..15 {
            let e = cp.next_edge();
            match e.domain {
                ClockDomain::External => ext_times.push(e.time),
                ClockDomain::Internal => int_times.push(e.time),
            }
        }
        assert_eq!(ext_times, vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]);
        assert_eq!(int_times, vec![0, 4, 8]);
        // Equal clocks normalize to period 1 regardless of magnitude.
        let mut cp = ClockPair::from_freqs(123_456_789, 123_456_789);
        let times: Vec<u64> = (0..6).map(|_| cp.next_edge().time).collect();
        assert_eq!(times, vec![0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn cycle_indices_are_monotone_per_domain() {
        // Every domain's cycle index counts 0,1,2,... with no skips, and
        // edge times never go backwards — for any ratio.
        for (e_hz, i_hz) in [(1u64, 1u64), (4, 1), (1, 4), (3, 7), (1_000_000, 250_000)] {
            let mut cp = ClockPair::from_freqs(e_hz, i_hz);
            let mut next_ext = 0u64;
            let mut next_int = 0u64;
            let mut last_time = 0u64;
            for _ in 0..200 {
                let e = cp.next_edge();
                assert!(e.time >= last_time, "time went backwards at {e:?}");
                last_time = e.time;
                match e.domain {
                    ClockDomain::External => {
                        assert_eq!(e.cycle, next_ext, "{e_hz}:{i_hz}");
                        next_ext += 1;
                    }
                    ClockDomain::Internal => {
                        assert_eq!(e.cycle, next_int, "{e_hz}:{i_hz}");
                        next_int += 1;
                    }
                }
            }
            assert_eq!(cp.external_cycles(), next_ext);
            assert_eq!(cp.internal_cycles(), next_int);
        }
    }

    #[test]
    fn skip_to_external_cycle_matches_edge_by_edge() {
        // The closed-form bulk advance must consume exactly the edges the
        // naive scheduler would pop before the target external edge, for
        // every ratio and from every starting phase.
        for (e_hz, i_hz) in [(1u64, 1u64), (4, 1), (1, 4), (3, 7), (1_000_000, 250_000)] {
            for warmup in [0usize, 1, 5, 13] {
                for ahead in [0u64, 1, 3, 17] {
                    let mut fast = ClockPair::from_freqs(e_hz, i_hz);
                    let mut slow = ClockPair::from_freqs(e_hz, i_hz);
                    for _ in 0..warmup {
                        fast.next_edge();
                        slow.next_edge();
                    }
                    let c = fast.external_cycles() + ahead;
                    let (exts, ints) = fast.skip_to_external_cycle(c);
                    let (mut ne, mut ni) = (0u64, 0u64);
                    loop {
                        // Stop when the next edge is external edge c.
                        if slow.ext_next <= slow.int_next && slow.external_cycles() == c {
                            break;
                        }
                        match slow.next_edge().domain {
                            ClockDomain::External => ne += 1,
                            ClockDomain::Internal => ni += 1,
                        }
                    }
                    assert_eq!((exts, ints), (ne, ni), "{e_hz}:{i_hz} w={warmup} a={ahead}");
                    assert_eq!(fast, slow, "{e_hz}:{i_hz} w={warmup} a={ahead}");
                    let next = fast.next_edge();
                    assert_eq!((next.domain, next.cycle), (ClockDomain::External, c));
                }
            }
        }
    }

    #[test]
    fn skip_internal_edges_matches_edge_by_edge() {
        for (e_hz, i_hz) in [(1u64, 1u64), (4, 1), (1, 4), (3, 7), (1_000_000, 250_000)] {
            for warmup in [0usize, 1, 5, 13] {
                for n in [1u64, 2, 7, 29] {
                    let mut fast = ClockPair::from_freqs(e_hz, i_hz);
                    let mut slow = ClockPair::from_freqs(e_hz, i_hz);
                    for _ in 0..warmup {
                        fast.next_edge();
                        slow.next_edge();
                    }
                    let exts = fast.skip_internal_edges(n);
                    let (mut ne, mut ni) = (0u64, 0u64);
                    while ni < n {
                        match slow.next_edge().domain {
                            ClockDomain::External => ne += 1,
                            ClockDomain::Internal => ni += 1,
                        }
                    }
                    assert_eq!(exts, ne, "{e_hz}:{i_hz} w={warmup} n={n}");
                    assert_eq!(fast, slow, "{e_hz}:{i_hz} w={warmup} n={n}");
                }
            }
        }
    }

    #[test]
    fn internal_span_of_external_converts_by_ratio() {
        // 1:1 clocks: one internal cycle per external cycle.
        assert_eq!(ClockPair::synchronous().internal_span_of_external(7), 7);
        // External 4x faster: 8 external cycles span 2 internal.
        assert_eq!(ClockPair::from_freqs(4, 1).internal_span_of_external(8), 2);
        assert_eq!(ClockPair::from_freqs(4, 1).internal_span_of_external(7), 2, "rounds up");
        // External 2x slower: 3 external cycles span 6 internal.
        assert_eq!(ClockPair::from_freqs(1, 2).internal_span_of_external(3), 6);
    }

    #[test]
    fn cycle_counters_track_edges() {
        let mut cp = ClockPair::from_freqs(3, 1);
        for _ in 0..100 {
            cp.next_edge();
        }
        assert_eq!(cp.internal_cycles() + cp.external_cycles(), 100);
        // 3:1 ratio → roughly 3 external edges per internal edge.
        let r = cp.external_cycles() as f64 / cp.internal_cycles() as f64;
        assert!((r - 3.0).abs() < 0.2, "ratio {r}");
    }
}

//! Deterministic fault-injection campaigns.
//!
//! A [`FaultPlan`] schedules upsets at exact (component, cycle, bit)
//! coordinates: single/multi-bit flips and stuck-at faults into any
//! stateful component (standard level slots, ping-pong halves, the input
//! buffer's FIFO + CDC flops + fill register, the OSR bit-FIFO, the
//! off-chip in-flight pipeline) plus *timing* faults (delayed or dropped
//! off-chip deliveries). [`crate::mem::Hierarchy::arm_faults`] attaches a
//! plan to a run; each event is delivered to its component through the
//! [`Stage::inject`](crate::sim::engine::Stage::inject) hook on the exact
//! scheduled edge (pending faults pin the quiescence horizon to `Active`,
//! so fast-forward never skips a scheduled cycle).
//!
//! ## Classification
//!
//! The end-to-end verify sink is the corruption oracle: every emitted
//! word is checked against the expected address/payload stream, so a
//! payload upset that survives to an output fails the run with an
//! integrity error, and a timing fault that starves the pipeline trips
//! the no-progress guard. [`classify`] maps a run to a deployment-view
//! [`FaultOutcome`]:
//!
//! * **Masked** — the run completed with outputs bit-identical to the
//!   fault-free baseline (the upset landed in dead storage or was
//!   overwritten before use).
//! * **Corrected** — SECDED scrubbed the upset; outputs bit-identical to
//!   fault-free ([`FaultReport::corrected`] is non-zero).
//! * **Detected** — a parity-protected level flagged the upset: the
//!   deployment knows the run is suspect (and may retry from a
//!   checkpoint), whatever the data did.
//! * **Silent** — corruption reached the output stream with no hardware
//!   flag raised: the deployment-silent case the protection dimension
//!   exists to buy down. (In simulation the verify sink *reports* it;
//!   real hardware would not.)
//! * **Hung** — the fault starved the pipeline and the no-progress guard
//!   fired (e.g. a dropped delivery the input buffer waits on forever).
//!
//! ## Protection semantics
//!
//! Per-level [`Protection`] is modelled **per upset at injection time**:
//! a scheduled flip/stuck-at that would change a stored bit of a
//! parity-protected level raises the detection flag instead of mutating
//! state (parity detects any odd-weight upset; the flagged run never
//! silently corrupts), and on a SECDED-protected level is corrected on
//! the spot (outputs stay bit-identical to fault-free). Upsets that land
//! in an empty slot, out of range, or would not change the bit (a
//! stuck-at matching the stored value) are **vacant** under every
//! protection level. This is deliberately conservative about multi-bit
//! upsets: each scheduled event is an independent single-bit upset, so a
//! double flip in one word is two events, each independently handled —
//! the aliasing window of a real SECDED codec under simultaneous
//! double-bit upsets is not modelled.
//!
//! ## Determinism
//!
//! Everything is seeded: [`FaultPlan::random`] derives a plan from a
//! `u64` seed and the configuration shape, and [`run_campaign`] expands a
//! campaign seed into per-run seeds with `SplitMix64`. The same (config,
//! program, seed, runs) quadruple reproduces the same
//! [`FaultCampaignStats`] bit for bit, on any platform.

use crate::config::{HierarchyConfig, Protection};
use crate::mem::{Hierarchy, OutputWord, RunResult};
use crate::pattern::PatternProgram;
use crate::util::bitword::Word;
use crate::util::rng::{Rng, SplitMix64, Xoshiro256};
use crate::{Error, Result};
use std::collections::BTreeMap;

/// What an upset does to the targeted bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Invert the stored bit (a soft-error bit flip).
    Flip,
    /// Force the bit to 0 (a stuck-at-zero cell).
    Stuck0,
    /// Force the bit to 1 (a stuck-at-one cell).
    Stuck1,
}

impl FaultKind {
    /// The post-upset value of a bit currently holding `cur` (0 or 1).
    pub fn apply(self, cur: u64) -> u64 {
        match self {
            FaultKind::Flip => cur ^ 1,
            FaultKind::Stuck0 => 0,
            FaultKind::Stuck1 => 1,
        }
    }

    /// Perturb one bit of `word` in place. Returns whether the stored
    /// value actually changed (`false` = out of range, or a stuck-at
    /// matching the stored bit — a vacant upset either way).
    pub fn perturb(self, word: &mut Word, bit: u32) -> bool {
        if bit >= word.width() {
            return false;
        }
        let cur = word.bits(bit, 1).as_u64();
        let new = self.apply(cur);
        if new == cur {
            return false;
        }
        word.set_bits(bit, &Word::from_u64(new, 1));
        true
    }
}

/// The exact state element an upset targets, interpreted by the owning
/// component's [`Stage::inject`](crate::sim::engine::Stage::inject)
/// implementation. Sites a component does not recognize are vacant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// A payload bit of the word stored in a level slot (standard banked
    /// levels index all banks; ping-pong levels index both halves,
    /// `[0, half_depth)` = half 0).
    Slot {
        /// Slot index within the level's storage.
        slot: u64,
        /// Payload bit within the stored word.
        bit: u32,
        /// Upset kind.
        kind: FaultKind,
    },
    /// A payload bit of a FIFO entry (input-buffer queue or OSR bit-FIFO;
    /// entry 0 = front/oldest).
    FifoEntry {
        /// Queue position (0 = oldest).
        entry: usize,
        /// Payload bit within the queued word.
        bit: u32,
        /// Upset kind.
        kind: FaultKind,
    },
    /// Invert one flop of the input buffer's two-stage `buffer_full` CDC
    /// synchronizer (0 = meta stage, 1 = synced stage).
    SyncFlop {
        /// Which flop (0 = meta, 1 = synced).
        which: u8,
    },
    /// A bit of the input buffer's fill register under construction.
    FillReg {
        /// Bit within the fill register.
        bit: u32,
        /// Upset kind.
        kind: FaultKind,
    },
    /// Invert one address bit of the *oldest* in-flight off-chip request
    /// (the word delivered will carry the wrong payload). Vacant if
    /// nothing is in flight or the flip would leave the address space.
    InflightAddr {
        /// Address bit to invert.
        bit: u32,
    },
    /// Delay the oldest in-flight off-chip delivery by `extra` external
    /// cycles (head-of-line blocking: later deliveries queue behind it).
    DelayDelivery {
        /// Additional external cycles of latency.
        extra: u64,
    },
    /// Drop the oldest in-flight off-chip delivery entirely — the word
    /// never arrives, and the requester's outstanding count never drains
    /// (the bus-error / lost-beat failure mode).
    DropDelivery,
}

/// The stateful component an upset targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultComponent {
    /// Hierarchy level `i` (standard or ping-pong).
    Level(usize),
    /// The input buffer (FIFO, CDC flops, fill register).
    InputBuffer,
    /// The output shift register's bit-FIFO.
    Osr,
    /// The off-chip memory's in-flight pipeline.
    OffChip,
}

impl FaultComponent {
    /// Whether the component's upset clock is the internal (accelerator)
    /// domain; off-chip faults are scheduled in external cycles.
    pub fn is_internal(self) -> bool {
        !matches!(self, FaultComponent::OffChip)
    }

    /// Stable display label (campaign tally key).
    pub fn label(self) -> String {
        match self {
            FaultComponent::Level(i) => format!("L{i}"),
            FaultComponent::InputBuffer => "input-buffer".into(),
            FaultComponent::Osr => "osr".into(),
            FaultComponent::OffChip => "off-chip".into(),
        }
    }
}

/// One scheduled upset: a (cycle, component, site) coordinate. `at` is an
/// internal-clock cycle for level / input-buffer / OSR faults and an
/// external-clock cycle for off-chip faults (each component's natural
/// domain — the edge on which its state mutates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Cycle (in the component's clock domain) on whose edge the upset
    /// lands, *before* the edge's regular state transitions.
    pub at: u64,
    /// Targeted component.
    pub component: FaultComponent,
    /// Targeted state element.
    pub site: FaultSite,
}

/// A deterministic schedule of upsets for one run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An empty plan (injects nothing; running under it is bit-identical
    /// to running with no plan armed).
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder: append one scheduled upset.
    pub fn with(mut self, at: u64, component: FaultComponent, site: FaultSite) -> Self {
        self.events.push(FaultEvent { at, component, site });
        self
    }

    /// The scheduled upsets, in insertion order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Whether the plan schedules nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Generate a seeded single-component plan of 1–3 upsets within the
    /// first `window` cycles, shaped by the configuration (slot counts,
    /// word widths, FIFO depths). The same (config shape, window, seed)
    /// triple reproduces the same plan bit for bit.
    pub fn random(cfg: &HierarchyConfig, window: u64, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let n_levels = cfg.levels.len();
        // Component menu: every level, the input buffer, off-chip, and
        // the OSR when configured.
        let n_choices = n_levels + 2 + usize::from(cfg.osr.is_some());
        let pick = rng.gen_range(n_choices as u64) as usize;
        let component = if pick < n_levels {
            FaultComponent::Level(pick)
        } else if pick == n_levels {
            FaultComponent::InputBuffer
        } else if pick == n_levels + 1 {
            FaultComponent::OffChip
        } else {
            FaultComponent::Osr
        };
        let span = window.max(2);
        let n_events = 1 + rng.gen_range(3);
        let mut plan = Self::new();
        for _ in 0..n_events {
            let at = 1 + rng.gen_range(span - 1);
            let kind = match rng.gen_range(4) {
                0 | 1 => FaultKind::Flip,
                2 => FaultKind::Stuck0,
                _ => FaultKind::Stuck1,
            };
            let site = match component {
                FaultComponent::Level(l) => {
                    let lc = &cfg.levels[l];
                    FaultSite::Slot {
                        slot: rng.gen_range(lc.capacity_words()),
                        bit: rng.gen_range(u64::from(lc.word_width)) as u32,
                        kind,
                    }
                }
                FaultComponent::InputBuffer => {
                    let w0 = cfg.levels[0].word_width;
                    match rng.gen_range(4) {
                        0 => FaultSite::SyncFlop { which: rng.gen_range(2) as u8 },
                        1 => FaultSite::FillReg {
                            bit: rng.gen_range(u64::from(w0)) as u32,
                            kind,
                        },
                        _ => FaultSite::FifoEntry {
                            entry: rng.gen_range(u64::from(cfg.offchip.ib_depth)) as usize,
                            bit: rng.gen_range(u64::from(w0)) as u32,
                            kind,
                        },
                    }
                }
                FaultComponent::Osr => {
                    // OSR queue entries are last-level words awaiting
                    // their shift out.
                    let o = cfg.osr.as_ref().expect("picked only when configured");
                    let wl = cfg.last_level().word_width;
                    let entries = u64::from(o.width / wl).max(1);
                    FaultSite::FifoEntry {
                        entry: rng.gen_range(entries) as usize,
                        bit: rng.gen_range(u64::from(wl)) as u32,
                        kind,
                    }
                }
                FaultComponent::OffChip => match rng.gen_range(4) {
                    0 => FaultSite::DelayDelivery { extra: 1 + rng.gen_range(16) },
                    1 => FaultSite::DropDelivery,
                    _ => FaultSite::InflightAddr {
                        bit: rng.gen_range(u64::from(cfg.offchip.addr_width.min(48))) as u32,
                    },
                },
            };
            plan = plan.with(at, component, site);
        }
        plan
    }
}

/// Per-run injection accounting, filled in as scheduled events land.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Upsets that mutated unprotected state.
    pub injected: u64,
    /// Upsets corrected on the spot by a SECDED-protected level.
    pub corrected: u64,
    /// Upsets detected (flagged, not injected) by a parity-protected
    /// level.
    pub detected: u64,
    /// Off-chip deliveries delayed.
    pub delayed: u64,
    /// Off-chip deliveries dropped.
    pub dropped: u64,
    /// Upsets that landed in vacant storage (empty slot, out-of-range
    /// bit, stuck-at matching the stored value, nothing in flight) or
    /// whose scheduled cycle the run never reached.
    pub vacant: u64,
}

/// The armed runtime state of a [`FaultPlan`]: per-domain event queues
/// sorted by cycle, plus the accumulating [`FaultReport`]. Owned by the
/// hierarchy core while armed; deliberately **not** checkpointed — a
/// fault campaign owns its runs end to end, and a checkpoint restored
/// elsewhere resumes fault-free.
#[derive(Debug, Clone)]
pub struct FaultState {
    internal: Vec<FaultEvent>,
    external: Vec<FaultEvent>,
    next_internal: usize,
    next_external: usize,
    /// Injection accounting so far.
    pub report: FaultReport,
}

impl FaultState {
    /// Arm a plan: partition events by clock domain and sort each queue
    /// by cycle (stable, so same-cycle events land in plan order).
    pub fn new(plan: &FaultPlan) -> Self {
        let mut internal: Vec<FaultEvent> =
            plan.events.iter().copied().filter(|e| e.component.is_internal()).collect();
        let mut external: Vec<FaultEvent> =
            plan.events.iter().copied().filter(|e| !e.component.is_internal()).collect();
        internal.sort_by_key(|e| e.at);
        external.sort_by_key(|e| e.at);
        Self { internal, external, next_internal: 0, next_external: 0, report: FaultReport::default() }
    }

    /// Whether any scheduled event has not yet landed. While true, the
    /// hierarchy pins its quiescence horizon to `Active` so fast-forward
    /// cannot skip a scheduled edge.
    pub fn pending(&self) -> bool {
        self.next_internal < self.internal.len() || self.next_external < self.external.len()
    }

    /// Pop the next internal-domain event due at or before `cycle`.
    pub fn take_due_internal(&mut self, cycle: u64) -> Option<FaultEvent> {
        let ev = self.internal.get(self.next_internal)?;
        if ev.at > cycle {
            return None;
        }
        self.next_internal += 1;
        Some(*ev)
    }

    /// Pop the next external-domain event due at or before `cycle`.
    pub fn take_due_external(&mut self, cycle: u64) -> Option<FaultEvent> {
        let ev = self.external.get(self.next_external)?;
        if ev.at > cycle {
            return None;
        }
        self.next_external += 1;
        Some(*ev)
    }

    /// Close out the state: events whose cycle the run never reached are
    /// counted as vacant (the run ended first), and the final report is
    /// returned.
    pub fn finish(self) -> FaultReport {
        let mut r = self.report;
        r.vacant += (self.internal.len() - self.next_internal) as u64
            + (self.external.len() - self.next_external) as u64;
        r
    }
}

/// Deployment-view outcome of one faulted run (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOutcome {
    /// Outputs bit-identical to fault-free; nothing flagged.
    Masked,
    /// SECDED corrected every landed upset; outputs bit-identical.
    Corrected,
    /// Parity flagged the run (whatever the data did).
    Detected,
    /// Corruption reached the outputs with no hardware flag.
    Silent,
    /// The pipeline starved and the no-progress guard fired.
    Hung,
}

/// Whether a run error is the engine's no-progress (deadlock) guard.
fn is_hang(e: &Error) -> bool {
    matches!(e, Error::Integrity { msg, .. } if msg.contains("no output progress"))
}

/// Classify one faulted run against the fault-free baseline outputs (the
/// run must have been executed with verification *and* collection on, so
/// `Ok` results carry the emitted stream).
pub fn classify(
    result: &Result<RunResult>,
    report: &FaultReport,
    baseline: &[OutputWord],
) -> FaultOutcome {
    match result {
        Err(e) if is_hang(e) => FaultOutcome::Hung,
        // The verify sink caught corruption in flight: hardware without a
        // flag would have consumed it silently — unless parity flagged
        // the run, in which case the deployment knows to discard it.
        Err(_) if report.detected > 0 => FaultOutcome::Detected,
        Err(_) => FaultOutcome::Silent,
        Ok(r) => {
            if r.outputs != baseline {
                if report.detected > 0 {
                    FaultOutcome::Detected
                } else {
                    FaultOutcome::Silent
                }
            } else if report.detected > 0 {
                FaultOutcome::Detected
            } else if report.corrected > 0 {
                FaultOutcome::Corrected
            } else {
                FaultOutcome::Masked
            }
        }
    }
}

/// Outcome counts for a set of runs (one campaign total, or one
/// component's slice of it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Tally {
    /// Runs classified.
    pub runs: u64,
    /// [`FaultOutcome::Masked`] runs.
    pub masked: u64,
    /// [`FaultOutcome::Corrected`] runs.
    pub corrected: u64,
    /// [`FaultOutcome::Detected`] runs.
    pub detected: u64,
    /// [`FaultOutcome::Silent`] runs.
    pub silent: u64,
    /// [`FaultOutcome::Hung`] runs.
    pub hung: u64,
}

impl Tally {
    /// Record one run's outcome.
    pub fn add(&mut self, o: FaultOutcome) {
        self.runs += 1;
        match o {
            FaultOutcome::Masked => self.masked += 1,
            FaultOutcome::Corrected => self.corrected += 1,
            FaultOutcome::Detected => self.detected += 1,
            FaultOutcome::Silent => self.silent += 1,
            FaultOutcome::Hung => self.hung += 1,
        }
    }

    /// AVF-style vulnerability: the fraction of runs whose fault was
    /// *not* absorbed (detected, silent, or hung — anything the
    /// deployment would notice or suffer). 0.0 for an empty tally.
    pub fn vulnerability(&self) -> f64 {
        if self.runs == 0 {
            return 0.0;
        }
        (self.detected + self.silent + self.hung) as f64 / self.runs as f64
    }
}

/// Aggregated results of a seeded campaign sweep
/// ([`run_campaign`]): per-component and total outcome tallies plus the
/// summed injection accounting. Deterministic given (config, program,
/// seed, runs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultCampaignStats {
    /// Outcome tally over all runs.
    pub total: Tally,
    /// Outcome tally per targeted component (key =
    /// [`FaultComponent::label`]).
    pub per_component: BTreeMap<String, Tally>,
    /// Summed per-run injection reports.
    pub report: FaultReport,
    /// Total upsets scheduled across all runs.
    pub events_scheduled: u64,
}

/// Run a seeded fault campaign: one fault-free baseline run (collected,
/// verified), then `runs` faulted runs on the same warm hierarchy, each
/// under a single-component [`FaultPlan::random`] plan derived from the
/// campaign seed. Returns the aggregated per-component tallies.
///
/// The internal-cycle span of the baseline bounds the scheduling window,
/// so every plan lands within a nominal run. A dropped delivery hangs
/// the run; the hierarchy's no-progress guard is tightened (relative to
/// the conservative default) to keep hung runs cheap without risking
/// false positives on nominal stall gaps.
pub fn run_campaign(
    cfg: &HierarchyConfig,
    prog: &PatternProgram,
    seed: u64,
    runs: u64,
) -> Result<FaultCampaignStats> {
    let mut h = Hierarchy::new(cfg)?;
    h.set_collect(true);
    // Nominal stall gaps are bounded by handshake latencies (tens of
    // cycles); 25k cycles without an output is unambiguously a hang.
    h.set_deadlock_limit(25_000);
    h.load_program(prog)?;
    let base = h.run()?;
    let baseline = base.outputs;
    let window = base.stats.internal_cycles + base.preload_cycles;
    let mut stats = FaultCampaignStats::default();
    let mut seeder = SplitMix64::new(seed);
    for _ in 0..runs {
        let run_seed = seeder.next_u64();
        let plan = FaultPlan::random(cfg, window, run_seed);
        let label = plan.events()[0].component.label();
        stats.events_scheduled += plan.events().len() as u64;
        h.load_program(prog)?;
        h.arm_faults(&plan);
        let result = h.run();
        let report = h.clear_faults().unwrap_or_default();
        let outcome = classify(&result, &report, &baseline);
        stats.total.add(outcome);
        stats.per_component.entry(label).or_default().add(outcome);
        let FaultReport { injected, corrected, detected, delayed, dropped, vacant } = report;
        stats.report.injected += injected;
        stats.report.corrected += corrected;
        stats.report.detected += detected;
        stats.report.delayed += delayed;
        stats.report.dropped += dropped;
        stats.report.vacant += vacant;
    }
    Ok(stats)
}

/// Campaign helper for protection sweeps: the same campaign run under a
/// uniform per-level protection override (every level set to `protect`).
/// This is what the soundness tests and the bench's coverage summary
/// sweep over.
pub fn run_campaign_protected(
    cfg: &HierarchyConfig,
    prog: &PatternProgram,
    protect: Protection,
    seed: u64,
    runs: u64,
) -> Result<FaultCampaignStats> {
    let mut cfg = cfg.clone();
    for l in &mut cfg.levels {
        l.protection = protect;
    }
    run_campaign(&cfg, prog, seed, runs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_apply_and_perturb() {
        assert_eq!(FaultKind::Flip.apply(0), 1);
        assert_eq!(FaultKind::Flip.apply(1), 0);
        assert_eq!(FaultKind::Stuck0.apply(1), 0);
        assert_eq!(FaultKind::Stuck1.apply(0), 1);
        let mut w = Word::from_u64(0b0101, 4);
        assert!(FaultKind::Flip.perturb(&mut w, 1));
        assert_eq!(w.as_u64(), 0b0111);
        assert!(!FaultKind::Stuck1.perturb(&mut w, 1), "already 1: vacant");
        assert!(FaultKind::Stuck0.perturb(&mut w, 1));
        assert_eq!(w.as_u64(), 0b0101);
        assert!(!FaultKind::Flip.perturb(&mut w, 4), "out of range is vacant");
    }

    #[test]
    fn state_orders_and_finishes() {
        let plan = FaultPlan::new()
            .with(30, FaultComponent::Level(0), FaultSite::Slot { slot: 0, bit: 0, kind: FaultKind::Flip })
            .with(10, FaultComponent::Level(1), FaultSite::Slot { slot: 1, bit: 2, kind: FaultKind::Flip })
            .with(20, FaultComponent::OffChip, FaultSite::DropDelivery);
        let mut st = FaultState::new(&plan);
        assert!(st.pending());
        assert!(st.take_due_internal(5).is_none());
        let a = st.take_due_internal(10).unwrap();
        assert_eq!(a.at, 10, "sorted by cycle");
        assert!(st.take_due_internal(10).is_none());
        let b = st.take_due_external(25).unwrap();
        assert!(matches!(b.site, FaultSite::DropDelivery));
        assert!(st.pending(), "cycle-30 event still scheduled");
        // Run ends before cycle 30: the leftover counts as vacant.
        let r = st.finish();
        assert_eq!(r.vacant, 1);
        assert_eq!(r.injected, 0);
    }

    #[test]
    fn random_plans_are_deterministic_and_in_window() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .osr(64, vec![32])
            .build()
            .unwrap();
        for seed in 0..50u64 {
            let a = FaultPlan::random(&cfg, 1_000, seed);
            let b = FaultPlan::random(&cfg, 1_000, seed);
            assert_eq!(a, b, "seed {seed} must reproduce");
            assert!(!a.is_empty() && a.events().len() <= 3);
            let c0 = a.events()[0].component;
            for e in a.events() {
                assert!(e.at >= 1 && e.at < 1_000, "in window: {e:?}");
                assert_eq!(e.component, c0, "single-component plan");
            }
        }
        // Different seeds diversify the targeted component.
        let mut labels = std::collections::BTreeSet::new();
        for seed in 0..200u64 {
            labels.insert(FaultPlan::random(&cfg, 1_000, seed).events()[0].component.label());
        }
        assert!(labels.len() >= 4, "components covered: {labels:?}");
    }

    #[test]
    fn tally_vulnerability() {
        let mut t = Tally::default();
        t.add(FaultOutcome::Masked);
        t.add(FaultOutcome::Silent);
        t.add(FaultOutcome::Hung);
        t.add(FaultOutcome::Detected);
        assert_eq!(t.runs, 4);
        assert!((t.vulnerability() - 0.75).abs() < 1e-12);
        assert_eq!(Tally::default().vulnerability(), 0.0);
    }
}

//! Trace classification: recover pattern family and parameters from a raw
//! address trace.
//!
//! This is the analysis half of §5.3 — the loop-nest analyzer generates
//! memory traces for every feasible unrolling and this module detects the
//! access-pattern class, cycle length and inter-cycle shift that the MCU
//! would need (Table 2 reports exactly these quantities per TC-ResNet
//! layer).

use crate::config::LevelKind;
use std::collections::HashSet;

/// Result of classifying an address trace.
#[derive(Debug, Clone, PartialEq)]
pub enum Classification {
    /// Empty or single-access trace.
    Trivial,
    /// Constant stride 1, all addresses distinct.
    Sequential {
        /// First address.
        start: u64,
    },
    /// Constant stride > 1, all addresses distinct.
    Strided {
        /// First address.
        start: u64,
        /// Constant stride.
        stride: u64,
    },
    /// Fixed window replayed identically (shift 0).
    Cyclic {
        /// Window base.
        start: u64,
        /// Cycle length.
        cycle_length: u64,
    },
    /// Overlapping windows: cycle length `l`, base shifting by `s` every
    /// `skip_shift + 1` cycles.
    ShiftedCyclic {
        /// First window base.
        start: u64,
        /// Cycle length.
        cycle_length: u64,
        /// Inter-cycle shift.
        inter_cycle_shift: u64,
        /// Cycles between shifts minus one.
        skip_shift: u64,
    },
    /// Several shifted-cyclic streams visited round-robin (§3.2 f). The
    /// MCU of the paper cannot execute these directly (§5.3: "some
    /// unrolling scenarios currently lack MCU support").
    ParallelShiftedCyclic {
        /// Number of interleaved streams detected.
        parts: usize,
        /// Cycle length of each part.
        cycle_length: u64,
    },
    /// No structure detected.
    PseudoRandom,
}

impl Classification {
    /// Cycle length if the classification has one (Table 2 column).
    pub fn cycle_length(&self) -> Option<u64> {
        match self {
            Classification::Cyclic { cycle_length, .. }
            | Classification::ShiftedCyclic { cycle_length, .. }
            | Classification::ParallelShiftedCyclic { cycle_length, .. } => Some(*cycle_length),
            Classification::Sequential { .. } | Classification::Strided { .. } => Some(1),
            _ => None,
        }
    }

    /// Whether the paper's MCU supports executing this pattern directly.
    pub fn mcu_supported(&self) -> bool {
        !matches!(
            self,
            Classification::ParallelShiftedCyclic { .. } | Classification::PseudoRandom
        )
    }

    /// How a level of the given kind executes this pattern family.
    ///
    /// Standard levels replay cyclic windows residently when the window
    /// fits (capacity is a sizing question, not a capability one — this
    /// reports the *capability*). Double-buffered levels clear slots as
    /// they drain, so every family they support runs in streaming mode;
    /// unsupported families stay unsupported regardless of kind.
    pub fn execution_mode(&self, kind: &LevelKind) -> ExecutionMode {
        if !self.mcu_supported() {
            return ExecutionMode::Unsupported;
        }
        match (self, kind) {
            (
                Classification::Cyclic { .. } | Classification::ShiftedCyclic { .. },
                LevelKind::Standard { .. },
            ) => ExecutionMode::ResidentReuse,
            _ => ExecutionMode::Streaming,
        }
    }
}

/// How a hierarchy level kind can execute a classified pattern family
/// (see [`Classification::execution_mode`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionMode {
    /// The level holds the window resident and replays it (the Listing 1
    /// reuse reads; each unique word is fetched once from upstream).
    ResidentReuse,
    /// The level streams the words through in arrival order; off-chip
    /// replays any duplicates (§5.3 "data from a single off-chip address
    /// must be stored several times").
    Streaming,
    /// The MCU cannot execute the pattern at all.
    Unsupported,
}

/// Number of unique addresses in a trace.
pub fn unique_addresses(trace: &[u64]) -> u64 {
    trace.iter().copied().collect::<HashSet<_>>().len() as u64
}

/// The trace as the MCU fetch stream sees it: uniform runs compressed
/// away (a port word held for `r` consecutive MAC steps costs one fetch,
/// not `r` — the read pointer simply stays put, §3.2). This is exactly
/// the normalization [`classify_trace`] applies before classifying, so a
/// pattern program reproducing `effective_trace(t)` models the fetch
/// traffic of raw trace `t`. Compression applies at most once: the
/// compressed trace has no consecutive duplicates left.
pub fn effective_trace(trace: &[u64]) -> Vec<u64> {
    compress_uniform_runs(trace).unwrap_or_else(|| trace.to_vec())
}

/// Classify an address trace. Deterministic, O(n·√n) worst case.
pub fn classify_trace(trace: &[u64]) -> Classification {
    if trace.len() < 2 {
        return Classification::Trivial;
    }

    // 0. Uniform-run compression: weight traces often hold one address for
    //    r consecutive MAC steps (e.g. a 1×1 conv's port word reused across
    //    the whole X loop). The pattern class is that of the compressed
    //    trace; the MCU simply leaves the read pointer in place.
    if let Some(compressed) = compress_uniform_runs(trace) {
        return classify_trace(&compressed);
    }

    // 1. Constant-stride check (sequential / strided).
    if let Some(stride) = constant_stride(trace) {
        if stride == 1 {
            return Classification::Sequential { start: trace[0] };
        }
        if stride > 1 {
            return Classification::Strided { start: trace[0], stride: stride as u64 };
        }
        // Negative / zero strides fall through to cyclic analysis.
    }

    // 2. Cyclic family: the smallest window length l such that every
    //    window of l accesses is dense (base..base+l — the MCU's read
    //    pointer walk) and the window bases follow a uniform shift
    //    schedule. Checking density first prevents mistaking a shifted
    //    cycle for interleaved parallel streams.
    let n = trace.len();
    for l in 2..=(n / 2) {
        if !windows_dense(trace, l) {
            continue;
        }
        let bases: Vec<u64> = trace.chunks(l).take(n / l).map(|w| w[0]).collect();
        if bases.iter().all(|&b| b == bases[0]) {
            return Classification::Cyclic { start: trace[0], cycle_length: l as u64 };
        }
        if let Some((s, k)) = shift_schedule(&bases) {
            return Classification::ShiftedCyclic {
                start: trace[0],
                cycle_length: l as u64,
                inter_cycle_shift: s,
                skip_shift: k,
            };
        }
        // Dense windows with an irregular base schedule: try larger l.
    }

    // 3. Interleaved dense streams (parallel-shifted cyclic, §3.2 f).
    for cand in 2..=8usize {
        if let Some((parts, part_len)) = interleaved_streams(trace, cand) {
            return Classification::ParallelShiftedCyclic { parts, cycle_length: part_len };
        }
    }

    Classification::PseudoRandom
}

/// If every address in the trace repeats exactly `r >= 2` times
/// consecutively, return the run-compressed trace.
fn compress_uniform_runs(trace: &[u64]) -> Option<Vec<u64>> {
    let mut runs: Vec<(u64, u64)> = Vec::new();
    for &a in trace {
        match runs.last_mut() {
            Some((v, n)) if *v == a => *n += 1,
            _ => runs.push((a, 1)),
        }
    }
    if runs.len() < 2 || runs.len() == trace.len() {
        return None; // no runs, or nothing compressed
    }
    let r = runs[0].1;
    if r < 2 || !runs.iter().all(|&(_, n)| n == r) {
        return None;
    }
    Some(runs.into_iter().map(|(v, _)| v).collect())
}

/// If the trace has a constant first-difference, return it.
fn constant_stride(trace: &[u64]) -> Option<i64> {
    let d = trace[1] as i64 - trace[0] as i64;
    for w in trace.windows(2) {
        if w[1] as i64 - w[0] as i64 != d {
            return None;
        }
    }
    Some(d)
}

/// Are all windows of length `l` (including a trailing partial one) dense,
/// i.e. `w[i] == w[0] + i`?
fn windows_dense(trace: &[u64], l: usize) -> bool {
    trace
        .chunks(l)
        .all(|w| w.iter().enumerate().all(|(i, &a)| a == w[0] + i as u64))
}

/// Given per-cycle window bases, recover (shift, skip_shift) if the bases
/// advance by a fixed `s` every `k+1` cycles (zeros in between).
fn shift_schedule(bases: &[u64]) -> Option<(u64, u64)> {
    if bases.len() < 2 {
        return None;
    }
    let deltas: Vec<u64> = bases.windows(2).map(|w| w[1].checked_sub(w[0])).collect::<Option<_>>()?;
    let s = *deltas.iter().find(|&&d| d > 0)?;
    // Count run length of zeros between shifts; must be uniform.
    let mut k: Option<u64> = None;
    let mut zeros = 0u64;
    for &d in &deltas {
        if d == 0 {
            zeros += 1;
        } else if d == s {
            match k {
                None => k = Some(zeros),
                Some(kk) if kk == zeros => {}
                _ => return None,
            }
            zeros = 0;
        } else {
            return None;
        }
    }
    Some((s, k.unwrap_or(0)))
}

/// Try interpreting the trace as `p` interleaved dense streams with a
/// common block length (each stream runs `block` consecutive accesses).
fn interleaved_streams(trace: &[u64], p: usize) -> Option<(usize, u64)> {
    // Find block length: run of unit-stride accesses at the start.
    let mut block = 1usize;
    while block < trace.len() && trace[block] == trace[block - 1] + 1 {
        block += 1;
    }
    if block == trace.len() || block == 0 {
        return None;
    }
    let total = p * block;
    if trace.len() < 2 * total {
        return None;
    }
    // Every block must be dense; blocks belonging to the same stream (p
    // apart) must progress monotonically.
    for (bi, w) in trace.chunks(block).enumerate() {
        if !w.iter().enumerate().all(|(i, &a)| a == w[0] + i as u64) {
            return None;
        }
        if bi >= p {
            let prev_base = trace[(bi - p) * block];
            if w[0] < prev_base {
                return None;
            }
        }
    }
    // Distinct streams must have distinct bases.
    let bases: HashSet<u64> = (0..p).map(|i| trace[i * block]).collect();
    if bases.len() != p {
        return None;
    }
    Some((p, block as u64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::kinds::{AccessPattern, ShiftedCyclicPart};

    #[test]
    fn classify_sequential() {
        let t = AccessPattern::Sequential { start: 10, len: 50 }.addresses();
        assert_eq!(classify_trace(&t), Classification::Sequential { start: 10 });
        assert!(classify_trace(&t).mcu_supported());
    }

    #[test]
    fn classify_strided() {
        let t = AccessPattern::Strided { start: 0, stride: 4, len: 32 }.addresses();
        assert_eq!(classify_trace(&t), Classification::Strided { start: 0, stride: 4 });
    }

    #[test]
    fn classify_cyclic() {
        let t = AccessPattern::Cyclic { start: 5, cycle_length: 8, cycles: 6 }.addresses();
        assert_eq!(classify_trace(&t), Classification::Cyclic { start: 5, cycle_length: 8 });
        assert_eq!(classify_trace(&t).cycle_length(), Some(8));
    }

    #[test]
    fn classify_shifted_cyclic() {
        let t = AccessPattern::ShiftedCyclic {
            start: 0, cycle_length: 6, inter_cycle_shift: 2, skip_shift: 0, cycles: 8,
        }
        .addresses();
        assert_eq!(
            classify_trace(&t),
            Classification::ShiftedCyclic {
                start: 0, cycle_length: 6, inter_cycle_shift: 2, skip_shift: 0
            }
        );
    }

    #[test]
    fn classify_shifted_cyclic_with_skip() {
        let t = AccessPattern::ShiftedCyclic {
            start: 0, cycle_length: 4, inter_cycle_shift: 3, skip_shift: 2, cycles: 12,
        }
        .addresses();
        assert_eq!(
            classify_trace(&t),
            Classification::ShiftedCyclic {
                start: 0, cycle_length: 4, inter_cycle_shift: 3, skip_shift: 2
            }
        );
    }

    #[test]
    fn classify_parallel_shifted_cyclic() {
        let t = AccessPattern::ParallelShiftedCyclic {
            parts: vec![
                ShiftedCyclicPart { start: 0, cycle_length: 4, inter_cycle_shift: 1 },
                ShiftedCyclicPart { start: 1000, cycle_length: 4, inter_cycle_shift: 1 },
                ShiftedCyclicPart { start: 2000, cycle_length: 4, inter_cycle_shift: 1 },
            ],
            rounds: 6,
        }
        .addresses();
        let c = classify_trace(&t);
        match c {
            Classification::ParallelShiftedCyclic { parts, cycle_length } => {
                assert_eq!(parts, 3);
                assert_eq!(cycle_length, 4);
            }
            other => panic!("expected parallel classification, got {other:?}"),
        }
        assert!(!classify_trace(&t).mcu_supported());
    }

    #[test]
    fn classify_pseudo_random() {
        let t = AccessPattern::PseudoRandom { start: 0, range: 1000, len: 300, seed: 3 }.addresses();
        assert_eq!(classify_trace(&t), Classification::PseudoRandom);
        assert!(!classify_trace(&t).mcu_supported());
    }

    #[test]
    fn execution_modes_per_kind() {
        use crate::config::{LevelKind, PortKind};
        let std_kind = LevelKind::Standard { banks: 1, ports: PortKind::Single };
        let db_kind = LevelKind::DoubleBuffered;
        let cyc = Classification::Cyclic { start: 0, cycle_length: 8 };
        let shc = Classification::ShiftedCyclic {
            start: 0,
            cycle_length: 8,
            inter_cycle_shift: 2,
            skip_shift: 0,
        };
        let seq = Classification::Sequential { start: 0 };
        let par = Classification::ParallelShiftedCyclic { parts: 2, cycle_length: 4 };
        // Reuse families: resident on standard, streamed on ping-pong.
        assert_eq!(cyc.execution_mode(&std_kind), ExecutionMode::ResidentReuse);
        assert_eq!(shc.execution_mode(&std_kind), ExecutionMode::ResidentReuse);
        assert_eq!(cyc.execution_mode(&db_kind), ExecutionMode::Streaming);
        assert_eq!(shc.execution_mode(&db_kind), ExecutionMode::Streaming);
        // No-reuse families stream on both kinds.
        assert_eq!(seq.execution_mode(&std_kind), ExecutionMode::Streaming);
        assert_eq!(seq.execution_mode(&db_kind), ExecutionMode::Streaming);
        // Unsupported stays unsupported regardless of kind.
        assert_eq!(par.execution_mode(&std_kind), ExecutionMode::Unsupported);
        assert_eq!(par.execution_mode(&db_kind), ExecutionMode::Unsupported);
    }

    #[test]
    fn classify_trivial_and_unique() {
        assert_eq!(classify_trace(&[]), Classification::Trivial);
        assert_eq!(classify_trace(&[7]), Classification::Trivial);
        assert_eq!(unique_addresses(&[1, 2, 2, 3, 1]), 3);
    }

    #[test]
    fn roundtrip_random_parameters() {
        // Property-style: classify(generate(params)) == params.
        use crate::util::rng::{Rng, Xoshiro256};
        let mut rng = Xoshiro256::new(99);
        for _ in 0..50 {
            let l = 2 + rng.gen_range(30);
            let s = 1 + rng.gen_range(l - 1); // 1 <= s < l keeps windows overlapping
            let k = rng.gen_range(3);
            let t = AccessPattern::ShiftedCyclic {
                start: rng.gen_range(1000),
                cycle_length: l,
                inter_cycle_shift: s,
                skip_shift: k,
                cycles: 10 + (k + 1) * 4,
            }
            .addresses();
            match classify_trace(&t) {
                Classification::ShiftedCyclic { cycle_length, inter_cycle_shift, skip_shift, .. } => {
                    assert_eq!((cycle_length, inter_cycle_shift, skip_shift), (l, s, k));
                }
                other => panic!("l={l} s={s} k={k}: got {other:?}"),
            }
        }
    }
}

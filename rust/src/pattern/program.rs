//! MCU pattern programs — the register-level view of Table 1.
//!
//! A [`PatternProgram`] is what the off-chip µC writes into the framework's
//! configuration ports before releasing reset: a hierarchy-wide
//! `start_address` plus, for each hierarchy level, a [`LevelProgram`] with
//! `cycle_length`, `inter_cycle_shift` and `skip_shift`.
//!
//! Most callers construct a program from the *output* pattern they want the
//! accelerator to see (e.g. [`PatternProgram::shifted_cyclic`]); the
//! hierarchy derives consistent upstream level programs at load time (see
//! `mem::hierarchy`).

use super::kinds::AccessPattern;
use crate::{Error, Result};

/// Per-level MCU registers (Table 1, scope = "level").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelProgram {
    /// Pattern cycle length `l` of this level.
    pub cycle_length: u64,
    /// Data words the cycle shifts by after each completed cycle;
    /// 0 = cyclic, `== cycle_length` = linear (Table 1).
    pub inter_cycle_shift: u64,
    /// Completed cycles before the inter-cycle shift is applied.
    pub skip_shift: u64,
}

impl LevelProgram {
    /// A linear (pass-through) program of the given length — every address
    /// read exactly once in order.
    pub fn linear(cycle_length: u64) -> Self {
        Self { cycle_length, inter_cycle_shift: cycle_length, skip_shift: 0 }
    }

    /// A pure cyclic program (shift 0).
    pub fn cyclic(cycle_length: u64) -> Self {
        Self { cycle_length, inter_cycle_shift: 0, skip_shift: 0 }
    }

    /// True if this program never revisits an address.
    pub fn is_linear(&self) -> bool {
        self.inter_cycle_shift >= self.cycle_length && self.skip_shift == 0
    }

    /// New words consumed per completed cycle, on average.
    pub fn words_per_cycle(&self) -> f64 {
        self.inter_cycle_shift.min(self.cycle_length) as f64 / (self.skip_shift + 1) as f64
    }
}

/// The full pattern program written to the framework (Table 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PatternProgram {
    /// Off-chip address the framework starts requesting from
    /// (`start_address_i`, scope "hier.").
    pub start_address: u64,
    /// The *output* pattern program: executed by the last hierarchy level
    /// toward the accelerator. Upstream levels are derived at load time
    /// unless `level_overrides` pins them.
    pub output: LevelProgram,
    /// Optional explicit per-level programs (index 0 = level 0 closest to
    /// off-chip). Levels without an entry are derived.
    pub level_overrides: Vec<Option<LevelProgram>>,
    /// Address stride in the off-chip space (§3.2 d; 1 = dense).
    pub stride: u64,
    /// Total output words to produce before the pattern completes; the
    /// paper's experiments use 5 000 (§5.2).
    pub total_outputs: u64,
}

impl PatternProgram {
    /// Shifted-cyclic output pattern (the workhorse of the paper's
    /// evaluation): cycle length `l`, inter-cycle shift `s`, shift applied
    /// every cycle.
    pub fn shifted_cyclic(start_address: u64, cycle_length: u64, inter_cycle_shift: u64) -> Self {
        Self {
            start_address,
            output: LevelProgram { cycle_length, inter_cycle_shift, skip_shift: 0 },
            level_overrides: Vec::new(),
            stride: 1,
            total_outputs: 5_000,
        }
    }

    /// Pure cyclic output pattern (shift 0) — Figures 5 and 6.
    pub fn cyclic(start_address: u64, cycle_length: u64) -> Self {
        Self::shifted_cyclic(start_address, cycle_length, 0)
    }

    /// Sequential / linear output pattern — no reuse.
    pub fn sequential(start_address: u64, len: u64) -> Self {
        let mut p = Self::shifted_cyclic(start_address, len.max(1), len.max(1));
        p.total_outputs = len;
        p
    }

    /// Strided pattern: sequential with a constant address stride.
    pub fn strided(start_address: u64, stride: u64, len: u64) -> Self {
        let mut p = Self::sequential(start_address, len);
        p.stride = stride;
        p
    }

    /// Set the number of outputs to produce (builder style).
    pub fn with_outputs(mut self, n: u64) -> Self {
        self.total_outputs = n;
        self
    }

    /// Set `skip_shift` on the output program (builder style).
    pub fn with_skip_shift(mut self, k: u64) -> Self {
        self.output.skip_shift = k;
        self
    }

    /// Pin an explicit program for hierarchy level `idx` (builder style).
    pub fn with_level_override(mut self, idx: usize, prog: LevelProgram) -> Self {
        if self.level_overrides.len() <= idx {
            self.level_overrides.resize(idx + 1, None);
        }
        self.level_overrides[idx] = Some(prog);
        self
    }

    /// Validate program invariants the RTL leaves to the engineer
    /// (§4.1.4: "the framework lacks runtime input validation").
    pub fn validate(&self) -> Result<()> {
        if self.output.cycle_length == 0 {
            return Err(Error::Pattern("cycle_length must be > 0".into()));
        }
        if self.stride == 0 {
            return Err(Error::Pattern("stride must be > 0".into()));
        }
        if self.output.inter_cycle_shift > self.output.cycle_length {
            return Err(Error::Pattern(format!(
                "inter_cycle_shift {} exceeds cycle_length {} (undefined in the RTL)",
                self.output.inter_cycle_shift, self.output.cycle_length
            )));
        }
        Ok(())
    }

    /// The abstract pattern this program produces at the output — the
    /// functional oracle the simulator is checked against.
    pub fn expected_pattern(&self) -> AccessPattern {
        let l = self.output.cycle_length;
        let cycles = crate::util::ceil_div(self.total_outputs, l);
        AccessPattern::ShiftedCyclic {
            start: self.start_address,
            cycle_length: l,
            inter_cycle_shift: self.output.inter_cycle_shift,
            skip_shift: self.output.skip_shift,
            cycles,
        }
    }

    /// The exact expected output address sequence (off-chip word
    /// addresses, stride applied), truncated to `total_outputs`.
    pub fn expected_outputs(&self) -> Vec<u64> {
        self.expected_pattern()
            .stream()
            .take(self.total_outputs as usize)
            .map(|a| {
                // Stride maps logical pattern positions to off-chip addresses.
                self.start_address + (a - self.start_address) * self.stride
            })
            .collect()
    }

    /// Number of unique off-chip addresses the program touches — what the
    /// input buffer must fetch in total.
    pub fn unique_addresses(&self) -> u64 {
        let mut v = self.expected_outputs();
        v.sort_unstable();
        v.dedup();
        v.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_program_properties() {
        let p = LevelProgram::linear(16);
        assert!(p.is_linear());
        assert!((p.words_per_cycle() - 16.0).abs() < 1e-12);
        let c = LevelProgram::cyclic(16);
        assert!(!c.is_linear());
        assert_eq!(c.words_per_cycle(), 0.0);
    }

    #[test]
    fn expected_outputs_cyclic() {
        let p = PatternProgram::cyclic(0, 4).with_outputs(10);
        assert_eq!(p.expected_outputs(), vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1]);
        assert_eq!(p.unique_addresses(), 4);
    }

    #[test]
    fn expected_outputs_shifted() {
        let p = PatternProgram::shifted_cyclic(100, 4, 2).with_outputs(8);
        assert_eq!(p.expected_outputs(), vec![100, 101, 102, 103, 102, 103, 104, 105]);
        assert_eq!(p.unique_addresses(), 6);
    }

    #[test]
    fn sequential_and_strided() {
        let p = PatternProgram::sequential(5, 4);
        assert_eq!(p.expected_outputs(), vec![5, 6, 7, 8]);
        let p = PatternProgram::strided(5, 3, 4);
        assert_eq!(p.expected_outputs(), vec![5, 8, 11, 14]);
        assert_eq!(p.unique_addresses(), 4);
    }

    #[test]
    fn skip_shift_delays_shift() {
        let p = PatternProgram::shifted_cyclic(0, 2, 1).with_skip_shift(1).with_outputs(8);
        assert_eq!(p.expected_outputs(), vec![0, 1, 0, 1, 1, 2, 1, 2]);
    }

    #[test]
    fn validation_rejects_bad_programs() {
        assert!(PatternProgram::cyclic(0, 0).validate().is_err());
        assert!(PatternProgram::shifted_cyclic(0, 4, 5).validate().is_err());
        let mut p = PatternProgram::cyclic(0, 4);
        p.stride = 0;
        assert!(p.validate().is_err());
        assert!(PatternProgram::shifted_cyclic(0, 4, 4).validate().is_ok());
    }

    #[test]
    fn partial_final_cycle_truncates() {
        let p = PatternProgram::cyclic(0, 8).with_outputs(5);
        assert_eq!(p.expected_outputs(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn level_override_builder() {
        let p = PatternProgram::cyclic(0, 8).with_level_override(1, LevelProgram::linear(8));
        assert_eq!(p.level_overrides.len(), 2);
        assert!(p.level_overrides[0].is_none());
        assert_eq!(p.level_overrides[1], Some(LevelProgram::linear(8)));
    }
}

//! Abstract access-pattern families (§3.2, Figure 1) and their address
//! streams.
//!
//! Every pattern can enumerate the exact sequence of off-chip addresses it
//! reads, in order. The cycle-accurate hierarchy must emit the same
//! sequence (data-integrity invariant); only the *timing* differs between
//! configurations.

use crate::util::rng::{Rng, Xoshiro256};

/// An abstract memory-access pattern (Figure 1 of the paper).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AccessPattern {
    /// (a) Successive addresses, each accessed exactly once; no reuse.
    Sequential {
        /// First address.
        start: u64,
        /// Number of addresses accessed.
        len: u64,
    },
    /// (b) Cyclic with cycle length `l`: the same `l` successive addresses
    /// are replayed each cycle.
    Cyclic {
        /// Base address of the cycle.
        start: u64,
        /// Cycle length `l`.
        cycle_length: u64,
        /// Number of full cycles replayed.
        cycles: u64,
    },
    /// (c) Shifted cyclic / overlapping: after each completed cycle the
    /// base address shifts by `s`; with `skip_shift = k`, the shift is
    /// applied only after `k + 1` completed cycles (Table 1).
    ShiftedCyclic {
        /// Initial base address.
        start: u64,
        /// Cycle length `l`.
        cycle_length: u64,
        /// Inter-cycle shift `s` (`0` degenerates to `Cyclic`,
        /// `s == l` degenerates to `Sequential`/linear).
        inter_cycle_shift: u64,
        /// Cycles to run before each shift is applied (0 = shift every cycle).
        skip_shift: u64,
        /// Number of full cycles replayed.
        cycles: u64,
    },
    /// (d) Strided: constant address offset `stride > 1` between accesses;
    /// may wrap a cyclic window (combination noted in §3.2 d).
    Strided {
        /// First address.
        start: u64,
        /// Constant offset between consecutive accesses.
        stride: u64,
        /// Number of accesses.
        len: u64,
    },
    /// (e) Pseudo-random: non-precalculable addresses over a range
    /// (deterministic here via seed, as in the paper's simulations).
    PseudoRandom {
        /// Lowest address.
        start: u64,
        /// Number of distinct addresses in the range.
        range: u64,
        /// Number of accesses.
        len: u64,
        /// PRNG seed (reproducible).
        seed: u64,
    },
    /// (f) Parallel-shifted cyclic: several shifted-cyclic sub-patterns;
    /// each runs one full cycle, then the next takes over; after all have
    /// run one cycle the outer pattern returns to the first and every
    /// sub-pattern applies its shift.
    ParallelShiftedCyclic {
        /// The nested sub-patterns (each must be `ShiftedCyclic`-shaped).
        parts: Vec<ShiftedCyclicPart>,
        /// Number of outer rounds (each round = one cycle of every part).
        rounds: u64,
    },
}

/// One nested component of a parallel-shifted-cyclic pattern.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShiftedCyclicPart {
    /// Initial base address of this part.
    pub start: u64,
    /// Cycle length of this part.
    pub cycle_length: u64,
    /// Shift applied after each outer round.
    pub inter_cycle_shift: u64,
}

impl AccessPattern {
    /// The full address stream of this pattern, in access order.
    pub fn addresses(&self) -> Vec<u64> {
        self.stream().collect()
    }

    /// Iterator over the address stream.
    pub fn stream(&self) -> AddressStream {
        AddressStream::new(self.clone())
    }

    /// Total number of accesses the pattern performs.
    pub fn len(&self) -> u64 {
        match self {
            AccessPattern::Sequential { len, .. } => *len,
            AccessPattern::Cyclic { cycle_length, cycles, .. } => cycle_length * cycles,
            AccessPattern::ShiftedCyclic { cycle_length, cycles, .. } => cycle_length * cycles,
            AccessPattern::Strided { len, .. } => *len,
            AccessPattern::PseudoRandom { len, .. } => *len,
            AccessPattern::ParallelShiftedCyclic { parts, rounds } => {
                rounds * parts.iter().map(|p| p.cycle_length).sum::<u64>()
            }
        }
    }

    /// True if the pattern performs no accesses.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of *unique* addresses touched — the quantity Table 2 reports
    /// per TC-ResNet layer.
    pub fn unique_addresses(&self) -> u64 {
        match self {
            AccessPattern::Sequential { len, .. } => *len,
            AccessPattern::Cyclic { cycle_length, cycles, .. } => {
                if *cycles == 0 { 0 } else { *cycle_length }
            }
            AccessPattern::ShiftedCyclic {
                cycle_length, inter_cycle_shift, skip_shift, cycles, ..
            } => {
                if *cycles == 0 {
                    0
                } else {
                    // One window of `l`, plus min(s, l) new addresses per
                    // applied shift (for s > l the windows are disjoint and
                    // each shift exposes only l fresh addresses).
                    let shifts_applied = (*cycles - 1) / (*skip_shift + 1);
                    cycle_length + (*inter_cycle_shift).min(*cycle_length) * shifts_applied
                }
            }
            AccessPattern::Strided { len, .. } => *len,
            AccessPattern::PseudoRandom { .. } => {
                // Exact count requires materializing the stream.
                let mut v = self.addresses();
                v.sort_unstable();
                v.dedup();
                v.len() as u64
            }
            AccessPattern::ParallelShiftedCyclic { .. } => {
                let mut v = self.addresses();
                v.sort_unstable();
                v.dedup();
                v.len() as u64
            }
        }
    }

    /// Data-reuse factor: total accesses / unique addresses. 1.0 means no
    /// reuse (sequential); the paper's §5.3 discussion selects unrollings
    /// by this metric.
    pub fn reuse_factor(&self) -> f64 {
        let u = self.unique_addresses();
        if u == 0 {
            return 0.0;
        }
        self.len() as f64 / u as f64
    }
}

/// Iterator over a pattern's address stream.
pub struct AddressStream {
    pat: AccessPattern,
    // Shared counters (interpretation depends on variant).
    emitted: u64,
    pattern_ptr: u64,
    offset: u64,
    skips: u64,
    cycles_done: u64,
    // Parallel variant state.
    part_idx: usize,
    part_offsets: Vec<u64>,
    rng: Option<Xoshiro256>,
}

impl AddressStream {
    fn new(pat: AccessPattern) -> Self {
        let (part_offsets, rng) = match &pat {
            AccessPattern::ParallelShiftedCyclic { parts, .. } => {
                (parts.iter().map(|p| p.start).collect(), None)
            }
            AccessPattern::PseudoRandom { seed, .. } => (Vec::new(), Some(Xoshiro256::new(*seed))),
            _ => (Vec::new(), None),
        };
        Self {
            pat,
            emitted: 0,
            pattern_ptr: 0,
            offset: 0,
            skips: 0,
            cycles_done: 0,
            part_idx: 0,
            part_offsets,
            rng,
        }
    }
}

impl Iterator for AddressStream {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        if self.emitted >= self.pat.len() {
            return None;
        }
        self.emitted += 1;
        match &self.pat {
            AccessPattern::Sequential { start, .. } => {
                let a = start + self.pattern_ptr;
                self.pattern_ptr += 1;
                Some(a)
            }
            AccessPattern::Strided { start, stride, .. } => {
                let a = start + self.pattern_ptr * stride;
                self.pattern_ptr += 1;
                Some(a)
            }
            AccessPattern::Cyclic { start, cycle_length, .. } => {
                let a = start + self.pattern_ptr;
                self.pattern_ptr += 1;
                if self.pattern_ptr == *cycle_length {
                    self.pattern_ptr = 0;
                }
                Some(a)
            }
            AccessPattern::ShiftedCyclic {
                start, cycle_length, inter_cycle_shift, skip_shift, ..
            } => {
                // Mirrors Listing 1: read addr = start + offset + pattern_ptr;
                // on cycle completion `skips` increments and the shift is
                // applied once `skips > skip_shift`.
                let a = start + self.offset + self.pattern_ptr;
                self.pattern_ptr += 1;
                if self.pattern_ptr == *cycle_length {
                    self.pattern_ptr = 0;
                    self.skips += 1;
                    if self.skips > *skip_shift {
                        self.skips = 0;
                        self.offset += inter_cycle_shift;
                    }
                }
                Some(a)
            }
            AccessPattern::PseudoRandom { start, range, .. } => {
                let r = self.rng.as_mut().expect("rng initialized");
                Some(start + r.gen_range(*range))
            }
            AccessPattern::ParallelShiftedCyclic { parts, .. } => {
                let part = &parts[self.part_idx];
                let a = self.part_offsets[self.part_idx] + self.pattern_ptr;
                self.pattern_ptr += 1;
                if self.pattern_ptr == part.cycle_length {
                    // This part completed one cycle; move to the next part.
                    self.pattern_ptr = 0;
                    self.part_idx += 1;
                    if self.part_idx == parts.len() {
                        // Outer round complete: every part applies its shift.
                        self.part_idx = 0;
                        for (off, p) in self.part_offsets.iter_mut().zip(parts.iter()) {
                            *off += p.inter_cycle_shift;
                        }
                        self.cycles_done += 1;
                    }
                }
                Some(a)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream() {
        let p = AccessPattern::Sequential { start: 10, len: 5 };
        assert_eq!(p.addresses(), vec![10, 11, 12, 13, 14]);
        assert_eq!(p.unique_addresses(), 5);
        assert!((p.reuse_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cyclic_replays_window() {
        let p = AccessPattern::Cyclic { start: 0, cycle_length: 3, cycles: 3 };
        assert_eq!(p.addresses(), vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
        assert_eq!(p.unique_addresses(), 3);
        assert!((p.reuse_factor() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn shifted_cyclic_overlaps() {
        // l=4, s=2: windows [0..4), [2..6), [4..8)
        let p = AccessPattern::ShiftedCyclic {
            start: 0, cycle_length: 4, inter_cycle_shift: 2, skip_shift: 0, cycles: 3,
        };
        assert_eq!(p.addresses(), vec![0, 1, 2, 3, 2, 3, 4, 5, 4, 5, 6, 7]);
        assert_eq!(p.unique_addresses(), 8); // 4 + 2*2
    }

    #[test]
    fn shifted_cyclic_with_skip() {
        // skip_shift=1: shift applied every 2nd cycle.
        let p = AccessPattern::ShiftedCyclic {
            start: 0, cycle_length: 2, inter_cycle_shift: 1, skip_shift: 1, cycles: 4,
        };
        assert_eq!(p.addresses(), vec![0, 1, 0, 1, 1, 2, 1, 2]);
        assert_eq!(p.unique_addresses(), 3); // 2 + 1 shift applied
    }

    #[test]
    fn shift_equal_length_is_linear() {
        // Table 1: "If the inter-cycle shift is equal to the cycle length,
        // the pattern will be linear."
        let p = AccessPattern::ShiftedCyclic {
            start: 0, cycle_length: 3, inter_cycle_shift: 3, skip_shift: 0, cycles: 3,
        };
        assert_eq!(p.addresses(), (0..9).collect::<Vec<u64>>());
        assert_eq!(p.unique_addresses(), 9);
        assert!((p.reuse_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn strided_skips_addresses() {
        let p = AccessPattern::Strided { start: 4, stride: 3, len: 4 };
        assert_eq!(p.addresses(), vec![4, 7, 10, 13]);
    }

    #[test]
    fn pseudo_random_in_range_and_deterministic() {
        let p = AccessPattern::PseudoRandom { start: 100, range: 50, len: 200, seed: 1 };
        let a = p.addresses();
        assert_eq!(a.len(), 200);
        assert!(a.iter().all(|&x| (100..150).contains(&x)));
        assert_eq!(a, p.addresses(), "same seed, same stream");
        let p2 = AccessPattern::PseudoRandom { start: 100, range: 50, len: 200, seed: 2 };
        assert_ne!(a, p2.addresses(), "different seed, different stream");
    }

    #[test]
    fn parallel_shifted_cyclic_round_robin() {
        // Two parts: A (l=2, s=1, start 0), B (l=2, s=1, start 100).
        // Round 0: A cycle then B cycle; after round both shift by 1.
        let p = AccessPattern::ParallelShiftedCyclic {
            parts: vec![
                ShiftedCyclicPart { start: 0, cycle_length: 2, inter_cycle_shift: 1 },
                ShiftedCyclicPart { start: 100, cycle_length: 2, inter_cycle_shift: 1 },
            ],
            rounds: 2,
        };
        assert_eq!(p.addresses(), vec![0, 1, 100, 101, 1, 2, 101, 102]);
        assert_eq!(p.unique_addresses(), 6);
    }

    #[test]
    fn empty_patterns() {
        let p = AccessPattern::Sequential { start: 0, len: 0 };
        assert!(p.is_empty());
        assert_eq!(p.addresses(), Vec::<u64>::new());
        let p = AccessPattern::Cyclic { start: 0, cycle_length: 4, cycles: 0 };
        assert_eq!(p.unique_addresses(), 0);
    }

    #[test]
    fn unique_count_matches_materialized_stream() {
        for (l, s, k, c) in [(8, 3, 0, 10), (16, 16, 0, 5), (5, 2, 2, 9), (4, 0, 0, 7), (3, 7, 0, 4)] {
            let p = AccessPattern::ShiftedCyclic {
                start: 7, cycle_length: l, inter_cycle_shift: s, skip_shift: k, cycles: c,
            };
            let mut v = p.addresses();
            v.sort_unstable();
            v.dedup();
            assert_eq!(
                v.len() as u64,
                p.unique_addresses(),
                "closed form vs stream for l={l} s={s} k={k} c={c}"
            );
        }
    }
}

//! Memory access patterns (§3.2 of the paper) and MCU pattern programs
//! (§4.1.4, Table 1).
//!
//! Two views of the same concept live here:
//!
//! * [`AccessPattern`] — an *abstract* pattern family (sequential, cyclic,
//!   shifted-cyclic, strided, pseudo-random, parallel-shifted-cyclic) that
//!   can enumerate its off-chip address stream. This is the functional
//!   oracle the cycle-accurate hierarchy is verified against.
//! * [`PatternProgram`] / [`LevelProgram`] — the *register-level* program
//!   the MCU executes: `start_address`, per-level `cycle_length`,
//!   `inter_cycle_shift` and `skip_shift` (Table 1).
//!
//! [`classify`] recovers pattern parameters from raw address traces — the
//! loop-nest analysis of §5.3 (Table 2) is built on it.

pub mod classify;
pub mod kinds;
pub mod program;

pub use classify::{classify_trace, effective_trace, Classification, ExecutionMode};
pub use kinds::{AccessPattern, AddressStream};
pub use program::{LevelProgram, PatternProgram};

//! Table 2, Figure 9 and Figure 12: the §5.3 case-study reports, plus
//! the §6 follow-on level-kind comparison (standard vs double-buffered
//! Pareto fronts on the UltraTrail-style streaming weight supply).

use crate::accel::wmem::fig9_areas;
use crate::accel::UltraTrail;
use crate::dse::{explore, pareto_front, DesignPoint, KindChoice, SearchSpace};
use crate::model::{tc_resnet8, LayerKind};
use crate::pattern::PatternProgram;
use crate::util::table::{fnum, fpct, TextTable};
use crate::Result;

/// Table 2: type, unique addresses and cycle length of each TC-ResNet
/// layer, with the paper's values alongside.
pub fn table2() -> TextTable {
    let mut t = TextTable::new(vec!["layer", "type", "unique_addresses", "cycle_length"]);
    for l in tc_resnet8() {
        t.row(vec![
            l.idx.to_string(),
            match l.kind {
                LayerKind::Conv => "CONV".to_string(),
                LayerKind::Fc => "FC".to_string(),
            },
            l.weights().to_string(),
            l.cycle_length().to_string(),
        ]);
    }
    t
}

/// Figure 9: occupied chip area — dual-ported SRAMs sized for the full
/// data set vs the memory frameworks, per unrolling.
pub fn fig9_table() -> TextTable {
    let mut t = TextTable::new(vec![
        "unique_addrs_per_step",
        "dp_sram_um2",
        "framework_um2",
        "framework_fraction",
    ]);
    for p in fig9_areas() {
        t.row(vec![
            p.point.unique_per_step.to_string(),
            fnum(p.dp_sram_area, 0),
            fnum(p.framework_area, 0),
            fnum(p.framework_area / p.dp_sram_area, 3),
        ]);
    }
    t
}

/// Figure 12 + headline: UltraTrail baseline vs hierarchy-as-WMEM.
pub fn fig12_table(preload: bool) -> Result<TextTable> {
    let cs = UltraTrail::default().case_study(preload)?;
    let mut t = TextTable::new(vec!["metric", "baseline", "hierarchy", "delta", "paper"]);
    t.row(vec![
        "chip_area_um2".to_string(),
        fnum(cs.baseline_area, 0),
        fnum(cs.hierarchy_area, 0),
        fpct(cs.area_delta * 100.0),
        "-62.2%".to_string(),
    ]);
    t.row(vec![
        "chip_power_uW@250kHz".to_string(),
        fnum(cs.baseline_power * 1e6, 2),
        fnum(cs.hierarchy_power * 1e6, 2),
        fpct(cs.power_delta * 100.0),
        "+6.2%".to_string(),
    ]);
    t.row(vec![
        "inference_cycles".to_string(),
        cs.ideal_cycles.to_string(),
        cs.realized_cycles.to_string(),
        fpct(cs.perf_loss * 100.0),
        "+2.4%".to_string(),
    ]);
    t.row(vec![
        "wmem_share_of_chip".to_string(),
        fnum(cs.baseline_wmem_share * 100.0, 1),
        fnum(cs.wmem_breakdown.total / cs.hierarchy_area * 100.0, 1),
        String::new(),
        ">70% baseline".to_string(),
    ]);
    t.row(vec![
        "latency_ms".to_string(),
        fnum(cs.ideal_cycles as f64 / 250e3 * 1e3, 2),
        fnum(cs.latency_s * 1e3, 2),
        String::new(),
        "<100ms".to_string(),
    ]);
    Ok(t)
}

/// The two sweeps the level-kind comparison contrasts (every scored
/// point, Pareto front marked via `on_front`).
#[derive(Debug, Clone)]
pub struct KindFronts {
    /// The standard-only sweep (the pre-§6 design space).
    pub standard: Vec<DesignPoint>,
    /// The sweep with double-buffered kinds enabled per level.
    pub with_kinds: Vec<DesignPoint>,
}

/// The UltraTrail-style streaming workload of the comparison: a conv
/// layer's weight window (256 level words, cf. the Table 2 cycle
/// lengths) replayed for ten rows — too large for the accelerator-facing
/// level of the swept configurations, so the §5.3.2 streaming regime
/// applies and the fill/drain overlap of a ping-pong level is on the
/// critical path.
fn kinds_workload() -> PatternProgram {
    PatternProgram::cyclic(0, 256).with_outputs(2_560)
}

/// The swept space (shared by both fronts; only `level_kinds` differs).
fn kinds_space() -> SearchSpace {
    SearchSpace {
        depths: vec![2],
        ram_depths: vec![512, 128],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: true,
        eval_hz: 250e3, // the UltraTrail case-study clock
    }
}

/// Explore the kind-enabled space on the streaming workload; both result
/// sets keep every scored point with the front marked, so reports can
/// show the fronts while comparisons (e.g. "which standard designs does
/// a ping-pong level obsolete?") see the full space.
///
/// The standard-only sweep is a subset of the kind-enabled enumeration
/// and scoring is deterministic, so its points are recovered by
/// filtering and re-marking the Pareto front — no second round of
/// simulations.
pub fn level_kind_fronts() -> Result<KindFronts> {
    let with_kinds = explore(&kinds_space(), &kinds_workload())?;
    let mut standard: Vec<DesignPoint> = with_kinds
        .iter()
        .filter(|p| p.config.levels.iter().all(|l| !l.kind.is_double_buffered()))
        .cloned()
        .collect();
    for p in standard.iter_mut() {
        p.on_front = false;
    }
    let objs: Vec<Vec<f64>> =
        standard.iter().map(|p| vec![p.area, p.power, p.cycles as f64]).collect();
    for i in pareto_front(&objs) {
        standard[i].on_front = true;
    }
    Ok(KindFronts { standard, with_kinds })
}

/// The §6 follow-on comparison table: the Pareto front of the standard
/// design space next to the front with double-buffered kinds enabled, on
/// the UltraTrail-style streaming weight supply.
pub fn level_kinds_table() -> Result<TextTable> {
    let fronts = level_kind_fronts()?;
    let mut t = TextTable::new(vec!["space", "config", "area_um2", "cycles", "power_uW"]);
    for (scope, pts) in
        [("standard", &fronts.standard), ("with_kinds", &fronts.with_kinds)]
    {
        for p in pts.iter().filter(|p| p.on_front) {
            t.row(vec![
                scope.to_string(),
                p.config.stack_desc(),
                fnum(p.area, 0),
                p.cycles.to_string(),
                fnum(p.power * 1e6, 3),
            ]);
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LevelKind;
    use crate::model::tcresnet::{TABLE2_CYCLE_LENGTHS, TABLE2_UNIQUE_ADDRESSES};

    #[test]
    fn table2_matches_paper_exactly() {
        let t = table2();
        let csv = t.to_csv();
        for (i, (&u, &c)) in
            TABLE2_UNIQUE_ADDRESSES.iter().zip(TABLE2_CYCLE_LENGTHS.iter()).enumerate()
        {
            assert!(csv.contains(&format!("{i},")), "layer {i} present");
            let _ = (u, c); // values asserted in model tests; here we check shape
        }
        assert_eq!(t.len(), 13);
    }

    #[test]
    fn fig9_has_four_sweep_points() {
        let t = fig9_table();
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn fig12_reports_all_metrics() {
        let t = fig12_table(true).unwrap();
        let s = t.render();
        assert!(s.contains("chip_area_um2"));
        assert!(s.contains("chip_power_uW"));
        assert!(s.contains("inference_cycles"));
    }

    #[test]
    fn level_kinds_front_features_a_dominating_ping_pong_point() {
        let fronts = level_kind_fronts().unwrap();
        assert!(!fronts.standard.is_empty());
        assert!(!fronts.with_kinds.is_empty());
        // The kind-enabled front must contain a double-buffered design
        // that strictly dominates a standard design on (area, cycles):
        // the fill/drain overlap buys dual-port-like throughput below
        // dual-port area, obsoleting the dual-ported streaming level.
        let dominated = fronts.standard.iter().any(|s| {
            fronts.with_kinds.iter().any(|d| {
                d.on_front
                    && d.config.levels.iter().any(|l| l.kind == LevelKind::DoubleBuffered)
                    && d.area < s.area
                    && d.cycles < s.cycles
            })
        });
        assert!(dominated, "no ping-pong front point dominates a standard design");
        // And the table renders one row per front member.
        let t = level_kinds_table().unwrap();
        let front_rows = fronts.standard.iter().filter(|p| p.on_front).count()
            + fronts.with_kinds.iter().filter(|p| p.on_front).count();
        assert_eq!(t.len(), front_rows);
        assert!(t.render().contains('P'), "ping-pong levels labelled");
    }
}

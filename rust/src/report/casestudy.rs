//! Table 2, Figure 9 and Figure 12: the §5.3 case-study reports.

use crate::accel::wmem::fig9_areas;
use crate::accel::UltraTrail;
use crate::model::{tc_resnet8, LayerKind};
use crate::util::table::{fnum, fpct, TextTable};
use crate::Result;

/// Table 2: type, unique addresses and cycle length of each TC-ResNet
/// layer, with the paper's values alongside.
pub fn table2() -> TextTable {
    let mut t = TextTable::new(vec!["layer", "type", "unique_addresses", "cycle_length"]);
    for l in tc_resnet8() {
        t.row(vec![
            l.idx.to_string(),
            match l.kind {
                LayerKind::Conv => "CONV".to_string(),
                LayerKind::Fc => "FC".to_string(),
            },
            l.weights().to_string(),
            l.cycle_length().to_string(),
        ]);
    }
    t
}

/// Figure 9: occupied chip area — dual-ported SRAMs sized for the full
/// data set vs the memory frameworks, per unrolling.
pub fn fig9_table() -> TextTable {
    let mut t = TextTable::new(vec![
        "unique_addrs_per_step",
        "dp_sram_um2",
        "framework_um2",
        "framework_fraction",
    ]);
    for p in fig9_areas() {
        t.row(vec![
            p.point.unique_per_step.to_string(),
            fnum(p.dp_sram_area, 0),
            fnum(p.framework_area, 0),
            fnum(p.framework_area / p.dp_sram_area, 3),
        ]);
    }
    t
}

/// Figure 12 + headline: UltraTrail baseline vs hierarchy-as-WMEM.
pub fn fig12_table(preload: bool) -> Result<TextTable> {
    let cs = UltraTrail::default().case_study(preload)?;
    let mut t = TextTable::new(vec!["metric", "baseline", "hierarchy", "delta", "paper"]);
    t.row(vec![
        "chip_area_um2".to_string(),
        fnum(cs.baseline_area, 0),
        fnum(cs.hierarchy_area, 0),
        fpct(cs.area_delta * 100.0),
        "-62.2%".to_string(),
    ]);
    t.row(vec![
        "chip_power_uW@250kHz".to_string(),
        fnum(cs.baseline_power * 1e6, 2),
        fnum(cs.hierarchy_power * 1e6, 2),
        fpct(cs.power_delta * 100.0),
        "+6.2%".to_string(),
    ]);
    t.row(vec![
        "inference_cycles".to_string(),
        cs.ideal_cycles.to_string(),
        cs.realized_cycles.to_string(),
        fpct(cs.perf_loss * 100.0),
        "+2.4%".to_string(),
    ]);
    t.row(vec![
        "wmem_share_of_chip".to_string(),
        fnum(cs.baseline_wmem_share * 100.0, 1),
        fnum(cs.wmem_breakdown.total / cs.hierarchy_area * 100.0, 1),
        String::new(),
        ">70% baseline".to_string(),
    ]);
    t.row(vec![
        "latency_ms".to_string(),
        fnum(cs.ideal_cycles as f64 / 250e3 * 1e3, 2),
        fnum(cs.latency_s * 1e3, 2),
        String::new(),
        "<100ms".to_string(),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tcresnet::{TABLE2_CYCLE_LENGTHS, TABLE2_UNIQUE_ADDRESSES};

    #[test]
    fn table2_matches_paper_exactly() {
        let t = table2();
        let csv = t.to_csv();
        for (i, (&u, &c)) in
            TABLE2_UNIQUE_ADDRESSES.iter().zip(TABLE2_CYCLE_LENGTHS.iter()).enumerate()
        {
            assert!(csv.contains(&format!("{i},")), "layer {i} present");
            let _ = (u, c); // values asserted in model tests; here we check shape
        }
        assert_eq!(t.len(), 13);
    }

    #[test]
    fn fig9_has_four_sweep_points() {
        let t = fig9_table();
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn fig12_reports_all_metrics() {
        let t = fig12_table(true).unwrap();
        let s = t.render();
        assert!(s.contains("chip_area_um2"));
        assert!(s.contains("chip_power_uW"));
        assert!(s.contains("inference_cycles"));
    }
}

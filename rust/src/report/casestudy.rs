//! Table 2, Figure 9 and Figure 12: the §5.3 case-study reports, plus
//! the §6 follow-on level-kind comparison (standard vs double-buffered
//! Pareto fronts on the UltraTrail-style streaming weight supply).

use crate::accel::wmem::fig9_areas;
use crate::accel::UltraTrail;
use crate::dse::{
    explore, explore_joint, pareto_front, DesignPoint, JointSpace, KindChoice, Mapping,
    SearchSpace,
};
use crate::loopnest::{LoopOrder, Unrolling};
use crate::model::{tc_resnet8, LayerKind, LayerSpec};
use crate::pattern::PatternProgram;
use crate::util::table::{fnum, fpct, TextTable};
use crate::Result;

/// Table 2: type, unique addresses and cycle length of each TC-ResNet
/// layer, with the paper's values alongside.
pub fn table2() -> TextTable {
    let mut t = TextTable::new(vec!["layer", "type", "unique_addresses", "cycle_length"]);
    for l in tc_resnet8() {
        t.row(vec![
            l.idx.to_string(),
            match l.kind {
                LayerKind::Conv => "CONV".to_string(),
                LayerKind::Fc => "FC".to_string(),
            },
            l.weights().to_string(),
            l.cycle_length().to_string(),
        ]);
    }
    t
}

/// Figure 9: occupied chip area — dual-ported SRAMs sized for the full
/// data set vs the memory frameworks, per unrolling.
pub fn fig9_table() -> TextTable {
    let mut t = TextTable::new(vec![
        "unique_addrs_per_step",
        "dp_sram_um2",
        "framework_um2",
        "framework_fraction",
    ]);
    for p in fig9_areas() {
        t.row(vec![
            p.point.unique_per_step.to_string(),
            fnum(p.dp_sram_area, 0),
            fnum(p.framework_area, 0),
            fnum(p.framework_area / p.dp_sram_area, 3),
        ]);
    }
    t
}

/// Figure 12 + headline: UltraTrail baseline vs hierarchy-as-WMEM.
pub fn fig12_table(preload: bool) -> Result<TextTable> {
    let cs = UltraTrail::default().case_study(preload)?;
    let mut t = TextTable::new(vec!["metric", "baseline", "hierarchy", "delta", "paper"]);
    t.row(vec![
        "chip_area_um2".to_string(),
        fnum(cs.baseline_area, 0),
        fnum(cs.hierarchy_area, 0),
        fpct(cs.area_delta * 100.0),
        "-62.2%".to_string(),
    ]);
    t.row(vec![
        "chip_power_uW@250kHz".to_string(),
        fnum(cs.baseline_power * 1e6, 2),
        fnum(cs.hierarchy_power * 1e6, 2),
        fpct(cs.power_delta * 100.0),
        "+6.2%".to_string(),
    ]);
    t.row(vec![
        "inference_cycles".to_string(),
        cs.ideal_cycles.to_string(),
        cs.realized_cycles.to_string(),
        fpct(cs.perf_loss * 100.0),
        "+2.4%".to_string(),
    ]);
    t.row(vec![
        "wmem_share_of_chip".to_string(),
        fnum(cs.baseline_wmem_share * 100.0, 1),
        fnum(cs.wmem_breakdown.total / cs.hierarchy_area * 100.0, 1),
        String::new(),
        ">70% baseline".to_string(),
    ]);
    t.row(vec![
        "latency_ms".to_string(),
        fnum(cs.ideal_cycles as f64 / 250e3 * 1e3, 2),
        fnum(cs.latency_s * 1e3, 2),
        String::new(),
        "<100ms".to_string(),
    ]);
    Ok(t)
}

/// The two sweeps the level-kind comparison contrasts (every scored
/// point, Pareto front marked via `on_front`).
#[derive(Debug, Clone)]
pub struct KindFronts {
    /// The standard-only sweep (the pre-§6 design space).
    pub standard: Vec<DesignPoint>,
    /// The sweep with double-buffered kinds enabled per level.
    pub with_kinds: Vec<DesignPoint>,
}

/// The UltraTrail-style streaming workload of the comparison: a conv
/// layer's weight window (256 level words, cf. the Table 2 cycle
/// lengths) replayed for ten rows — too large for the accelerator-facing
/// level of the swept configurations, so the §5.3.2 streaming regime
/// applies and the fill/drain overlap of a ping-pong level is on the
/// critical path.
fn kinds_workload() -> PatternProgram {
    PatternProgram::cyclic(0, 256).with_outputs(2_560)
}

/// The swept space (shared by both fronts; only `level_kinds` differs).
fn kinds_space() -> SearchSpace {
    SearchSpace {
        depths: vec![2],
        ram_depths: vec![512, 128],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: true,
        protections: vec![crate::config::Protection::None],
        eval_hz: 250e3, // the UltraTrail case-study clock
    }
}

/// Explore the kind-enabled space on the streaming workload; both result
/// sets keep every scored point with the front marked, so reports can
/// show the fronts while comparisons (e.g. "which standard designs does
/// a ping-pong level obsolete?") see the full space.
///
/// The standard-only sweep is a subset of the kind-enabled enumeration
/// and scoring is deterministic, so its points are recovered by
/// filtering and re-marking the Pareto front — no second round of
/// simulations.
pub fn level_kind_fronts() -> Result<KindFronts> {
    let with_kinds = explore(&kinds_space(), &kinds_workload())?;
    let mut standard: Vec<DesignPoint> = with_kinds
        .iter()
        .filter(|p| p.config.levels.iter().all(|l| !l.kind.is_double_buffered()))
        .cloned()
        .collect();
    for p in standard.iter_mut() {
        p.on_front = false;
    }
    let objs: Vec<Vec<f64>> =
        standard.iter().map(|p| vec![p.area, p.power, p.cycles as f64]).collect();
    for i in pareto_front(&objs) {
        standard[i].on_front = true;
    }
    Ok(KindFronts { standard, with_kinds })
}

/// The §6 follow-on comparison table: the Pareto front of the standard
/// design space next to the front with double-buffered kinds enabled, on
/// the UltraTrail-style streaming weight supply.
pub fn level_kinds_table() -> Result<TextTable> {
    let fronts = level_kind_fronts()?;
    let mut t = TextTable::new(vec!["space", "config", "area_um2", "cycles", "power_uW"]);
    for (scope, pts) in
        [("standard", &fronts.standard), ("with_kinds", &fronts.with_kinds)]
    {
        for p in pts.iter().filter(|p| p.on_front) {
            t.row(vec![
                scope.to_string(),
                p.config.stack_desc(),
                fnum(p.area, 0),
                p.cycles.to_string(),
                fnum(p.power * 1e6, 3),
            ]);
        }
    }
    Ok(t)
}

/// The joint-sweep comparison: what the search gives up by fixing the
/// mapping up front (the pre-joint workflow) versus co-exploring mapping
/// and hierarchy. Both sets keep every scored point with their front
/// marked — `fixed` over the fixed-mapping subset, `joint` over the full
/// *(mapping, config)* space — on the same four axes (area, power,
/// cycles, off-chip reads).
#[derive(Debug, Clone)]
pub struct JointFronts {
    /// The mapping the fixed sweep is pinned to (K-major, UltraTrail
    /// loop order — the paper's default style).
    pub fixed_mapping: Mapping,
    /// Every scored point of the fixed mapping, front re-marked within
    /// the subset.
    pub fixed: Vec<DesignPoint>,
    /// Every scored point of the joint sweep, four-axis front marked.
    pub joint: Vec<DesignPoint>,
}

/// The joint comparison space: all unrollings of a 16-MAC array on a
/// small conv layer, crossed with the paper's two loop orders, over a
/// trimmed config space (single word width keeps the report quick; the
/// CLI `dse --joint` runs the full default space).
fn joint_report_space() -> JointSpace {
    let space = SearchSpace {
        depths: vec![1, 2],
        ram_depths: vec![32, 128, 512],
        word_widths: vec![32],
        level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
        try_dual_ported: true,
        protections: vec![crate::config::Protection::None],
        eval_hz: 100e6,
    };
    let layer = LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 };
    JointSpace::new(space, layer, 16, &[LoopOrder::ultratrail(), LoopOrder::output_stationary()])
}

/// Explore the joint space once and derive both fronts. The fixed-
/// mapping sweep is a subset of the joint enumeration and scoring is
/// deterministic, so its points are recovered by filtering and
/// re-marking — no second round of simulations (the same recovery trick
/// [`level_kind_fronts`] uses).
pub fn joint_fronts() -> Result<JointFronts> {
    let space = joint_report_space();
    // The paper-default mapping: K-major at full array width under the
    // UltraTrail loop order, falling back to the first supported
    // UltraTrail-order mapping should that unrolling be unsupported.
    let preferred = Mapping {
        unrolling: Unrolling { uk: 8, uc: 2, ux: 1, uf: 1 },
        order: LoopOrder::ultratrail(),
    };
    let fixed_mapping = space
        .mappings
        .iter()
        .copied()
        .find(|m| *m == preferred)
        .or_else(|| space.mappings.iter().copied().find(|m| m.order == LoopOrder::ultratrail()))
        .unwrap_or(space.mappings[0]);
    let out = explore_joint(&space)?;
    let mut fixed: Vec<DesignPoint> = out
        .points
        .iter()
        .filter(|p| p.mapping == Some(fixed_mapping))
        .cloned()
        .collect();
    for p in fixed.iter_mut() {
        p.on_front = false;
    }
    let objs: Vec<Vec<f64>> = fixed
        .iter()
        .map(|p| vec![p.area, p.power, p.cycles as f64, p.offchip_reads as f64])
        .collect();
    for i in pareto_front(&objs) {
        fixed[i].on_front = true;
    }
    Ok(JointFronts { fixed_mapping, fixed, joint: out.points })
}

/// The joint comparison table: the front reachable with the mapping
/// fixed at the paper default next to the joint co-exploration front.
/// Fixed-front designs that fall off the joint front are flagged
/// `dominated` — hierarchy configurations that only look Pareto-optimal
/// because the mapping was never questioned.
pub fn joint_table() -> Result<TextTable> {
    let fronts = joint_fronts()?;
    let mut t = TextTable::new(vec![
        "front", "config", "uk", "uc", "ux", "uf", "order", "area_um2", "power_mW", "cycles",
        "offchip", "status",
    ]);
    let mut row = |scope: &str, p: &DesignPoint, status: String| {
        let m = p.mapping.expect("joint points carry their mapping");
        t.row(vec![
            scope.to_string(),
            p.config.stack_desc(),
            m.unrolling.uk.to_string(),
            m.unrolling.uc.to_string(),
            m.unrolling.ux.to_string(),
            m.unrolling.uf.to_string(),
            m.order_name(),
            fnum(p.area, 0),
            fnum(p.power * 1e3, 3),
            p.cycles.to_string(),
            p.offchip_reads.to_string(),
            status,
        ]);
    };
    for p in fronts.fixed.iter().filter(|p| p.on_front) {
        // A fixed-front point survives the joint sweep iff the same
        // (config, mapping) point is marked on the joint front.
        let kept = fronts
            .joint
            .iter()
            .any(|q| q.on_front && q.config == p.config && q.mapping == p.mapping);
        row("fixed", p, if kept { "kept".to_string() } else { "dominated".to_string() });
    }
    for p in fronts.joint.iter().filter(|p| p.on_front) {
        row("joint", p, String::new());
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LevelKind;
    use crate::model::tcresnet::{TABLE2_CYCLE_LENGTHS, TABLE2_UNIQUE_ADDRESSES};

    #[test]
    fn table2_matches_paper_exactly() {
        let t = table2();
        let csv = t.to_csv();
        for (i, (&u, &c)) in
            TABLE2_UNIQUE_ADDRESSES.iter().zip(TABLE2_CYCLE_LENGTHS.iter()).enumerate()
        {
            assert!(csv.contains(&format!("{i},")), "layer {i} present");
            let _ = (u, c); // values asserted in model tests; here we check shape
        }
        assert_eq!(t.len(), 13);
    }

    #[test]
    fn fig9_has_four_sweep_points() {
        let t = fig9_table();
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn fig12_reports_all_metrics() {
        let t = fig12_table(true).unwrap();
        let s = t.render();
        assert!(s.contains("chip_area_um2"));
        assert!(s.contains("chip_power_uW"));
        assert!(s.contains("inference_cycles"));
    }

    #[test]
    fn level_kinds_front_features_a_dominating_ping_pong_point() {
        let fronts = level_kind_fronts().unwrap();
        assert!(!fronts.standard.is_empty());
        assert!(!fronts.with_kinds.is_empty());
        // The kind-enabled front must contain a double-buffered design
        // that strictly dominates a standard design on (area, cycles):
        // the fill/drain overlap buys dual-port-like throughput below
        // dual-port area, obsoleting the dual-ported streaming level.
        let dominated = fronts.standard.iter().any(|s| {
            fronts.with_kinds.iter().any(|d| {
                d.on_front
                    && d.config.levels.iter().any(|l| l.kind == LevelKind::DoubleBuffered)
                    && d.area < s.area
                    && d.cycles < s.cycles
            })
        });
        assert!(dominated, "no ping-pong front point dominates a standard design");
        // And the table renders one row per front member.
        let t = level_kinds_table().unwrap();
        let front_rows = fronts.standard.iter().filter(|p| p.on_front).count()
            + fronts.with_kinds.iter().filter(|p| p.on_front).count();
        assert_eq!(t.len(), front_rows);
        assert!(t.render().contains('P'), "ping-pong levels labelled");
    }

    #[test]
    fn joint_table_flags_exactly_the_dominated_fixed_points() {
        let fronts = joint_fronts().unwrap();
        assert!(!fronts.fixed.is_empty(), "fixed-mapping subset non-empty");
        assert!(!fronts.joint.is_empty());
        assert!(fronts.fixed.iter().all(|p| p.mapping == Some(fronts.fixed_mapping)));
        // The fixed subset front and the joint front must both be marked.
        let fixed_front: Vec<_> = fronts.fixed.iter().filter(|p| p.on_front).collect();
        let joint_front: Vec<_> = fronts.joint.iter().filter(|p| p.on_front).collect();
        assert!(!fixed_front.is_empty());
        assert!(!joint_front.is_empty());
        // Flag consistency: a fixed-front point is `kept` iff its exact
        // (config, mapping) point is on the joint front; otherwise some
        // joint point must weakly dominate it with a strict axis (the
        // joint enumeration is a superset, so there is no third case).
        for p in &fixed_front {
            let kept = joint_front
                .iter()
                .any(|q| q.config == p.config && q.mapping == p.mapping);
            if !kept {
                let dominated = fronts.joint.iter().any(|q| {
                    q.area <= p.area
                        && q.power <= p.power
                        && q.cycles <= p.cycles
                        && q.offchip_reads <= p.offchip_reads
                        && (q.area < p.area
                            || q.power < p.power
                            || q.cycles < p.cycles
                            || q.offchip_reads < p.offchip_reads)
                });
                assert!(dominated, "fixed-front point neither kept nor dominated");
            }
        }
        // One table row per front member, statuses rendered.
        let t = joint_table().unwrap();
        assert_eq!(t.len(), fixed_front.len() + joint_front.len());
        let s = t.render();
        assert!(s.contains("fixed") && s.contains("joint"));
    }
}

//! Figures 5–8 and 10: the §5.2 performance analysis and §5.3.1 layer
//! runtimes.

use crate::accel::wmem::{fig10_runtimes, sweep_points};
use crate::config::HierarchyConfig;
use crate::cost::{hierarchy_area, run_power};
use crate::mem::Hierarchy;
use crate::pattern::PatternProgram;
use crate::util::table::{fnum, TextTable};
use crate::Result;

/// Number of data words each §5.2 experiment outputs.
pub const N_OUTPUTS: u64 = 5_000;
/// Cycle lengths swept in Figs 5, 6.
pub const CYCLE_LENGTHS: [u64; 8] = [8, 16, 32, 64, 128, 256, 512, 1024];

fn two_level_32(d0: u64, d1: u64, l0_ports: u32, preload: bool) -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, d0, 1, l0_ports)
        .level(32, d1, 1, 2)
        .preload(preload)
        .build()
        .expect("valid")
}

fn run_cycles(cfg: &HierarchyConfig, prog: &PatternProgram) -> Result<u64> {
    let mut h = Hierarchy::new(cfg)?;
    h.load_program(prog)?;
    h.set_verify(false);
    Ok(h.run()?.stats.internal_cycles)
}

/// Figure 5: clock cycles to output 5 000 words over cycle lengths
/// 8→1024; level 0 = 1024 words; level 1 depth ∈ {32, 128, 512};
/// with and without preloading.
pub fn fig5_table() -> Result<TextTable> {
    let mut t = TextTable::new(vec![
        "cycle_length",
        "L1=32",
        "L1=32+pre",
        "L1=128",
        "L1=128+pre",
        "L1=512",
        "L1=512+pre",
    ]);
    for &l in &CYCLE_LENGTHS {
        let mut row = vec![l.to_string()];
        for d1 in [32u64, 128, 512] {
            for pre in [false, true] {
                let cfg = two_level_32(1024, d1, 1, pre);
                let prog = PatternProgram::cyclic(0, l).with_outputs(N_OUTPUTS);
                row.push(run_cycles(&cfg, &prog)?.to_string());
            }
        }
        t.row(row);
    }
    Ok(t)
}

/// Figure 6: equal bit capacity at different word widths — 32-bit
/// (512+128 deep) vs 128-bit (128+32 deep, with OSR) over the same sweep.
pub fn fig6_table() -> Result<TextTable> {
    let cfg32 = |pre| two_level_32(512, 128, 1, pre);
    let cfg128 = |pre| {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(128, vec![32])
            .preload(pre)
            .build()
            .expect("valid")
    };
    let mut t = TextTable::new(vec!["cycle_length", "32bit", "32bit+pre", "128bit+OSR", "128bit+OSR+pre"]);
    for &l in &CYCLE_LENGTHS {
        let prog = PatternProgram::cyclic(0, l).with_outputs(N_OUTPUTS);
        // 128-bit packing needs cycle lengths divisible by 4 — all sweep
        // points are.
        t.row(vec![
            l.to_string(),
            run_cycles(&cfg32(false), &prog)?.to_string(),
            run_cycles(&cfg32(true), &prog)?.to_string(),
            run_cycles(&cfg128(false), &prog)?.to_string(),
            run_cycles(&cfg128(true), &prog)?.to_string(),
        ]);
    }
    Ok(t)
}

/// Figure 7: chip area and power of the two Fig 6 frameworks.
pub fn fig7_table() -> Result<TextTable> {
    let cfg32 = two_level_32(512, 128, 1, false);
    let cfg128 = HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(128, 128, 1, 1)
        .level(128, 32, 1, 2)
        .osr(128, vec![32])
        .build()
        .expect("valid");
    let mut t = TextTable::new(vec!["framework", "area_um2", "power_mW@100MHz", "paper_area_um2"]);
    for (name, cfg, paper) in [("32-bit", &cfg32, 7_566.0), ("128-bit+OSR", &cfg128, 15_202.0)] {
        let prog = PatternProgram::cyclic(0, 512).with_outputs(N_OUTPUTS - N_OUTPUTS % 4);
        let mut h = Hierarchy::new(cfg)?;
        h.load_program(&prog)?;
        h.set_verify(false);
        let stats = h.run()?.stats;
        let area = hierarchy_area(cfg).total;
        let power = run_power(cfg, &stats, 100e6).total * 1e3;
        t.row(vec![name.to_string(), fnum(area, 0), fnum(power, 3), fnum(paper, 0)]);
    }
    Ok(t)
}

/// Figure 8: inter-cycle-shift sweep at selected cycle lengths, single-
/// vs dual-ported level 0 (depths 512 + 128).
pub fn fig8_table() -> Result<TextTable> {
    let mut t = TextTable::new(vec!["cycle_length", "shift", "cycles_SP_L0", "cycles_DP_L0"]);
    for &l in &[32u64, 64, 96, 128] {
        // Shift swept from 1 to the cycle length (§5.2.3).
        let shifts: Vec<u64> =
            [1, l / 8, l / 4, l / 3, l / 2, 2 * l / 3, l].iter().copied().filter(|&s| s >= 1).collect();
        let mut seen = std::collections::BTreeSet::new();
        for s in shifts {
            if !seen.insert(s) {
                continue;
            }
            let prog = PatternProgram::shifted_cyclic(0, l, s).with_outputs(N_OUTPUTS);
            let sp = run_cycles(&two_level_32(512, 128, 1, false), &prog)?;
            let dp = run_cycles(&two_level_32(512, 128, 2, false), &prog)?;
            t.row(vec![l.to_string(), s.to_string(), sp.to_string(), dp.to_string()]);
        }
    }
    Ok(t)
}

/// Figure 10: relative runtime of each TC-ResNet layer for the four
/// unrollings (8/16/32/64 unique addresses per step), plus overall
/// efficiency. Paper values: 58.8 / 60.6 / 85.7 / 97.6 %.
pub fn fig10_table() -> Result<TextTable> {
    let points = sweep_points();
    let mut t = TextTable::new(vec!["layer", "u=8", "u=16", "u=32", "u=64"]);
    let results: Vec<_> = points.iter().map(fig10_runtimes).collect();
    let n_layers = results[0].0.len();
    for i in 0..n_layers {
        let mut row = vec![results[0].0[i].layer.to_string()];
        for (per, _) in &results {
            let rel = per[i].runtime as f64 / per[i].steps as f64;
            row.push(fnum(rel, 2));
        }
        t.row(row);
    }
    let mut eff_row = vec!["overall_eff".to_string()];
    for (_, eff) in &results {
        eff_row.push(format!("{:.1}%", eff * 100.0));
    }
    t.row(eff_row);
    t.row(vec!["paper_eff", "58.8%", "60.6%", "85.7%", "97.6%"]);
    Ok(t)
}

//! Regenerators for every table and figure of the paper's evaluation.
//!
//! Each function runs the corresponding experiment and returns a
//! [`TextTable`] whose rows are the figure's series — printable as
//! aligned text or CSV. The `memhier report <id>` CLI command and the
//! `rust/benches/*` binaries both call these.

pub mod casestudy;
pub mod figures;

pub use casestudy::{
    fig12_table, fig9_table, joint_fronts, joint_table, level_kind_fronts, level_kinds_table,
    table2, JointFronts,
};
pub use figures::{fig10_table, fig5_table, fig6_table, fig7_table, fig8_table};

use crate::util::table::TextTable;

/// Write a table to `out/<name>.csv` (creating `out/`), returning the path.
pub fn save_csv(table: &TextTable, name: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    std::fs::write(&path, table.to_csv())?;
    Ok(path)
}

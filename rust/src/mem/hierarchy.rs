//! The composed memory hierarchy (Fig 2): a thin composition of
//! [`Stage`]s driven by the [`sim::engine`](crate::sim::engine) layer.
//!
//! See the module docs of [`crate::mem`] for the timing semantics. The
//! step order within one internal clock cycle is:
//!
//! 1. input-buffer synchronizer shift (CDC, Fig 3);
//! 2. OSR shift-out (emits an output if enough valid bits are present);
//! 3. write/read enable computation from registered (previous-cycle)
//!    state, including the write-enable toggle and port arbitration;
//! 4. write commits (each consumes the upstream out-register / buffer);
//! 5. read commits (each loads the level's out-register, or feeds the
//!    OSR / accelerator at the last level).
//!
//! External clock edges step the off-chip interface and the input-buffer
//! fill logic. Both domains are interleaved by [`crate::sim::ClockPair`],
//! owned — together with the deadlock guard, stats, verification and
//! waveform storage — by the [`Engine`]. [`HierarchyCore`] holds only the
//! datapath components and the per-cycle port scheduling; `Hierarchy`
//! glues the two together behind the original public API.

use super::input_buffer::{FillHorizon, InputBuffer, InputBufferCheckpoint};
use super::level::{LevelStage, LevelStageCheckpoint, Slot};
use super::mcu::McuProgram;
use super::offchip::{payload_for, OffChipCheckpoint, OffChipMemory};
use super::osr::{Osr, OsrCheckpoint};
use crate::config::{HierarchyConfig, Protection};
use crate::pattern::PatternProgram;
use crate::sim::engine::{
    BudgetOutcome, Core, CycleCtx, Engine, EngineCheckpoint, Horizon, Stage, StreamSpec,
};
use crate::sim::fault::{FaultComponent, FaultEvent, FaultPlan, FaultReport, FaultSite, FaultState};
use crate::sim::{ClockPair, SimStats, Waveform, WaveformProbe};
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};

pub use crate::sim::engine::OutputWord;

/// A captured mid-run simulation state: everything a suspended program
/// needs to continue bit-identically, on this hierarchy or on any other
/// hierarchy armed for the same (configuration, program) pair.
///
/// ## Invariants
///
/// * A checkpoint is **config-keyed**: it stores the configuration it was
///   taken under, and [`Hierarchy::restore`] refuses a checkpoint whose
///   configuration differs from the restoring hierarchy's — restoring
///   onto a re-armed warm session is a *checked* operation.
/// * A checkpoint is **program-bound**: it captures the compiled
///   [`McuProgram`] and restore refuses any mismatch (different pattern,
///   totals, roles, or fetch plan). The caller must `load_program` the
///   same program before restoring (loading re-derives all static
///   compiled state — fetch plan, level units, stream spec — so the
///   checkpoint only carries mutable registers, occupancy, and cursors).
/// * A checkpoint records the capture-time verify/collect switches as a
///   **compatibility key**: the sink's run state (verifier cursor,
///   collected outputs) is only meaningful under the same settings, so
///   restore refuses a target whose switches differ. The switches
///   themselves stay session-owned — set them to match before restoring.
/// * Snapshots are taken at an edge boundary (after a completed
///   [`Hierarchy::run_budgeted`] suspension): continuing a restored run
///   replays exactly the edge schedule the uninterrupted run would have
///   executed, so stats and outputs are bit-for-bit identical. This is
///   what lets the successive-halving DSE resume candidates across rungs
///   instead of re-running the screened prefix.
/// * Operator settings (verify/collect switches, the `force_naive`
///   fast-forward oracle switch, deadlock limit, armed fault schedule)
///   and waveform storage are **not** part of a checkpoint — they belong
///   to the session. A
///   checkpoint taken under fast-forward restores onto a `force_naive`
///   session (and vice versa) bit-identically: both modes visit the same
///   edge-boundary states. Waveform capture across a suspend/resume
///   boundary is unsupported.
///
/// ## Wire format
///
/// Checkpoints serialize to a versioned, zero-dependency binary format
/// (see [`crate::mem::wire`]) so they can cross process boundaries — the
/// sharded DSE ships them between a coordinator and `dse-worker`
/// processes. The body layout mirrors the struct field-for-field in
/// declaration order, each component via its own `wire_write`/`wire_read`
/// pair, little-endian fixed-width integers throughout:
///
/// * level count (`u32`), then one [`LevelStageCheckpoint`] per level
///   (tagged standard / double-buffered, matched against the decode
///   configuration's level kinds);
/// * input-buffer presence flag (`u8` bool) + body;
/// * off-chip state (in-flight request pipeline + read counter);
/// * OSR presence flag + body (presence must match the configuration);
/// * `output_enabled`, `preload_done` flags;
/// * engine state (clocks, stats, sink, progress guard).
///
/// Decoding validates every structural invariant the simulator's
/// `restore` paths assume (slot-vector lengths, pointer bounds, word
/// widths, tag ranges) so that arbitrary bytes return [`Error::Parse`]
/// rather than panicking; semantic integrity beyond that is enforced by
/// [`Hierarchy::restore`]'s config/program/switch keying and the
/// verifier.
#[derive(Debug, Clone, PartialEq)]
pub struct HierarchyCheckpoint {
    config: HierarchyConfig,
    prog: McuProgram,
    levels: Vec<LevelStageCheckpoint>,
    ib: Option<InputBufferCheckpoint>,
    offchip: OffChipCheckpoint,
    osr: Option<OsrCheckpoint>,
    output_enabled: bool,
    preload_done: bool,
    engine: EngineCheckpoint,
}

impl HierarchyCheckpoint {
    /// The configuration the checkpoint was taken under.
    pub fn config(&self) -> &HierarchyConfig {
        &self.config
    }

    /// Internal cycles consumed at the capture point (the simulation work
    /// a restore inherits instead of re-paying).
    pub fn cycles(&self) -> u64 {
        self.engine.internal_cycles()
    }

    /// Off-chip units emitted at the capture point.
    pub fn units_out(&self) -> u64 {
        self.engine.units_out()
    }

    /// The compiled program the checkpoint is bound to.
    pub(crate) fn prog(&self) -> &McuProgram {
        &self.prog
    }

    /// Serialize the checkpoint *body* (everything except the config and
    /// compiled program, which the envelope carries as keys — see the
    /// "Wire format" section above and [`crate::mem::wire`]).
    pub(crate) fn wire_write_body(&self, w: &mut ByteWriter) {
        let Self {
            config: _,
            prog: _,
            levels,
            ib,
            offchip,
            osr,
            output_enabled,
            preload_done,
            engine,
        } = self;
        w.put_u32(levels.len() as u32);
        for lv in levels {
            lv.wire_write(w);
        }
        w.put_bool(ib.is_some());
        if let Some(ib) = ib {
            ib.wire_write(w);
        }
        offchip.wire_write(w);
        w.put_bool(osr.is_some());
        if let Some(osr) = osr {
            osr.wire_write(w);
        }
        w.put_bool(*output_enabled);
        w.put_bool(*preload_done);
        engine.wire_write(w);
    }

    /// Checked decode of [`Self::wire_write_body`] output against the
    /// already-decoded `config` and compiled `prog` keys. Validates every
    /// structural invariant `restore` assumes; returns [`Error::Parse`]
    /// on any mismatch.
    pub(crate) fn wire_read_body(
        r: &mut ByteReader<'_>,
        config: HierarchyConfig,
        prog: McuProgram,
    ) -> Result<Self> {
        let n_levels = r.get_count(1)?;
        if n_levels != config.levels.len() {
            return Err(Error::Parse(format!(
                "wire: checkpoint has {n_levels} levels, config has {}",
                config.levels.len()
            )));
        }
        let mut levels = Vec::with_capacity(n_levels);
        for lc in &config.levels {
            levels.push(LevelStageCheckpoint::wire_read(r, lc)?);
        }
        let ib = if r.get_bool()? {
            let width = config.levels[0].word_width;
            Some(InputBufferCheckpoint::wire_read(r, width, prog.plan.pack())?)
        } else {
            None
        };
        let offchip = OffChipCheckpoint::wire_read(r)?;
        let osr = if r.get_bool()? {
            let Some(osr_cfg) = &config.osr else {
                let msg = "wire: checkpoint has OSR state, config has no OSR";
                return Err(Error::Parse(msg.into()));
            };
            Some(OsrCheckpoint::wire_read(r, config.offchip.data_width, osr_cfg.shifts.len())?)
        } else {
            if config.osr.is_some() {
                let msg = "wire: config has an OSR, checkpoint has no OSR state";
                return Err(Error::Parse(msg.into()));
            }
            None
        };
        let output_enabled = r.get_bool()?;
        let preload_done = r.get_bool()?;
        let engine = EngineCheckpoint::wire_read(r)?;
        Ok(Self {
            config,
            prog,
            levels,
            ib,
            offchip,
            osr,
            output_enabled,
            preload_done,
            engine,
        })
    }
}

/// Result of a simulation run.
#[derive(Debug)]
pub struct RunResult {
    /// Counters for the (post-preload) run.
    pub stats: SimStats,
    /// Internal cycles spent in the preload phase (0 if preload disabled).
    pub preload_cycles: u64,
    /// Collected outputs (only if collection was enabled).
    pub outputs: Vec<OutputWord>,
}

/// Outcome of a cycle-budgeted run ([`Hierarchy::run_budgeted`]).
#[derive(Debug)]
pub enum BudgetedRun {
    /// The program completed within the budget; the result is exactly
    /// what an unbudgeted [`Hierarchy::run`] would have produced.
    Complete(RunResult),
    /// The budget expired first. The hierarchy is suspended mid-program:
    /// the caller may inspect [`Hierarchy::stats_snapshot`], continue
    /// with [`Hierarchy::step_cycles`], capture the state with
    /// [`Hierarchy::snapshot`] to resume later (possibly elsewhere), or
    /// load the next program.
    Partial {
        /// Internal cycles consumed so far (excluding preload).
        cycles: u64,
        /// Off-chip units emitted so far.
        units_out: u64,
    },
}

/// The composed, simulatable memory hierarchy: datapath core + engine.
pub struct Hierarchy {
    core: HierarchyCore,
    engine: Engine,
    /// Whether the preload phase has already run for the loaded program
    /// (a suspended budgeted run resumed with another `run*` call must
    /// not preload twice mid-program).
    preload_done: bool,
}

/// The datapath composition: the stages of Fig 2 plus the per-cycle port
/// scheduling (the role the enclosing SystemVerilog module plays in the
/// RTL). Everything run-loop shaped lives in the [`Engine`].
struct HierarchyCore {
    cfg: HierarchyConfig,
    prog: Option<McuProgram>,
    levels: Vec<LevelStage>,
    ib: Option<InputBuffer>,
    offchip: OffChipMemory,
    osr: Option<Osr>,
    output_enabled: bool,
    /// Output-address staging buffer (capacity reserved at load for the
    /// largest emission, so the hot loop never reallocates).
    addr_buf: Vec<u64>,
    /// Waveform probes (Fig 4 style): per-level write/read strobes and
    /// the output-valid signal; the waveform itself lives in the engine.
    wave_probes: Option<(Vec<WaveformProbe>, Vec<WaveformProbe>, WaveformProbe)>,
    /// Armed fault schedule (see [`crate::sim::fault`]): `None` on every
    /// fault-free run, so no per-edge cost and bit-identical behavior.
    /// Session state like the verify/collect switches — cleared by
    /// `load_program`/`reset`, never checkpointed (a restored run is
    /// fault-free unless re-armed).
    faults: Option<FaultState>,
    /// Whether the most recent clock edge (either domain) changed any
    /// component state — the O(1) gate in front of the full quiescence
    /// check ([`Core::horizon`]). A skip heuristic, not simulation state:
    /// it is deliberately *not* checkpointed, and re-arm/restore reset it
    /// to `true`, which merely forces the engine to tick the next edge
    /// naively — always sound.
    last_edge_active: bool,
}

impl Core for HierarchyCore {
    /// One external clock edge: the input-buffer fill engine talks to the
    /// off-chip memory (after delivering any fault scheduled for this
    /// edge — an in-flight perturbation must land before the fill engine
    /// polls, exactly like a glitch on the external bus would).
    fn external_edge(&mut self, ext_cycle: u64) {
        if self.prog.is_none() {
            return;
        }
        let mut fault_fired = false;
        if let Some(mut fs) = self.faults.take() {
            while let Some(ev) = fs.take_due_external(ext_cycle) {
                self.apply_fault(&ev, &mut fs.report);
                fault_fired = true;
            }
            self.faults = Some(fs);
        }
        let Some(prog) = &self.prog else { return };
        let mut acted = fault_fired;
        if let Some(ib) = &mut self.ib {
            acted |= ib.step_external(&prog.plan, &mut self.offchip, ext_cycle);
        }
        self.last_edge_active = acted;
    }

    /// One internal clock edge: the five-step schedule from the module
    /// docs. Cycle counting, verification and waveform storage are the
    /// engine's (`ctx`).
    fn internal_edge(&mut self, ctx: &mut CycleCtx<'_>) -> Result<()> {
        let cycle = ctx.cycle;
        let n = self.levels.len();
        // Activity tracking for the quiescence fast path: set whenever
        // this edge changes any component state or bumps a non-closed-
        // form counter. Mirrors [`Self::horizon`]'s conditions exactly —
        // the debug assertion in the engine's naive mode holds the two in
        // sync.
        let mut active = false;

        // 0. Deliver faults scheduled for this internal cycle (before the
        // datapath reads anything, like an SEU striking between edges).
        // `faults` is `None` on every fault-free run, so this is free.
        if let Some(mut fs) = self.faults.take() {
            while let Some(ev) = fs.take_due_internal(cycle) {
                self.apply_fault(&ev, &mut fs.report);
                active = true;
            }
            self.faults = Some(fs);
        }

        // 1. CDC synchronizer shift.
        if let Some(ib) = &mut self.ib {
            active |= !ib.sync_settled();
            ib.on_internal_edge();
        }

        // 2. OSR shift-out (the Stage handshake gates the shift; step_into
        // re-checks the valid-bit count internally).
        let mut emitted_this_cycle = false;
        if self.output_enabled && !ctx.sink.complete() {
            if let Some(osr) = &mut self.osr {
                if osr.ready_out() {
                    self.addr_buf.clear();
                    if let Some(word) = osr.step_into(&mut self.addr_buf) {
                        emitted_this_cycle = true;
                        ctx.sink.emit(&self.addr_buf, word, cycle, ctx.stats)?;
                    }
                }
            }
        }

        // 3a. Write enables from registered state.
        let mut want_write = [false; crate::config::MAX_LEVELS];
        for l in 0..n {
            let avail = if l == 0 {
                self.ib.as_ref().is_some_and(|ib| ib.ready_out())
            } else {
                self.levels[l - 1].ready_out()
            };
            let lv = &self.levels[l];
            // The write-enable toggle models "a write needs an active read
            // in the preceding level" (§4.1.4) — it applies to
            // level-to-level transfers between standard levels. Level 0 is
            // fed by the input buffer's handshake instead, and
            // double-buffered levels pace writes with the ping-pong swap
            // handshake (`write_allowed_by_toggle` is always true there).
            let toggle_ok = l == 0 || lv.write_allowed_by_toggle();
            let can_latch = lv.ready_in(lv.word_width());
            want_write[l] = !lv.writes_complete() && toggle_ok && avail && can_latch;
            // A set write-enable toggle changes this edge no matter what
            // (released by the no-write path, re-armed by a write) —
            // level 0 included, whose toggle paces nothing but is still
            // registered state.
            active |= lv.quiescent_for() == 0 || want_write[l];
            if !lv.writes_complete() && avail && (!toggle_ok || !can_latch) {
                ctx.stats.write_waits[l] += 1;
                active = true;
            }
        }

        // 3b. Read enables + port arbitration.
        let mut do_read = [false; crate::config::MAX_LEVELS];
        for l in 0..n {
            let lv = &self.levels[l];
            if lv.reads_complete() || !lv.read_data_ready() {
                continue;
            }
            let is_last = l == n - 1;
            let consumer_ready = if is_last {
                self.output_enabled
                    && match (&self.osr, ctx.sink.complete()) {
                        (_, true) => false,
                        (Some(osr), _) => osr.ready_in(lv.word_width()),
                        (None, _) => true,
                    }
            } else {
                !lv.has_out_reg() || want_write[l + 1]
            };
            if !consumer_ready {
                continue;
            }
            if lv.read_port_free(want_write[l]) {
                do_read[l] = true;
            } else {
                ctx.stats.write_over_read_stalls[l] += 1;
            }
            active = true;
        }

        // 4. Commit writes (consume upstream out-registers / buffer).
        for l in 0..n {
            if want_write[l] {
                let incoming: Slot = if l == 0 {
                    let ib = self.ib.as_mut().expect("ib exists");
                    let (tag, word) = ib.consume();
                    Slot { tag, word }
                } else {
                    self.levels[l - 1].take_out_reg().expect("availability checked")
                };
                self.levels[l].commit_write(incoming).map_err(|e| at_cycle(e, cycle))?;
                ctx.stats.level_writes[l] += 1;
            } else {
                self.levels[l].no_write_this_cycle();
            }
        }

        // 5. Commit reads.
        for l in 0..n {
            if !do_read[l] {
                continue;
            }
            let is_last = l == n - 1;
            let slot = self.levels[l].commit_read(cycle)?;
            ctx.stats.level_reads[l] += 1;
            if is_last {
                self.levels[l].clear_out_reg();
                let prog = self.prog.as_ref().expect("program loaded");
                let pack = prog.plan.pack();
                self.addr_buf.clear();
                for j in 0..pack {
                    self.addr_buf.push(prog.plan.addr_of(slot.tag, j));
                }
                match &mut self.osr {
                    Some(osr) => osr.push_word(&slot.word, &self.addr_buf),
                    None => {
                        emitted_this_cycle = true;
                        ctx.sink.emit(&self.addr_buf, slot.word, cycle, ctx.stats)?;
                    }
                }
            }
        }

        if self.output_enabled && !emitted_this_cycle && !ctx.sink.complete() {
            ctx.stats.output_stalls += 1;
        }

        if let (Some(wf), Some((writes, reads, out))) =
            (ctx.wave.as_deref_mut(), self.wave_probes.as_ref())
        {
            for l in 0..n {
                wf.record(writes[l], cycle, u64::from(want_write[l]));
                wf.record(reads[l], cycle, u64::from(do_read[l]));
            }
            wf.record(*out, cycle, u64::from(emitted_this_cycle));
        }
        self.last_edge_active = active || emitted_this_cycle;
        Ok(())
    }

    fn set_output_enabled(&mut self, on: bool) {
        self.output_enabled = on;
    }

    fn total_units(&self) -> u64 {
        self.prog.as_ref().map(|p| p.total_output_units).unwrap_or(0)
    }

    /// The composed quiescence horizon (see the [`crate::sim::engine`]
    /// module docs). Declares the core quiescent only when the next
    /// internal edge is provably a no-op — the conditions mirror
    /// [`Self::internal_edge`]'s activity tracking one for one — and then
    /// reports when the external domain can next change the picture
    /// (the input buffer's fill horizon over the off-chip pipeline).
    ///
    /// A no-op internal edge leaves the exact state it read, so every
    /// later internal edge before the external wake-up is a no-op by
    /// induction; this is what makes the one-cycle check good for the
    /// whole span.
    fn horizon(&self, sink_complete: bool, next_ext_cycle: u64) -> Horizon {
        // O(1) fast path: anything happened on the last edge → assume
        // active (the full check runs once the machine settles).
        if self.last_edge_active {
            return Horizon::Active;
        }
        // Pending faults pin the horizon: fast-forward must never skip an
        // edge a fault is scheduled on (the injection would silently miss
        // its exact (component, cycle, bit) coordinate).
        if self.faults.as_ref().is_some_and(FaultState::pending) {
            return Horizon::Active;
        }
        let Some(prog) = self.prog.as_ref() else { return Horizon::Active };
        if let Some(ib) = &self.ib {
            // Mid-flight CDC synchronizer: the next shift changes a flop.
            if ib.quiescent_for() == 0 {
                return Horizon::Active;
            }
        }
        if let Some(osr) = &self.osr {
            // An OSR shift would fire (and emit) this cycle.
            if self.output_enabled && !sink_complete && osr.ready_out() {
                return Horizon::Active;
            }
        }
        let n = self.levels.len();
        for l in 0..n {
            let lv = &self.levels[l];
            // A set write-enable toggle is released on the next edge.
            if lv.quiescent_for() == 0 {
                return Horizon::Active;
            }
            // Upstream data presented to a level still writing: either
            // the write commits or `write_waits` ticks — active either
            // way.
            let avail = if l == 0 {
                self.ib.as_ref().is_some_and(|ib| ib.ready_out())
            } else {
                self.levels[l - 1].has_out_reg()
            };
            if avail && !lv.writes_complete() {
                return Horizon::Active;
            }
            // A pending read whose data is present and whose consumer can
            // take it commits this cycle. (With no write anywhere — ruled
            // out above — a ready read is never port-blocked, so no
            // write-over-read stall can tick here either.)
            if !lv.reads_complete() && lv.read_data_ready() {
                let consumer_ready = if l == n - 1 {
                    self.output_enabled
                        && !sink_complete
                        && match &self.osr {
                            Some(osr) => osr.ready_in(lv.word_width()),
                            None => true,
                        }
                } else {
                    !lv.has_out_reg()
                };
                if consumer_ready {
                    return Horizon::Active;
                }
            }
        }
        // Internal edges are no-ops; ask the fill engine when the
        // external domain can next act.
        let output_gated = self.output_enabled;
        let Some(ib) = &self.ib else {
            return Horizon::Quiescent { until_ext: None, output_gated };
        };
        match ib.fill_horizon(&prog.plan, &self.offchip) {
            FillHorizon::Busy => Horizon::Active,
            FillHorizon::Delivery(t) if t <= next_ext_cycle => Horizon::Active,
            FillHorizon::Delivery(t) => {
                Horizon::Quiescent { until_ext: Some(t), output_gated }
            }
            FillHorizon::Idle => Horizon::Quiescent { until_ext: None, output_gated },
        }
    }

    fn last_edge_active(&self) -> bool {
        self.last_edge_active
    }

    /// Handshake round trip in external cycles: the configured off-chip
    /// read latency (issue → delivery of the oldest in-flight word), one
    /// transfer cycle per off-chip sub-word packed into a level-0 word,
    /// and the depth-1 `reset_buffer` round trip — the bound the engine's
    /// preload saturation window is derived from.
    fn handshake_round_trip_ext(&self) -> u64 {
        let pack = u64::from(self.cfg.levels[0].word_width / self.cfg.offchip.data_width);
        self.cfg.offchip.latency + pack + 2
    }

    fn flush_stats(&mut self, stats: &mut SimStats) {
        stats.offchip_reads = self.offchip.reads;
        if let Some(ib) = &self.ib {
            stats.cdc_transfers = ib.transfers;
        }
        if let Some(osr) = &self.osr {
            stats.osr_shifts = osr.shifts_executed;
        }
    }
}

impl HierarchyCore {
    /// Deliver one scheduled fault to its target component and account
    /// for it in `report`.
    ///
    /// Protection is resolved *here*, per upset (see the protection
    /// contract in [`crate::mem`]): an upset that would change a stored
    /// bit of a `Parity` level is counted as detected, of a `Secded`
    /// level as corrected — in both cases the stored state is left
    /// untouched, which is exactly what "detect and re-fetch" / "correct
    /// on read" produce at the architectural level. An upset whose target
    /// is vacant (empty slot, out-of-range bit, stuck-at matching the
    /// stored value, idle pipeline) perturbs nothing anywhere and is
    /// counted as vacant — protected levels get no detection credit for
    /// it either.
    fn apply_fault(&mut self, ev: &FaultEvent, report: &mut FaultReport) {
        match ev.component {
            FaultComponent::Level(l) => {
                let Some(lv) = self.levels.get_mut(l) else {
                    report.vacant += 1;
                    return;
                };
                match lv.cfg().protection {
                    Protection::None => {
                        if lv.inject(&ev.site) {
                            report.injected += 1;
                        } else {
                            report.vacant += 1;
                        }
                    }
                    prot => {
                        // Probe without mutating: the upset only counts
                        // if it would actually change a stored bit.
                        let hit = matches!(ev.site, FaultSite::Slot { slot, bit, kind }
                            if lv.probe_slot_bit(slot, bit).is_some_and(|cur| {
                                kind.apply(u64::from(cur)) != u64::from(cur)
                            }));
                        if !hit {
                            report.vacant += 1;
                        } else if prot == Protection::Parity {
                            report.detected += 1;
                        } else {
                            report.corrected += 1;
                        }
                    }
                }
            }
            FaultComponent::InputBuffer => {
                if self.ib.as_mut().is_some_and(|ib| ib.inject(&ev.site)) {
                    report.injected += 1;
                } else {
                    report.vacant += 1;
                }
            }
            FaultComponent::Osr => {
                if self.osr.as_mut().is_some_and(|osr| osr.inject(&ev.site)) {
                    report.injected += 1;
                } else {
                    report.vacant += 1;
                }
            }
            FaultComponent::OffChip => {
                let landed = self.offchip.inject(&ev.site);
                let bucket = match (landed, ev.site) {
                    (false, _) => &mut report.vacant,
                    (true, FaultSite::DelayDelivery { .. }) => &mut report.delayed,
                    (true, FaultSite::DropDelivery) => &mut report.dropped,
                    (true, _) => &mut report.injected,
                };
                *bucket += 1;
            }
        }
    }
}

impl Hierarchy {
    /// Validate `cfg` for simulation: the config's own §4.1 constraints
    /// plus the input-buffer packing direction (shared by [`Self::new`]
    /// and [`Self::rearm`]).
    fn validate_cfg(cfg: &HierarchyConfig) -> Result<()> {
        cfg.validate()?;
        if cfg.levels[0].word_width < cfg.offchip.data_width {
            return Err(Error::Config(format!(
                "level-0 word width {} below off-chip width {} is not supported \
                 (the input buffer packs, it does not split)",
                cfg.levels[0].word_width, cfg.offchip.data_width
            )));
        }
        Ok(())
    }

    /// Build an idle hierarchy for `cfg`.
    pub fn new(cfg: &HierarchyConfig) -> Result<Self> {
        Self::validate_cfg(cfg)?;
        let core = HierarchyCore {
            cfg: cfg.clone(),
            prog: None,
            levels: Vec::new(),
            ib: None,
            offchip: OffChipMemory::new(
                cfg.offchip.data_width,
                cfg.offchip.latency,
                cfg.offchip.addr_width,
            ),
            osr: None,
            output_enabled: true,
            addr_buf: Vec::with_capacity(16),
            wave_probes: None,
            faults: None,
            last_edge_active: true,
        };
        let engine = Engine::new(
            ClockPair::from_freqs(cfg.offchip.external_hz, cfg.offchip.internal_hz),
            cfg.levels.len(),
            StreamSpec::idle(cfg.offchip.data_width, payload_for),
        );
        Ok(Self { core, engine, preload_done: false })
    }

    /// Attach a waveform recorder capturing per-level write/read strobes
    /// and the output-valid signal each internal cycle (Fig 4).
    pub fn attach_waveform(&mut self) {
        let mut wf = Waveform::new();
        let n = self.core.cfg.levels.len();
        let writes: Vec<_> = (0..n).map(|i| wf.probe(&format!("L{i}_write"), 1)).collect();
        let reads: Vec<_> = (0..n).map(|i| wf.probe(&format!("L{i}_read"), 1)).collect();
        let out = wf.probe("output_valid", 1);
        self.core.wave_probes = Some((writes, reads, out));
        self.engine.attach_waveform(wf);
    }

    /// Take the recorded waveform (if any).
    pub fn take_waveform(&mut self) -> Option<Waveform> {
        self.engine.take_waveform()
    }

    /// Load a pattern program (a reset cycle in the RTL): compiles the
    /// program, resets all state, and arms the fetch plan.
    ///
    /// Loading is **warm**: once a hierarchy has run a program, loading
    /// the next one re-arms the existing levels, input buffer, OSR,
    /// off-chip model, stats and output sink *in place* — no component is
    /// reallocated, which is what makes back-to-back co-simulation
    /// ([`crate::sim::batch::Session`]) and the pooled DSE paths cheap.
    /// The post-load state is bit-identical to a freshly constructed
    /// hierarchy, so warm and cold runs produce the same results.
    pub fn load_program(&mut self, prog: &PatternProgram) -> Result<()> {
        let compiled = McuProgram::compile(&self.core.cfg, prog)?;
        // A failed load must not leave a previous program half-armed, and
        // a fault plan is armed per program — loading disarms it.
        self.core.prog = None;
        self.core.faults = None;
        // OSR alignment: emissions must tile the total output units.
        let w_off = self.core.cfg.offchip.data_width;
        if let Some(osr_cfg) = &self.core.cfg.osr {
            for &s in &osr_cfg.shifts {
                if s % w_off != 0 {
                    return Err(Error::Config(format!(
                        "OSR shift {s} not a multiple of off-chip width {w_off}"
                    )));
                }
            }
        }
        // Levels: re-arm existing storage in place; allocate only on
        // first use (or when a re-configuration deepened the hierarchy).
        let n_levels = self.core.cfg.levels.len();
        self.core.levels.truncate(n_levels);
        for i in 0..n_levels {
            let lu = compiled.levels[i];
            if i < self.core.levels.len() {
                self.core.levels[i].rearm(&self.core.cfg.levels[i], lu);
            } else {
                self.core.levels.push(LevelStage::new(&self.core.cfg.levels[i], lu));
            }
        }
        let w0 = self.core.cfg.levels[0].word_width;
        let ib_depth = self.core.cfg.offchip.ib_depth;
        if let Some(ib) = self.core.ib.as_mut() {
            ib.rearm(w0, w_off, ib_depth, &compiled.plan);
        } else {
            self.core.ib = Some(InputBuffer::new(w0, w_off, ib_depth, &compiled.plan));
        }
        match &self.core.cfg.osr {
            None => self.core.osr = None,
            Some(o) => {
                if let Some(osr) = self.core.osr.as_mut() {
                    osr.rearm(o.width, w_off, &o.shifts, 1)?;
                } else {
                    self.core.osr = Some(Osr::new(o.width, w_off, o.shifts.clone(), 1)?);
                }
            }
        }
        self.core.offchip.rearm(
            w_off,
            self.core.cfg.offchip.latency,
            self.core.cfg.offchip.addr_width,
        );
        // Reserve the address staging buffer for the largest emission so
        // the hot loop never reallocates.
        let mut need = compiled.plan.pack() as usize;
        if let Some(o) = &self.core.cfg.osr {
            let per_shift = o.shifts.iter().map(|&s| (s / w_off) as usize).max();
            need = need.max(per_shift.unwrap_or(0));
        }
        self.core.addr_buf.clear();
        if self.core.addr_buf.capacity() < need {
            // reserve() is relative to len (0 after the clear), so this
            // guarantees capacity >= need.
            self.core.addr_buf.reserve(need);
        }
        self.core.output_enabled = true;
        self.engine.arm(
            ClockPair::from_freqs(
                self.core.cfg.offchip.external_hz,
                self.core.cfg.offchip.internal_hz,
            ),
            n_levels,
            StreamSpec {
                start_address: prog.start_address,
                stride: prog.stride,
                cycle_length: prog.output.cycle_length,
                inter_cycle_shift: prog.output.inter_cycle_shift,
                skip_shift: prog.output.skip_shift,
                sub_width: w_off,
                total_units: prog.total_outputs,
                payload: payload_for,
            },
        );
        self.core.prog = Some(compiled);
        self.core.last_edge_active = true;
        self.preload_done = false;
        Ok(())
    }

    /// Return to the idle state (no program loaded) without deallocating:
    /// level slots, buffers, stats vectors and the collection pool all
    /// keep their storage for the next [`Self::load_program`].
    pub fn reset(&mut self) {
        self.core.prog = None;
        self.core.faults = None;
        self.core.output_enabled = true;
        self.core.last_edge_active = true;
        self.engine.arm(
            ClockPair::from_freqs(
                self.core.cfg.offchip.external_hz,
                self.core.cfg.offchip.internal_hz,
            ),
            self.core.cfg.levels.len(),
            StreamSpec::idle(self.core.cfg.offchip.data_width, payload_for),
        );
    }

    /// Re-configure the hierarchy to `cfg` **in place** (the warm-session
    /// DSE path): validates exactly like [`Self::new`], swaps the
    /// configuration, and drops to the idle state while keeping every
    /// reusable allocation — level slot storage, queues, stats vectors
    /// and the output-collection pool are re-armed by the next
    /// `load_program` instead of being reallocated. Equivalent to
    /// `*self = Hierarchy::new(cfg)?` as far as simulation results are
    /// concerned.
    pub fn rearm(&mut self, cfg: &HierarchyConfig) -> Result<()> {
        Self::validate_cfg(cfg)?;
        if self.core.cfg.levels.len() != cfg.levels.len() {
            // Waveform probes are registered per level; a different depth
            // invalidates them (re-attach after re-configuring).
            self.core.wave_probes = None;
        }
        if self.core.cfg != *cfg {
            self.core.cfg = cfg.clone();
        }
        self.reset();
        Ok(())
    }

    /// Force the engine's naive tick-per-cycle loop, disabling the
    /// event-horizon fast-forward (see [`crate::sim::engine`]'s module
    /// docs). An operator setting like the verify/collect switches: it
    /// survives re-arms and program loads, is not captured by
    /// checkpoints (the state at any edge boundary is identical in both
    /// modes, so checkpoints move freely across them), and has no effect
    /// on any result — it exists as the differential-testing oracle and
    /// the A/B baseline for wall-clock measurements.
    pub fn set_force_naive(&mut self, on: bool) {
        self.engine.set_force_naive(on);
    }

    /// Whether the naive tick-per-cycle loop is forced.
    pub fn force_naive(&self) -> bool {
        self.engine.force_naive()
    }

    /// Enable/disable end-to-end data verification (on by default; turn
    /// off for performance measurements).
    pub fn set_verify(&mut self, on: bool) {
        self.engine.set_verify(on);
    }

    /// Whether end-to-end data verification is enabled.
    pub fn verify_enabled(&self) -> bool {
        self.engine.verifying()
    }

    /// Enable output collection (off by default).
    pub fn set_collect(&mut self, on: bool) {
        self.engine.set_collect(on);
    }

    /// Whether output collection is enabled.
    pub fn collect_enabled(&self) -> bool {
        self.engine.collecting()
    }

    /// Return consumed output buffers to the collection pool, so repeated
    /// collected runs allocate nothing per output in steady state.
    pub fn recycle_outputs(&mut self, outputs: Vec<OutputWord>) {
        self.engine.sink_mut().recycle(outputs);
    }

    /// Select the OSR shift at runtime.
    pub fn select_osr_shift(&mut self, sel: usize) -> Result<()> {
        match &mut self.core.osr {
            Some(o) => o.select_shift(sel),
            None => Err(Error::Config("no OSR configured".into())),
        }
    }

    /// The `disable_output_i` port (Table 1).
    pub fn set_output_enabled(&mut self, on: bool) {
        self.core.set_output_enabled(on);
    }

    /// Total off-chip units the loaded program will emit.
    pub fn total_units(&self) -> u64 {
        self.core.total_units()
    }

    /// Whether all programmed outputs have been emitted.
    pub fn outputs_complete(&self) -> bool {
        self.engine.units_out() >= self.core.total_units()
    }

    /// Run until all outputs are produced. If preload is configured, first
    /// runs a fill phase with outputs disabled (not counted in
    /// `stats.internal_cycles`).
    pub fn run(&mut self) -> Result<RunResult> {
        if self.core.prog.is_none() {
            return Err(Error::Pattern("no program loaded".into()));
        }
        let preload = self.core.cfg.preload && !self.preload_done;
        self.preload_done = true;
        let r = self.engine.run(&mut self.core, preload)?;
        Ok(RunResult { stats: r.stats, preload_cycles: r.preload_cycles, outputs: r.outputs })
    }

    /// Like [`Self::run`] but stops after `budget` internal cycles if the
    /// program has not completed by then — the successive-halving
    /// screening primitive of `dse`. When the program completes within
    /// the budget, the returned [`RunResult`] is bit-identical to what a
    /// plain `run` would have produced.
    pub fn run_budgeted(&mut self, budget: u64) -> Result<BudgetedRun> {
        if self.core.prog.is_none() {
            return Err(Error::Pattern("no program loaded".into()));
        }
        // Preload exactly once per loaded program: resuming a suspended
        // Partial run must not re-run the fill phase mid-program.
        let preload = self.core.cfg.preload && !self.preload_done;
        self.preload_done = true;
        match self.engine.run_budget(&mut self.core, preload, budget)? {
            BudgetOutcome::Complete(r) => Ok(BudgetedRun::Complete(RunResult {
                stats: r.stats,
                preload_cycles: r.preload_cycles,
                outputs: r.outputs,
            })),
            BudgetOutcome::Partial { cycles, units_out } => {
                Ok(BudgetedRun::Partial { cycles, units_out })
            }
        }
    }

    /// Convenience: run and return stats, checking that the loaded
    /// program is sized for exactly `n` outputs (off-chip units). Returns
    /// the sizing mismatch or any simulation failure as an error instead
    /// of panicking.
    pub fn run_to_outputs(&mut self, n: u64) -> Result<SimStats> {
        let total = self.total_units();
        if total != n {
            return Err(Error::Pattern(format!(
                "loaded program is sized for {total} output units, not {n}"
            )));
        }
        Ok(self.run()?.stats)
    }

    /// Fault injection (verification testing): flip the given bit of the
    /// word stored in `level`/`slot`. Returns false if the slot is empty.
    /// A subsequent run must fail with an integrity error — this is how
    /// the end-to-end data-path checking is itself validated.
    pub fn inject_bit_flip(&mut self, level: usize, slot: u64, bit: u32) -> bool {
        let Some(lv) = self.core.levels.get_mut(level) else { return false };
        lv.corrupt_slot(slot, bit)
    }

    /// Arm a deterministic fault schedule for the loaded program (see
    /// [`crate::sim::fault`]): each event fires at its exact
    /// (component, cycle, bit) coordinate during subsequent `run*` calls.
    /// A plan is armed per program — `load_program` and [`Self::reset`]
    /// disarm it, and checkpoints never carry it (a restored run is
    /// fault-free unless re-armed). Re-arming replaces any previous plan
    /// and discards its in-progress report.
    pub fn arm_faults(&mut self, plan: &FaultPlan) {
        self.core.faults = Some(FaultState::new(plan));
        // Force a naive first edge so the engine re-evaluates the horizon
        // with the pending-fault clamp in place.
        self.core.last_edge_active = true;
    }

    /// Disarm the fault schedule, returning the injection report (what
    /// actually landed, was corrected, detected, delayed, dropped, or hit
    /// vacant storage). `None` if no plan was armed.
    pub fn clear_faults(&mut self) -> Option<FaultReport> {
        self.core.faults.take().map(FaultState::finish)
    }

    /// The in-progress injection report of the armed fault schedule, if
    /// any (events not yet fired are not reflected).
    pub fn fault_report(&self) -> Option<&FaultReport> {
        self.core.faults.as_ref().map(|fs| &fs.report)
    }

    /// Override the engine's no-progress deadlock window (default
    /// [`crate::sim::engine::DEADLOCK_LIMIT`]). An operator setting like
    /// [`Self::set_verify`] — never checkpointed. Fault campaigns tighten
    /// it so hung runs (e.g. a dropped off-chip delivery starving the
    /// input buffer) fail fast.
    pub fn set_deadlock_limit(&mut self, limit: u64) {
        self.engine.set_deadlock_limit(limit);
    }

    /// Run exactly `n` internal cycles (micro-stepping for tests and
    /// waveform capture); external edges are interleaved per the clock
    /// ratio. Returns the outputs emitted so far.
    pub fn step_cycles(&mut self, n: u64) -> Result<u64> {
        self.engine.step_cycles(&mut self.core, n)
    }

    /// Access the accumulated stats (e.g. mid-run).
    pub fn stats(&self) -> &SimStats {
        self.engine.stats()
    }

    /// Clone the accumulated stats *including* component-resident
    /// counters (off-chip reads, CDC transfers, OSR shifts), which a
    /// full run only flushes at completion. This is the mid-run view a
    /// budgeted screening pass scores candidates from.
    pub fn stats_snapshot(&mut self) -> SimStats {
        let mut s = self.engine.stats().clone();
        self.core.flush_stats(&mut s);
        s
    }

    /// The active configuration.
    pub fn config(&self) -> &HierarchyConfig {
        &self.core.cfg
    }

    /// Capture the full simulation state of the loaded program at the
    /// current edge boundary (see [`HierarchyCheckpoint`] for the
    /// invariants). Typically called after [`Self::run_budgeted`] returned
    /// [`BudgetedRun::Partial`]; errors if no program is loaded.
    pub fn snapshot(&self) -> Result<HierarchyCheckpoint> {
        let Some(prog) = self.core.prog.as_ref() else {
            return Err(Error::Pattern("no program loaded to snapshot".into()));
        };
        Ok(HierarchyCheckpoint {
            config: self.core.cfg.clone(),
            prog: prog.clone(),
            levels: self.core.levels.iter().map(LevelStage::snapshot).collect(),
            ib: self.core.ib.as_ref().map(InputBuffer::snapshot),
            offchip: self.core.offchip.snapshot(),
            osr: self.core.osr.as_ref().map(Osr::snapshot),
            output_enabled: self.core.output_enabled,
            preload_done: self.preload_done,
            engine: self.engine.snapshot(),
        })
    }

    /// Restore a [`HierarchyCheckpoint`] onto this hierarchy. The
    /// hierarchy must be armed for the checkpoint's (configuration,
    /// program) pair — i.e. `rearm(ck.config())` (or construction under
    /// that config) followed by `load_program` of the checkpointed
    /// program. Configuration or program mismatches are rejected before
    /// any state is touched; after a successful restore, continuing with
    /// `run`/`run_budgeted`/`step_cycles` is bit-identical to never having
    /// suspended. Restoring reuses the armed components' allocations.
    pub fn restore(&mut self, ck: &HierarchyCheckpoint) -> Result<()> {
        let Some(armed) = self.core.prog.as_ref() else {
            return Err(Error::Pattern(
                "load the checkpointed program before restoring".into(),
            ));
        };
        if self.core.cfg != ck.config {
            return Err(Error::Config(
                "checkpoint belongs to a different hierarchy configuration".into(),
            ));
        }
        if *armed != ck.prog {
            return Err(Error::Pattern(
                "checkpoint was taken under a different program than the one loaded".into(),
            ));
        }
        if self.engine.verifying() != ck.engine.captured_verify()
            || self.engine.collecting() != ck.engine.captured_collect()
        {
            return Err(Error::Config(
                "checkpoint was captured under different verify/collect settings; \
                 set the session's switches to match before restoring"
                    .into(),
            ));
        }
        // Config equality guarantees matching level kinds and component
        // presence; the per-component checks below are defensive.
        if self.core.levels.len() != ck.levels.len()
            || self.core.ib.is_some() != ck.ib.is_some()
            || self.core.osr.is_some() != ck.osr.is_some()
        {
            return Err(Error::Config(
                "checkpoint component layout does not match the armed hierarchy".into(),
            ));
        }
        for (lv, c) in self.core.levels.iter_mut().zip(ck.levels.iter()) {
            lv.restore(c)?;
        }
        if let (Some(ib), Some(c)) = (self.core.ib.as_mut(), ck.ib.as_ref()) {
            ib.restore(c);
        }
        self.core.offchip.restore(&ck.offchip);
        if let (Some(osr), Some(c)) = (self.core.osr.as_mut(), ck.osr.as_ref()) {
            osr.restore(c);
        }
        self.core.output_enabled = ck.output_enabled;
        self.core.last_edge_active = true;
        self.preload_done = ck.preload_done;
        self.engine.restore(&ck.engine);
        Ok(())
    }
}

fn at_cycle(e: Error, cycle: u64) -> Error {
    match e {
        Error::Integrity { msg, .. } => Error::Integrity { cycle, msg },
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchyConfig;
    use crate::pattern::PatternProgram;

    fn cfg(d0: u64, d1: u64, l0_ports: u32, preload: bool) -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, d0, 1, l0_ports)
            .level(32, d1, 1, 2)
            .preload(preload)
            .build()
            .unwrap()
    }

    #[test]
    fn cyclic_small_window_streams_at_one_per_cycle() {
        // Window fits the last level: steady state is one output per cycle.
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 5_000);
        // Fill phase: 64 words at ~3 cycles each, then 1/cycle.
        let cycles = r.stats.internal_cycles;
        assert!(cycles >= 5_000, "cannot beat one per cycle, got {cycles}");
        assert!(cycles < 5_000 + 3 * 64 + 50, "fill overhead too high: {cycles}");
        assert!(r.stats.steady_state_efficiency() > 0.95);
    }

    #[test]
    fn cyclic_large_window_doubles_runtime() {
        // Window exceeds the last level but fits level 0: round-robin
        // replacement halves throughput (§5.2.1, Fig 5).
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 512).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        let eff = r.stats.efficiency();
        assert!(
            (0.42..0.55).contains(&eff),
            "expected ~0.5 outputs/cycle (doubled runtime), got {eff}"
        );
    }

    #[test]
    fn no_resident_level_triples_runtime() {
        // Window fits nowhere: every word re-fetched off-chip at the
        // 3-cycle handshake cadence.
        let c = cfg(64, 16, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 256).with_outputs(2_048)).unwrap();
        let r = h.run().unwrap();
        let eff = r.stats.efficiency();
        assert!(
            (0.30..0.37).contains(&eff),
            "expected ~1/3 outputs/cycle (off-chip bound), got {eff}"
        );
        // Every unit fetched once per use.
        assert_eq!(r.stats.offchip_reads, 2_048);
    }

    #[test]
    fn double_buffered_level_overlaps_fill_and_drain() {
        // Window fits L0 but not L1, so L1 streams the full output. A
        // standard L1 is toggle-limited to ~0.5 outputs/cycle (see
        // `cyclic_large_window_doubles_runtime`); a ping-pong L1 accepts a
        // write and serves a read every cycle, so the stream runs at ~1
        // output/cycle once the window is fetched.
        let std_cfg = cfg(1024, 128, 1, false);
        let db_cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 1024, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap();
        let prog = PatternProgram::cyclic(0, 512).with_outputs(10_000);
        let run = |c: &HierarchyConfig| {
            let mut h = Hierarchy::new(c).unwrap();
            h.load_program(&prog).unwrap();
            h.run().unwrap().stats
        };
        let s = run(&std_cfg);
        let d = run(&db_cfg);
        assert!(
            (0.42..0.55).contains(&s.efficiency()),
            "standard streams at ~0.5, got {}",
            s.efficiency()
        );
        assert!(
            d.efficiency() > 0.8,
            "ping-pong overlap should reach ~1/cycle, got {}",
            d.efficiency()
        );
        assert!(
            d.internal_cycles * 10 < s.internal_cycles * 7,
            "ping-pong {} vs standard {} cycles",
            d.internal_cycles,
            s.internal_cycles
        );
    }

    #[test]
    fn preload_removes_fill_phase() {
        let c = cfg(1024, 128, 1, true);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        assert!(r.preload_cycles > 0);
        assert!(
            r.stats.internal_cycles <= 5_010,
            "preloaded run should be ~1/cycle, got {}",
            r.stats.internal_cycles
        );
    }

    #[test]
    fn shifted_cyclic_verified_end_to_end() {
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.set_collect(true);
        h.load_program(&PatternProgram::shifted_cyclic(1000, 32, 8).with_outputs(512)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.outputs.len(), 512);
        // Spot-check the pattern: first window 1000..1032, second 1008..1040.
        assert_eq!(r.outputs[0].addrs, vec![1000]);
        assert_eq!(r.outputs[31].addrs, vec![1031]);
        assert_eq!(r.outputs[32].addrs, vec![1008]);
    }

    #[test]
    fn sequential_pattern_runs_at_one_third() {
        // No reuse: every output crosses the CDC handshake (3 cycles).
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::sequential(0, 1_000)).unwrap();
        let r = h.run().unwrap();
        let eff = r.stats.efficiency();
        assert!((0.30..0.37).contains(&eff), "sequential ~1/3 per cycle, got {eff}");
    }

    #[test]
    fn packing_with_osr_sustains_full_rate() {
        // Fig 6: 128-bit levels + OSR emitting 32-bit words.
        let c = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 256).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 5_000);
        // Window (256 units = 64 level words) exceeds L1 (32) but fits L0:
        // the wide word moves 4 units per write, so the stream sustains
        // one 32-bit output per cycle even while replacing round-robin.
        let eff = r.stats.efficiency();
        assert!(eff > 0.9, "wide words must hide replacement, got {eff}");
    }

    #[test]
    fn dual_ported_l0_matches_single_at_worst_case() {
        // At shift == cycle length both configs bottom out at 1/3 (§5.2.3).
        for ports in [1, 2] {
            let c = cfg(512, 128, ports, false);
            let mut h = Hierarchy::new(&c).unwrap();
            h.load_program(&PatternProgram::shifted_cyclic(0, 64, 64).with_outputs(4_096)).unwrap();
            let r = h.run().unwrap();
            let eff = r.stats.efficiency();
            assert!(
                (0.30..0.37).contains(&eff),
                "ports={ports}: worst case ~1/3, got {eff}"
            );
        }
    }

    #[test]
    fn small_shift_keeps_full_throughput() {
        // Shift below one third of the cycle length: refills hide behind
        // the reuse window (§5.2.3).
        let c = cfg(512, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(4_800)).unwrap();
        let r = h.run().unwrap();
        assert!(
            r.stats.steady_state_efficiency() > 0.95,
            "s=l/6 should sustain full rate, got {}",
            r.stats.steady_state_efficiency()
        );
    }

    #[test]
    fn case_study_clock_ratio_weight_loads() {
        // §5.3.2: 32-bit off-chip at 4x the accelerator clock; 128-bit
        // level words take 3 accelerator cycles each.
        let c = HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&c).unwrap();
        // Sequential weights: 96 units = 24 level words = 8 OSR fills.
        h.load_program(&PatternProgram::sequential(0, 96)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 8, "eight 384-bit weight ports");
        let cyc = r.stats.internal_cycles;
        // 24 level words at ~3 cycles each ≈ 72 cycles (+pipeline slack).
        assert!((70..95).contains(&cyc), "expected ≈3 cycles/word, got {cyc}");
    }

    #[test]
    fn single_level_hierarchy_works() {
        let c = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 256, 1, 2)
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(4_096)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 4_096);
        // steady_state_efficiency only excludes cycles before the *first*
        // output; the 3-cycle-per-word fill tail still dilutes it.
        assert!(r.stats.steady_state_efficiency() > 0.93);
    }

    #[test]
    fn run_without_program_errors() {
        let c = cfg(64, 16, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        assert!(h.run().is_err());
    }

    #[test]
    fn offchip_reads_match_unique_for_resident_patterns() {
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::shifted_cyclic(0, 64, 8).with_outputs(640)).unwrap();
        let r = h.run().unwrap();
        // 640 outputs = 10 cycles: window 64 + 9 shifts x 8 = 136 uniques.
        assert_eq!(r.stats.offchip_reads, 136);
        assert_eq!(r.stats.outputs, 640);
    }

    #[test]
    fn warm_reload_matches_fresh_run() {
        // The warm-session guarantee at the hierarchy level: running
        // program B after program A on the same hierarchy produces the
        // exact stats and outputs a fresh hierarchy produces for B.
        let c = cfg(1024, 128, 1, false);
        let progs = [
            PatternProgram::cyclic(0, 64).with_outputs(640),
            PatternProgram::shifted_cyclic(1000, 32, 8).with_outputs(512),
            PatternProgram::sequential(7, 300),
        ];
        let mut warm = Hierarchy::new(&c).unwrap();
        warm.set_collect(true);
        for p in &progs {
            warm.load_program(p).unwrap();
            let w = warm.run().unwrap();
            let mut fresh = Hierarchy::new(&c).unwrap();
            fresh.set_collect(true);
            fresh.load_program(p).unwrap();
            let f = fresh.run().unwrap();
            assert_eq!(w.stats, f.stats);
            assert_eq!(w.outputs, f.outputs);
            assert_eq!(w.preload_cycles, f.preload_cycles);
        }
    }

    #[test]
    fn rearm_reconfigures_in_place() {
        // Re-arm across configurations (including a depth change) must be
        // indistinguishable from constructing fresh hierarchies.
        let configs = [
            cfg(1024, 128, 1, false),
            cfg(64, 16, 1, false),
            HierarchyConfig::builder()
                .offchip(32, 24, 1.0)
                .level(32, 256, 1, 2)
                .build()
                .unwrap(),
        ];
        let prog = PatternProgram::cyclic(0, 48).with_outputs(480);
        let mut warm = Hierarchy::new(&configs[0]).unwrap();
        for c in configs.iter().cycle().take(6) {
            warm.rearm(c).unwrap();
            warm.load_program(&prog).unwrap();
            let w = warm.run().unwrap();
            let mut fresh = Hierarchy::new(c).unwrap();
            fresh.load_program(&prog).unwrap();
            let f = fresh.run().unwrap();
            assert_eq!(w.stats, f.stats, "config {:?}", c.levels);
        }
        // Invalid configs are rejected without corrupting the session.
        let bad = {
            let mut b = configs[0].clone();
            b.levels[0].word_width = 16; // below the off-chip width
            b
        };
        assert!(warm.rearm(&bad).is_err());
        warm.rearm(&configs[1]).unwrap();
        warm.load_program(&prog).unwrap();
        assert!(warm.run().is_ok());
    }

    #[test]
    fn reset_returns_to_idle() {
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(320)).unwrap();
        h.run().unwrap();
        h.reset();
        assert!(h.run().is_err(), "idle hierarchy must refuse to run");
        assert_eq!(h.total_units(), 0);
        h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(320)).unwrap();
        assert_eq!(h.run().unwrap().stats.outputs, 320);
    }

    #[test]
    fn budgeted_run_screens_and_completes() {
        let c = cfg(1024, 128, 1, false);
        let prog = PatternProgram::cyclic(0, 64).with_outputs(5_000);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&prog).unwrap();
        let partial = match h.run_budgeted(1_000).unwrap() {
            BudgetedRun::Partial { cycles, units_out } => (cycles, units_out),
            other => panic!("expected partial, got {other:?}"),
        };
        assert_eq!(partial.0, 1_000);
        assert!(partial.1 > 0 && partial.1 < 5_000);
        // Mid-run snapshot carries component counters.
        let snap = h.stats_snapshot();
        assert!(snap.offchip_reads > 0);
        // A generous budget completes with stats identical to run().
        let mut a = Hierarchy::new(&c).unwrap();
        a.load_program(&prog).unwrap();
        let ra = match a.run_budgeted(u64::MAX).unwrap() {
            BudgetedRun::Complete(r) => r,
            other => panic!("expected complete, got {other:?}"),
        };
        let mut b = Hierarchy::new(&c).unwrap();
        b.load_program(&prog).unwrap();
        let rb = b.run().unwrap();
        assert_eq!(ra.stats, rb.stats);
    }

    #[test]
    fn budgeted_resume_matches_uninterrupted_run() {
        // Resuming a suspended Partial run must not re-run the preload
        // phase: the final stats equal a single uninterrupted run's.
        let c = cfg(1024, 128, 1, true);
        let prog = PatternProgram::cyclic(0, 64).with_outputs(2_000);
        let mut a = Hierarchy::new(&c).unwrap();
        a.load_program(&prog).unwrap();
        assert!(matches!(a.run_budgeted(500).unwrap(), BudgetedRun::Partial { .. }));
        let ra = match a.run_budgeted(u64::MAX).unwrap() {
            BudgetedRun::Complete(r) => r,
            other => panic!("expected completion, got {other:?}"),
        };
        // The preload happened during the first (partial) call, so the
        // resumed completion reports 0 preload cycles of its own.
        assert_eq!(ra.preload_cycles, 0);
        let mut b = Hierarchy::new(&c).unwrap();
        b.load_program(&prog).unwrap();
        let rb = b.run().unwrap();
        assert!(rb.preload_cycles > 0);
        assert_eq!(ra.stats, rb.stats, "resumed run diverged from uninterrupted run");
    }

    #[test]
    fn run_to_outputs_reports_mismatch_as_error() {
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(640)).unwrap();
        assert!(h.run_to_outputs(999).is_err(), "sizing mismatch must error");
        let stats = h.run_to_outputs(640).unwrap();
        assert_eq!(stats.outputs, 640);
    }

    #[test]
    fn snapshot_resume_matches_uninterrupted_run() {
        // Suspend mid-run, snapshot, dirty the hierarchy with a different
        // program, then reload + restore: the completed run must equal an
        // uninterrupted one bit for bit.
        let c = cfg(1024, 128, 1, true);
        let prog = PatternProgram::cyclic(0, 64).with_outputs(2_000);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&prog).unwrap();
        assert!(matches!(h.run_budgeted(700).unwrap(), BudgetedRun::Partial { .. }));
        let ck = h.snapshot().unwrap();
        assert_eq!(ck.cycles(), 700);
        assert!(ck.units_out() > 0);
        // Dirty the session with an unrelated program, then come back.
        h.load_program(&PatternProgram::sequential(5, 300)).unwrap();
        h.run().unwrap();
        h.load_program(&prog).unwrap();
        h.restore(&ck).unwrap();
        let resumed = match h.run_budgeted(u64::MAX).unwrap() {
            BudgetedRun::Complete(r) => r,
            other => panic!("expected completion, got {other:?}"),
        };
        let mut fresh = Hierarchy::new(&c).unwrap();
        fresh.load_program(&prog).unwrap();
        let straight = fresh.run().unwrap();
        assert_eq!(resumed.stats, straight.stats, "restored run diverged");
    }

    #[test]
    fn restore_is_config_and_program_keyed() {
        let c = cfg(1024, 128, 1, false);
        let prog = PatternProgram::cyclic(0, 64).with_outputs(2_000);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&prog).unwrap();
        assert!(h.snapshot().is_ok());
        assert!(matches!(h.run_budgeted(500).unwrap(), BudgetedRun::Partial { .. }));
        let ck = h.snapshot().unwrap();
        // Different configuration: rejected.
        let other_cfg = cfg(64, 16, 1, false);
        let mut other = Hierarchy::new(&other_cfg).unwrap();
        other.load_program(&PatternProgram::cyclic(0, 16).with_outputs(512)).unwrap();
        assert!(other.restore(&ck).is_err(), "config mismatch must be rejected");
        // Same configuration, different program size: rejected.
        let mut same = Hierarchy::new(&c).unwrap();
        same.load_program(&prog.clone().with_outputs(1_000)).unwrap();
        assert!(same.restore(&ck).is_err(), "program-size mismatch must be rejected");
        // Same size, different pattern: still rejected (the key is the
        // full compiled program, not just the output count).
        same.load_program(&PatternProgram::sequential(0, 2_000)).unwrap();
        assert!(same.restore(&ck).is_err(), "pattern mismatch must be rejected");
        // Matching program but mismatched verify/collect switches:
        // rejected (the sink's run state is keyed to the capture-time
        // settings).
        same.load_program(&prog).unwrap();
        same.set_verify(false);
        assert!(same.restore(&ck).is_err(), "switch mismatch must be rejected");
        same.set_verify(true);
        // No program loaded: rejected.
        let mut idle = Hierarchy::new(&c).unwrap();
        assert!(idle.restore(&ck).is_err(), "idle hierarchy must refuse restore");
        assert!(idle.snapshot().is_err(), "idle hierarchy has nothing to snapshot");
        // Properly re-armed: accepted, and snapshot round-trips.
        same.load_program(&prog).unwrap();
        same.restore(&ck).unwrap();
        assert_eq!(same.snapshot().unwrap(), ck, "snapshot-restore-snapshot round trip");
    }

    #[test]
    fn armed_fault_fires_and_reload_disarms() {
        use crate::sim::fault::{FaultComponent, FaultKind, FaultPlan, FaultSite};
        let c = cfg(1024, 128, 1, false);
        let prog = PatternProgram::cyclic(0, 64).with_outputs(640);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&prog).unwrap();
        // Flip a stored bit in the last level mid-stream: the window is
        // resident there and re-read every pass, so the verifying sink
        // must catch the corrupted payload.
        let site = FaultSite::Slot { slot: 3, bit: 5, kind: FaultKind::Flip };
        h.arm_faults(&FaultPlan::new().with(200, FaultComponent::Level(1), site));
        let r = h.run();
        let report = h.clear_faults().expect("plan was armed");
        assert_eq!(report.injected, 1, "flip must land in occupied storage");
        assert!(r.is_err(), "verified run must catch the flipped bit");
        // Loading the next program disarms: the rerun is clean.
        h.load_program(&prog).unwrap();
        assert!(h.fault_report().is_none());
        assert_eq!(h.run().unwrap().stats.outputs, 640);
    }

    #[test]
    fn collected_output_buffers_recycle_across_runs() {
        // The collection pool keeps repeated collected runs allocation-
        // free: recycled buffers are handed back out on the next run.
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.set_collect(true);
        h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(320)).unwrap();
        let a = h.run().unwrap();
        assert_eq!(a.outputs.len(), 320);
        let first = a.outputs.clone();
        h.recycle_outputs(a.outputs);
        h.load_program(&PatternProgram::cyclic(0, 32).with_outputs(320)).unwrap();
        let b = h.run().unwrap();
        assert_eq!(first, b.outputs, "recycling must not change the stream");
    }
}

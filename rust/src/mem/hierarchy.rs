//! The composed memory hierarchy and its per-cycle step function (Fig 2).
//!
//! See the module docs of [`crate::mem`] for the timing semantics. The
//! step order within one internal clock cycle is:
//!
//! 1. input-buffer synchronizer shift (CDC, Fig 3);
//! 2. OSR shift-out (emits an output if enough valid bits are present);
//! 3. write/read enable computation from registered (previous-cycle)
//!    state, including the write-enable toggle and port arbitration;
//! 4. write commits (each consumes the upstream out-register / buffer);
//! 5. read commits (each loads the level's out-register, or feeds the
//!    OSR / accelerator at the last level).
//!
//! External clock edges step the off-chip interface and the input-buffer
//! fill logic. Both domains are interleaved by [`crate::sim::ClockPair`].

use super::input_buffer::InputBuffer;
use super::level::{Level, Slot};
use super::mcu::McuProgram;
use super::offchip::{payload_for, OffChipMemory};
use super::osr::Osr;
use crate::config::HierarchyConfig;
use crate::pattern::PatternProgram;
use crate::sim::{ClockDomain, ClockPair, SimStats, Waveform, WaveformProbe};
use crate::util::bitword::Word;
use crate::{Error, Result};

/// One word delivered to the accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct OutputWord {
    /// Source off-chip addresses (LSB-first sub-words).
    pub addrs: Vec<u64>,
    /// Payload bits.
    pub word: Word,
}

/// Result of a simulation run.
#[derive(Debug)]
pub struct RunResult {
    /// Counters for the (post-preload) run.
    pub stats: SimStats,
    /// Internal cycles spent in the preload phase (0 if preload disabled).
    pub preload_cycles: u64,
    /// Collected outputs (only if collection was enabled).
    pub outputs: Vec<OutputWord>,
}

/// Progress guard: a run with no output progress for this many internal
/// cycles is declared deadlocked (a scheduling bug, not a configuration
/// property — valid configurations always make progress).
const DEADLOCK_LIMIT: u64 = 200_000;

/// The composed, simulatable memory hierarchy.
pub struct Hierarchy {
    cfg: HierarchyConfig,
    prog: Option<McuProgram>,
    start_address: u64,
    stride: u64,
    levels: Vec<Level>,
    ib: Option<InputBuffer>,
    offchip: OffChipMemory,
    osr: Option<Osr>,
    clocks: ClockPair,
    stats: SimStats,
    output_enabled: bool,
    /// Off-chip units emitted so far.
    units_out: u64,
    /// Expected-output verifier state (unit stream cursor).
    verify: bool,
    verify_state: VerifyState,
    collect: bool,
    collected: Vec<OutputWord>,
    /// Optional waveform capture (Fig 4 style): per-level write/read
    /// strobes and the output-valid signal.
    wave: Option<(Waveform, Vec<WaveformProbe>, Vec<WaveformProbe>, WaveformProbe)>,
    /// Hot-loop scratch (no allocation per cycle): enable flags and the
    /// output-address staging buffer.
    ww: [bool; crate::config::MAX_LEVELS],
    dr: [bool; crate::config::MAX_LEVELS],
    addr_buf: Vec<u64>,
}

/// Incremental expected-unit-stream generator (shifted-cyclic in off-chip
/// units), mirroring `AccessPattern::stream` without allocation.
#[derive(Debug, Clone)]
struct VerifyState {
    l: u64,
    s: u64,
    k: u64,
    ptr: u64,
    offset: u64,
    skips: u64,
}

impl VerifyState {
    fn next_unit(&mut self) -> u64 {
        let u = self.offset + self.ptr;
        self.ptr += 1;
        if self.ptr == self.l {
            self.ptr = 0;
            self.skips += 1;
            if self.skips > self.k {
                self.skips = 0;
                self.offset += self.s;
            }
        }
        u
    }
}

impl Hierarchy {
    /// Build an idle hierarchy for `cfg`.
    pub fn new(cfg: &HierarchyConfig) -> Result<Self> {
        cfg.validate()?;
        if cfg.levels[0].word_width < cfg.offchip.data_width {
            return Err(Error::Config(format!(
                "level-0 word width {} below off-chip width {} is not supported \
                 (the input buffer packs, it does not split)",
                cfg.levels[0].word_width, cfg.offchip.data_width
            )));
        }
        Ok(Self {
            cfg: cfg.clone(),
            prog: None,
            start_address: 0,
            stride: 1,
            levels: Vec::new(),
            ib: None,
            offchip: OffChipMemory::new(
                cfg.offchip.data_width,
                cfg.offchip.latency,
                cfg.offchip.addr_width,
            ),
            osr: None,
            clocks: ClockPair::from_freqs(cfg.offchip.external_hz, cfg.offchip.internal_hz),
            stats: SimStats::new(cfg.levels.len()),
            output_enabled: true,
            units_out: 0,
            verify: true,
            verify_state: VerifyState { l: 1, s: 1, k: 0, ptr: 0, offset: 0, skips: 0 },
            collect: false,
            collected: Vec::new(),
            wave: None,
            ww: [false; crate::config::MAX_LEVELS],
            dr: [false; crate::config::MAX_LEVELS],
            addr_buf: Vec::with_capacity(16),
        })
    }

    /// Attach a waveform recorder capturing per-level write/read strobes
    /// and the output-valid signal each internal cycle (Fig 4).
    pub fn attach_waveform(&mut self) {
        let mut wf = Waveform::new();
        let n = self.cfg.levels.len();
        let writes: Vec<_> = (0..n).map(|i| wf.probe(&format!("L{i}_write"), 1)).collect();
        let reads: Vec<_> = (0..n).map(|i| wf.probe(&format!("L{i}_read"), 1)).collect();
        let out = wf.probe("output_valid", 1);
        self.wave = Some((wf, writes, reads, out));
    }

    /// Take the recorded waveform (if any).
    pub fn take_waveform(&mut self) -> Option<Waveform> {
        self.wave.take().map(|(w, ..)| w)
    }

    /// Load a pattern program (a reset cycle in the RTL): compiles the
    /// program, resets all state, and arms the fetch plan.
    pub fn load_program(&mut self, prog: &PatternProgram) -> Result<()> {
        let compiled = McuProgram::compile(&self.cfg, prog)?;
        // OSR alignment: emissions must tile the total output units.
        if let Some(osr_cfg) = &self.cfg.osr {
            let w_off = self.cfg.offchip.data_width;
            for &s in &osr_cfg.shifts {
                if s % w_off != 0 {
                    return Err(Error::Config(format!(
                        "OSR shift {s} not a multiple of off-chip width {w_off}"
                    )));
                }
            }
        }
        self.levels = self
            .cfg
            .levels
            .iter()
            .zip(compiled.levels.iter())
            .map(|(lc, lu)| Level::new(lc.clone(), *lu))
            .collect();
        self.ib = Some(InputBuffer::new(
            self.cfg.levels[0].word_width,
            self.cfg.offchip.data_width,
            self.cfg.offchip.ib_depth,
            &compiled.plan,
        ));
        self.osr = match &self.cfg.osr {
            None => None,
            Some(o) => Some(Osr::new(
                o.width,
                self.cfg.offchip.data_width,
                o.shifts.clone(),
                1,
            )?),
        };
        self.offchip = OffChipMemory::new(
            self.cfg.offchip.data_width,
            self.cfg.offchip.latency,
            self.cfg.offchip.addr_width,
        );
        self.clocks = ClockPair::from_freqs(self.cfg.offchip.external_hz, self.cfg.offchip.internal_hz);
        self.stats = SimStats::new(self.cfg.levels.len());
        self.units_out = 0;
        self.start_address = prog.start_address;
        self.stride = prog.stride;
        self.verify_state = VerifyState {
            l: prog.output.cycle_length,
            s: prog.output.inter_cycle_shift,
            k: prog.output.skip_shift,
            ptr: 0,
            offset: 0,
            skips: 0,
        };
        self.output_enabled = true;
        self.collected.clear();
        self.prog = Some(compiled);
        Ok(())
    }

    /// Enable/disable end-to-end data verification (on by default; turn
    /// off for performance measurements).
    pub fn set_verify(&mut self, on: bool) {
        self.verify = on;
    }

    /// Enable output collection (off by default).
    pub fn set_collect(&mut self, on: bool) {
        self.collect = on;
    }

    /// Select the OSR shift at runtime.
    pub fn select_osr_shift(&mut self, sel: usize) -> Result<()> {
        match &mut self.osr {
            Some(o) => o.select_shift(sel),
            None => Err(Error::Config("no OSR configured".into())),
        }
    }

    /// The `disable_output_i` port (Table 1).
    pub fn set_output_enabled(&mut self, on: bool) {
        self.output_enabled = on;
    }

    /// Total off-chip units the loaded program will emit.
    pub fn total_units(&self) -> u64 {
        self.prog.as_ref().map(|p| p.total_output_units).unwrap_or(0)
    }

    /// Whether all programmed outputs have been emitted.
    pub fn outputs_complete(&self) -> bool {
        self.units_out >= self.total_units()
    }

    /// Run until all outputs are produced. If preload is configured, first
    /// runs a fill phase with outputs disabled (not counted in
    /// `stats.internal_cycles`).
    pub fn run(&mut self) -> Result<RunResult> {
        if self.prog.is_none() {
            return Err(Error::Pattern("no program loaded".into()));
        }
        let mut preload_cycles = 0;
        if self.cfg.preload {
            preload_cycles = self.run_preload()?;
        }
        let mut last_progress_cycle = self.stats.internal_cycles;
        let mut last_units = self.units_out;
        while !self.outputs_complete() {
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.step_external(edge.cycle),
                ClockDomain::Internal => {
                    self.step_internal()?;
                    if self.units_out > last_units {
                        last_units = self.units_out;
                        last_progress_cycle = self.stats.internal_cycles;
                    } else if self.stats.internal_cycles - last_progress_cycle > DEADLOCK_LIMIT {
                        return Err(Error::Integrity {
                            cycle: self.stats.internal_cycles,
                            msg: format!(
                                "no output progress for {DEADLOCK_LIMIT} cycles \
                                 ({}/{} units emitted)",
                                self.units_out,
                                self.total_units()
                            ),
                        });
                    }
                }
            }
        }
        self.stats.offchip_reads = self.offchip.reads;
        if let Some(ib) = &self.ib {
            self.stats.cdc_transfers = ib.transfers;
        }
        if let Some(osr) = &self.osr {
            self.stats.osr_shifts = osr.shifts_executed;
        }
        Ok(RunResult {
            stats: self.stats.clone(),
            preload_cycles,
            outputs: std::mem::take(&mut self.collected),
        })
    }

    /// Convenience: run and return stats, asserting `n` outputs were
    /// produced (off-chip units).
    pub fn run_to_outputs(&mut self, n: u64) -> SimStats {
        assert_eq!(self.total_units(), n, "program must be sized for {n} units");
        self.run().expect("simulation error").stats
    }

    /// Preload phase: outputs disabled, run until the hierarchy saturates
    /// (no write commits for a full handshake round-trip).
    fn run_preload(&mut self) -> Result<u64> {
        self.output_enabled = false;
        let mut idle_internal = 0u64;
        let mut cycles = 0u64;
        let saved_internal = self.stats.internal_cycles;
        while idle_internal < 8 {
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.step_external(edge.cycle),
                ClockDomain::Internal => {
                    let wrote = self.step_internal_counting()?;
                    cycles += 1;
                    if wrote {
                        idle_internal = 0;
                    } else {
                        idle_internal += 1;
                    }
                    if cycles > DEADLOCK_LIMIT {
                        return Err(Error::Integrity {
                            cycle: cycles,
                            msg: "preload did not saturate".into(),
                        });
                    }
                }
            }
        }
        // Preload cycles are not part of the measured run (§5.2.1: idle
        // time between layers is used for preloading).
        self.stats.internal_cycles = saved_internal;
        self.stats.external_cycles = 0;
        self.output_enabled = true;
        Ok(cycles)
    }

    fn step_internal_counting(&mut self) -> Result<bool> {
        let writes_before: u64 = self.levels.iter().map(|l| l.writes_done).sum();
        self.step_internal()?;
        let writes_after: u64 = self.levels.iter().map(|l| l.writes_done).sum();
        Ok(writes_after > writes_before)
    }

    /// One external clock edge.
    fn step_external(&mut self, ext_cycle: u64) {
        self.stats.external_cycles += 1;
        let Some(prog) = &self.prog else { return };
        if let Some(ib) = &mut self.ib {
            ib.step_external(&prog.plan, &mut self.offchip, ext_cycle);
        }
    }

    /// One internal clock edge.
    fn step_internal(&mut self) -> Result<()> {
        let cycle = self.stats.internal_cycles;
        self.stats.internal_cycles += 1;
        let n = self.levels.len();

        // 1. CDC synchronizer shift.
        if let Some(ib) = &mut self.ib {
            ib.step_sync();
        }

        // 2. OSR shift-out.
        let mut emitted_this_cycle = false;
        if self.output_enabled && !self.outputs_complete() {
            if let Some(osr) = &mut self.osr {
                let mut buf = std::mem::take(&mut self.addr_buf);
                buf.clear();
                let word = osr.step_into(&mut buf);
                self.addr_buf = buf;
                if let Some(word) = word {
                    emitted_this_cycle = true;
                    self.handle_output_buf(word, cycle)?;
                }
            }
        }

        // 3a. Write enables from registered state.
        let mut want_write = self.ww;
        want_write[..n].fill(false);
        for l in 0..n {
            let avail = if l == 0 {
                self.ib.as_ref().is_some_and(|ib| ib.word_available())
            } else {
                self.levels[l - 1].out_reg.is_some()
            };
            let lv = &self.levels[l];
            // The write-enable toggle models "a write needs an active read
            // in the preceding level" (§4.1.4) — it applies to
            // level-to-level transfers. Level 0 is fed by the input
            // buffer's handshake instead, which provides its own pacing.
            let toggle_ok = l == 0 || lv.write_allowed_by_toggle();
            want_write[l] = !lv.writes_complete() && toggle_ok && avail && lv.write_slot_free();
            if !lv.writes_complete() && avail && (!toggle_ok || !lv.write_slot_free()) {
                self.stats.write_waits[l] += 1;
            }
        }

        // 3b. Read enables + port arbitration.
        let mut do_read = self.dr;
        do_read[..n].fill(false);
        for l in 0..n {
            let lv = &self.levels[l];
            if lv.reads_complete() || !lv.read_data_ready() {
                continue;
            }
            let is_last = l == n - 1;
            let consumer_ready = if is_last {
                self.output_enabled
                    && match (&self.osr, self.outputs_complete()) {
                        (_, true) => false,
                        (Some(osr), _) => osr.can_accept(lv.cfg.word_width),
                        (None, _) => true,
                    }
            } else {
                lv.out_reg.is_none() || want_write[l + 1]
            };
            if !consumer_ready {
                continue;
            }
            if lv.read_port_free(want_write[l]) {
                do_read[l] = true;
            } else {
                self.stats.write_over_read_stalls[l] += 1;
            }
        }

        // 4. Commit writes (consume upstream out-registers / buffer).
        for l in 0..n {
            if want_write[l] {
                let incoming: Slot = if l == 0 {
                    let ib = self.ib.as_mut().expect("ib exists");
                    let (tag, word) = ib.consume();
                    Slot { tag, word }
                } else {
                    self.levels[l - 1].out_reg.take().expect("availability checked")
                };
                self.levels[l].commit_write(incoming).map_err(|e| at_cycle(e, cycle))?;
                self.stats.level_writes[l] += 1;
            } else {
                self.levels[l].no_write_this_cycle();
            }
        }

        // 5. Commit reads.
        for l in 0..n {
            if !do_read[l] {
                continue;
            }
            let is_last = l == n - 1;
            let slot = self.levels[l].commit_read(cycle)?;
            self.stats.level_reads[l] += 1;
            if is_last {
                self.levels[l].out_reg = None;
                let prog = self.prog.as_ref().expect("program loaded");
                let pack = prog.plan.pack();
                let mut buf = std::mem::take(&mut self.addr_buf);
                buf.clear();
                for j in 0..pack {
                    buf.push(prog.plan.addr_of(slot.tag, j));
                }
                self.addr_buf = buf;
                match &mut self.osr {
                    Some(osr) => osr.push_word(&slot.word, &self.addr_buf),
                    None => {
                        emitted_this_cycle = true;
                        self.handle_output_buf(slot.word, cycle)?;
                    }
                }
            }
        }

        if self.output_enabled && !emitted_this_cycle && !self.outputs_complete() {
            self.stats.output_stalls += 1;
        }

        if let Some((wf, writes, reads, out)) = &mut self.wave {
            for l in 0..n {
                wf.record(writes[l], cycle, u64::from(want_write[l]));
                wf.record(reads[l], cycle, u64::from(do_read[l]));
            }
            wf.record(*out, cycle, u64::from(emitted_this_cycle));
        }
        Ok(())
    }

    /// Record an emitted output word whose source addresses are staged in
    /// `self.addr_buf`; verify against the expected pattern stream and
    /// payload function. Allocation-free unless collection is enabled.
    fn handle_output_buf(&mut self, word: Word, cycle: u64) -> Result<()> {
        let addrs = std::mem::take(&mut self.addr_buf);
        let r = self.handle_output(&addrs, word, cycle);
        self.addr_buf = addrs;
        r
    }

    /// Record an emitted output word; verify against the expected pattern
    /// stream and payload function.
    fn handle_output(&mut self, addrs: &[u64], word: Word, cycle: u64) -> Result<()> {
        let w_off = self.cfg.offchip.data_width;
        if self.verify {
            for (j, &addr) in addrs.iter().enumerate() {
                let unit = self.verify_state.next_unit();
                let expect_addr = self.start_address + unit * self.stride;
                if addr != expect_addr {
                    return Err(Error::Integrity {
                        cycle,
                        msg: format!(
                            "output unit {} address {addr:#x} != expected {expect_addr:#x}",
                            self.units_out + j as u64
                        ),
                    });
                }
                let expect_payload = payload_for(addr, w_off);
                if word.bits(j as u32 * w_off, w_off) != expect_payload {
                    return Err(Error::Integrity {
                        cycle,
                        msg: format!("payload corruption at address {addr:#x}"),
                    });
                }
            }
        }
        self.units_out += addrs.len() as u64;
        self.stats.outputs += 1;
        if self.stats.first_output_cycle.is_none() {
            self.stats.first_output_cycle = Some(cycle);
        }
        if self.collect {
            self.collected.push(OutputWord { addrs: addrs.to_vec(), word });
        }
        Ok(())
    }

    /// Fault injection (verification testing): flip the given bit of the
    /// word stored in `level`/`slot`. Returns false if the slot is empty.
    /// A subsequent run must fail with an integrity error — this is how
    /// the end-to-end data-path checking is itself validated.
    pub fn inject_bit_flip(&mut self, level: usize, slot: u64, bit: u32) -> bool {
        let Some(lv) = self.levels.get_mut(level) else { return false };
        lv.corrupt_slot(slot, bit)
    }

    /// Run exactly `n` internal cycles (micro-stepping for tests and
    /// waveform capture); external edges are interleaved per the clock
    /// ratio. Returns the outputs emitted so far.
    pub fn step_cycles(&mut self, n: u64) -> Result<u64> {
        let target = self.stats.internal_cycles + n;
        while self.stats.internal_cycles < target && !self.outputs_complete() {
            let edge = self.clocks.next_edge();
            match edge.domain {
                ClockDomain::External => self.step_external(edge.cycle),
                ClockDomain::Internal => self.step_internal()?,
            }
        }
        Ok(self.units_out)
    }

    /// Access the accumulated stats (e.g. mid-run).
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// The active configuration.
    pub fn config(&self) -> &HierarchyConfig {
        &self.cfg
    }
}

fn at_cycle(e: Error, cycle: u64) -> Error {
    match e {
        Error::Integrity { msg, .. } => Error::Integrity { cycle, msg },
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchyConfig;
    use crate::pattern::PatternProgram;

    fn cfg(d0: u64, d1: u64, l0_ports: u32, preload: bool) -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, d0, 1, l0_ports)
            .level(32, d1, 1, 2)
            .preload(preload)
            .build()
            .unwrap()
    }

    #[test]
    fn cyclic_small_window_streams_at_one_per_cycle() {
        // Window fits the last level: steady state is one output per cycle.
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 5_000);
        // Fill phase: 64 words at ~3 cycles each, then 1/cycle.
        let cycles = r.stats.internal_cycles;
        assert!(cycles >= 5_000, "cannot beat one per cycle, got {cycles}");
        assert!(cycles < 5_000 + 3 * 64 + 50, "fill overhead too high: {cycles}");
        assert!(r.stats.steady_state_efficiency() > 0.95);
    }

    #[test]
    fn cyclic_large_window_doubles_runtime() {
        // Window exceeds the last level but fits level 0: round-robin
        // replacement halves throughput (§5.2.1, Fig 5).
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 512).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        let eff = r.stats.efficiency();
        assert!(
            (0.42..0.55).contains(&eff),
            "expected ~0.5 outputs/cycle (doubled runtime), got {eff}"
        );
    }

    #[test]
    fn no_resident_level_triples_runtime() {
        // Window fits nowhere: every word re-fetched off-chip at the
        // 3-cycle handshake cadence.
        let c = cfg(64, 16, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 256).with_outputs(2_048)).unwrap();
        let r = h.run().unwrap();
        let eff = r.stats.efficiency();
        assert!(
            (0.30..0.37).contains(&eff),
            "expected ~1/3 outputs/cycle (off-chip bound), got {eff}"
        );
        // Every unit fetched once per use.
        assert_eq!(r.stats.offchip_reads, 2_048);
    }

    #[test]
    fn preload_removes_fill_phase() {
        let c = cfg(1024, 128, 1, true);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        assert!(r.preload_cycles > 0);
        assert!(
            r.stats.internal_cycles <= 5_010,
            "preloaded run should be ~1/cycle, got {}",
            r.stats.internal_cycles
        );
    }

    #[test]
    fn shifted_cyclic_verified_end_to_end() {
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.set_collect(true);
        h.load_program(&PatternProgram::shifted_cyclic(1000, 32, 8).with_outputs(512)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.outputs.len(), 512);
        // Spot-check the pattern: first window 1000..1032, second 1008..1040.
        assert_eq!(r.outputs[0].addrs, vec![1000]);
        assert_eq!(r.outputs[31].addrs, vec![1031]);
        assert_eq!(r.outputs[32].addrs, vec![1008]);
    }

    #[test]
    fn sequential_pattern_runs_at_one_third() {
        // No reuse: every output crosses the CDC handshake (3 cycles).
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::sequential(0, 1_000)).unwrap();
        let r = h.run().unwrap();
        let eff = r.stats.efficiency();
        assert!((0.30..0.37).contains(&eff), "sequential ~1/3 per cycle, got {eff}");
    }

    #[test]
    fn packing_with_osr_sustains_full_rate() {
        // Fig 6: 128-bit levels + OSR emitting 32-bit words.
        let c = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(256, vec![32])
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 256).with_outputs(5_000)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 5_000);
        // Window (256 units = 64 level words) exceeds L1 (32) but fits L0:
        // the wide word moves 4 units per write, so the stream sustains
        // one 32-bit output per cycle even while replacing round-robin.
        let eff = r.stats.efficiency();
        assert!(eff > 0.9, "wide words must hide replacement, got {eff}");
    }

    #[test]
    fn dual_ported_l0_matches_single_at_worst_case() {
        // At shift == cycle length both configs bottom out at 1/3 (§5.2.3).
        for ports in [1, 2] {
            let c = cfg(512, 128, ports, false);
            let mut h = Hierarchy::new(&c).unwrap();
            h.load_program(&PatternProgram::shifted_cyclic(0, 64, 64).with_outputs(4_096)).unwrap();
            let r = h.run().unwrap();
            let eff = r.stats.efficiency();
            assert!(
                (0.30..0.37).contains(&eff),
                "ports={ports}: worst case ~1/3, got {eff}"
            );
        }
    }

    #[test]
    fn small_shift_keeps_full_throughput() {
        // Shift below one third of the cycle length: refills hide behind
        // the reuse window (§5.2.3).
        let c = cfg(512, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::shifted_cyclic(0, 96, 16).with_outputs(4_800)).unwrap();
        let r = h.run().unwrap();
        assert!(
            r.stats.steady_state_efficiency() > 0.95,
            "s=l/6 should sustain full rate, got {}",
            r.stats.steady_state_efficiency()
        );
    }

    #[test]
    fn case_study_clock_ratio_weight_loads() {
        // §5.3.2: 32-bit off-chip at 4x the accelerator clock; 128-bit
        // level words take 3 accelerator cycles each.
        let c = HierarchyConfig::builder()
            .offchip(32, 24, 4.0)
            .level(128, 104, 1, 2)
            .osr(384, vec![384])
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&c).unwrap();
        // Sequential weights: 96 units = 24 level words = 8 OSR fills.
        h.load_program(&PatternProgram::sequential(0, 96)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 8, "eight 384-bit weight ports");
        let cyc = r.stats.internal_cycles;
        // 24 level words at ~3 cycles each ≈ 72 cycles (+pipeline slack).
        assert!((70..95).contains(&cyc), "expected ≈3 cycles/word, got {cyc}");
    }

    #[test]
    fn single_level_hierarchy_works() {
        let c = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 256, 1, 2)
            .build()
            .unwrap();
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::cyclic(0, 64).with_outputs(4_096)).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.stats.outputs, 4_096);
        // steady_state_efficiency only excludes cycles before the *first*
        // output; the 3-cycle-per-word fill tail still dilutes it.
        assert!(r.stats.steady_state_efficiency() > 0.93);
    }

    #[test]
    fn run_without_program_errors() {
        let c = cfg(64, 16, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        assert!(h.run().is_err());
    }

    #[test]
    fn offchip_reads_match_unique_for_resident_patterns() {
        let c = cfg(1024, 128, 1, false);
        let mut h = Hierarchy::new(&c).unwrap();
        h.load_program(&PatternProgram::shifted_cyclic(0, 64, 8).with_outputs(640)).unwrap();
        let r = h.run().unwrap();
        // 640 outputs = 10 cycles: window 64 + 9 shifts x 8 = 136 uniques.
        assert_eq!(r.stats.offchip_reads, 136);
        assert_eq!(r.stats.outputs, 640);
    }
}

//! The input buffer and its clock-domain-crossing handshake (§4.1.1,
//! Figure 3).
//!
//! The buffer is a register file with the word width of hierarchy level 0,
//! clocked by the external (µC) clock. It fills by requesting off-chip
//! words in fetch-plan order and concatenating them LSB-first. A completed
//! word raises `buffer_full`; the signal crosses into the accelerator
//! domain through a two-flop synchronizer ("holding the signal for at
//! least an entire cycle", §4.1.3). After the MCU writes the word into
//! level 0, `reset_buffer` crosses back at the next external edge and the
//! fill restarts.
//!
//! With the paper's single-entry buffer (`depth = 1`, the default) and
//! equal clocks the steady-state cadence is one level-0 word every
//! **three internal cycles** (sync → write → reset/refill) — the constant
//! behind the ⅓-cycle-length knee and the three-cycle worst case of
//! Fig 8, and §5.3.2's "three accelerator clock cycles ... to request and
//! store a 128-bit weight".
//!
//! `depth > 1` models the natural FIFO extension (gray-code pointer
//! synchronizer): the fill engine keeps receiving while earlier words
//! await consumption — "the input buffer prevents potential blocking of
//! the off-chip memory during data storage in the hierarchy" (§4.1.1).
//! Once the FIFO is warm, the cadence approaches the raw off-chip
//! bandwidth; the UltraTrail case study (4× faster external clock) uses
//! this to stream weights at ≈1 level word per accelerator cycle.

use super::mcu::{FetchCursor, FetchPlan};
use super::offchip::OffChipMemory;
use crate::sim::engine::Stage;
use crate::sim::fault::FaultSite;
use crate::util::bitword::Word;
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};
use std::collections::VecDeque;

/// The input buffer's external-domain quiescence horizon (see
/// [`InputBuffer::fill_horizon`]): what the fill engine will do at
/// upcoming external edges, given its current inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FillHorizon {
    /// The next external edge changes state (reset landing, request
    /// issue): no skipping.
    Busy,
    /// External edges are no-ops until the external cycle at which the
    /// oldest in-flight off-chip word becomes deliverable.
    Delivery(u64),
    /// No external edge will change the buffer until the internal domain
    /// consumes from it (or ever, if the fetch plan is exhausted).
    Idle,
}

/// Captured run state of the [`InputBuffer`] at a cycle boundary: the
/// FIFO contents, the fill register under construction, both synchronizer
/// flops, the fetch cursor, and the in-flight request count. The static
/// geometry (widths, depth) is re-derived by `rearm` and not captured; a
/// checkpoint is only valid on a buffer re-armed for the same (config,
/// program) pair, checked by [`crate::mem::Hierarchy::restore`].
#[derive(Debug, Clone, PartialEq)]
pub struct InputBufferCheckpoint {
    queue: VecDeque<(u64, Word)>,
    reg: Word,
    filled: u64,
    reg_tag: u64,
    resetting: bool,
    full_meta: bool,
    full_synced: bool,
    cursor: FetchCursor,
    outstanding: u64,
    transfers: u64,
}

impl InputBufferCheckpoint {
    /// Serialize for the checkpoint wire format (destructured so a newly
    /// added register must be encoded here explicitly).
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self {
            queue,
            reg,
            filled,
            reg_tag,
            resetting,
            full_meta,
            full_synced,
            cursor,
            outstanding,
            transfers,
        } = self;
        w.put_u32(queue.len() as u32);
        for (tag, word) in queue {
            w.put_u64(*tag);
            word.wire_write(w);
        }
        reg.wire_write(w);
        w.put_u64(*filled);
        w.put_u64(*reg_tag);
        w.put_bool(*resetting);
        w.put_bool(*full_meta);
        w.put_bool(*full_synced);
        cursor.wire_write(w);
        w.put_u64(*outstanding);
        w.put_u64(*transfers);
    }

    /// Checked decode. `width` is the configured level-0 word width and
    /// `pack` the off-chip words per level word — the fill register must
    /// be exactly `width` bits with `filled < pack`, and every queued
    /// word must be `width` bits (invariants of every legitimately
    /// captured checkpoint), so corrupt bytes fail here instead of
    /// tripping bit-slice assertions mid-simulation.
    pub(crate) fn wire_read(r: &mut ByteReader<'_>, width: u32, pack: u64) -> Result<Self> {
        let n = r.get_count(12)?;
        let mut queue = VecDeque::with_capacity(n);
        for _ in 0..n {
            let tag = r.get_u64()?;
            let word = Word::wire_read(r)?;
            if word.width() != width {
                return Err(Error::Parse(format!(
                    "wire: input-buffer queue word is {} bits, expected {width}",
                    word.width()
                )));
            }
            queue.push_back((tag, word));
        }
        let ck = Self {
            queue,
            reg: Word::wire_read(r)?,
            filled: r.get_u64()?,
            reg_tag: r.get_u64()?,
            resetting: r.get_bool()?,
            full_meta: r.get_bool()?,
            full_synced: r.get_bool()?,
            cursor: FetchCursor::wire_read(r)?,
            outstanding: r.get_u64()?,
            transfers: r.get_u64()?,
        };
        if ck.reg.width() != width {
            return Err(Error::Parse(format!(
                "wire: input-buffer fill register is {} bits, expected {width}",
                ck.reg.width()
            )));
        }
        if ck.filled >= pack.max(1) {
            return Err(Error::Parse(format!(
                "wire: input-buffer fill count {} out of range (pack {pack})",
                ck.filled
            )));
        }
        Ok(ck)
    }
}

/// The input buffer with CDC handshake state.
#[derive(Debug)]
pub struct InputBuffer {
    width: u32,
    sub_width: u32,
    pack: u64,
    depth: usize,
    /// Completed level words awaiting transfer (front = oldest).
    queue: VecDeque<(u64, Word)>,
    /// Fill register under construction.
    reg: Word,
    filled: u64,
    reg_tag: u64,
    /// `reset_buffer` in flight: fill may not restart until the next
    /// external edge (depth-1 handshake only).
    resetting: bool,
    /// Two-stage synchronizer for `buffer_full` (= queue non-empty).
    full_meta: bool,
    full_synced: bool,
    /// Fetch cursor (what to request next).
    cursor: FetchCursor,
    /// Requests issued but data not yet latched.
    outstanding: u64,
    /// Total level words delivered across the CDC.
    pub transfers: u64,
}

impl InputBuffer {
    /// New buffer for a level-0 word of `width` bits built from
    /// `sub_width`-bit off-chip words, walking `plan`. `depth` is the
    /// number of buffer entries (1 = the paper's single register file).
    pub fn new(width: u32, sub_width: u32, depth: u32, plan: &FetchPlan) -> Self {
        assert_eq!(width % sub_width, 0, "validated by config");
        assert!(depth >= 1);
        Self {
            width,
            sub_width,
            pack: (width / sub_width) as u64,
            depth: depth as usize,
            queue: VecDeque::with_capacity(depth as usize),
            reg: Word::zero(width),
            filled: 0,
            reg_tag: 0,
            resetting: false,
            full_meta: false,
            full_synced: false,
            cursor: plan.cursor(),
            outstanding: 0,
            transfers: 0,
        }
    }

    /// In-place re-arm for a new program/configuration: equivalent to
    /// `*self = InputBuffer::new(width, sub_width, depth, plan)` but keeps
    /// the queue allocation (warm-session path).
    pub fn rearm(&mut self, width: u32, sub_width: u32, depth: u32, plan: &FetchPlan) {
        assert_eq!(width % sub_width, 0, "validated by config");
        assert!(depth >= 1);
        self.width = width;
        self.sub_width = sub_width;
        self.pack = (width / sub_width) as u64;
        self.depth = depth as usize;
        self.queue.clear();
        self.reg = Word::zero(width);
        self.filled = 0;
        self.reg_tag = 0;
        self.resetting = false;
        self.full_meta = false;
        self.full_synced = false;
        self.cursor = plan.cursor();
        self.outstanding = 0;
        self.transfers = 0;
    }

    /// External-domain step: issue the next fetch request (one per cycle)
    /// and latch any word the off-chip memory delivers. Returns whether
    /// the edge changed any state (cleared the handshake reset, latched a
    /// word, or issued a request) — `false` edges are exactly the ones
    /// [`Self::fill_horizon`] predicts and the engine may skip.
    pub fn step_external(
        &mut self,
        plan: &FetchPlan,
        mem: &mut OffChipMemory,
        ext_cycle: u64,
    ) -> bool {
        let mut acted = false;
        if self.resetting {
            // `reset_buffer` lands on this edge: the register file may be
            // refilled from now on.
            self.resetting = false;
            acted = true;
        }
        let may_fill = !self.resetting && self.queue.len() < self.depth;
        // Latch delivered data first (pipelined memory).
        if may_fill {
            if let Some((_, word)) = mem.poll(ext_cycle) {
                debug_assert!(self.outstanding > 0);
                self.outstanding -= 1;
                self.reg.set_bits((self.filled as u32) * self.sub_width, &word);
                self.filled += 1;
                if self.filled == self.pack {
                    self.queue.push_back((self.reg_tag, self.reg));
                    self.reg = Word::zero(self.width);
                    self.filled = 0;
                }
                acted = true;
            }
        }
        // Issue the next request if there is room for its data: never run
        // more than one queue entry ahead of the registers we can hold.
        let capacity_units = (self.depth - self.queue.len()) as u64 * self.pack;
        if !self.resetting && self.filled + self.outstanding < capacity_units {
            if let Some((tag, sub, addr)) = self.cursor.peek(plan) {
                if mem.request(addr, ext_cycle) {
                    if sub == 0 {
                        self.reg_tag = tag;
                    }
                    self.cursor.advance(plan);
                    self.outstanding += 1;
                    acted = true;
                }
            }
        }
        acted
    }

    /// Internal-domain synchronizer step: shift `buffer_full` through the
    /// two-flop synchronizer. Call once per internal cycle *before* the MCU
    /// samples [`Self::word_available`].
    pub fn step_sync(&mut self) {
        self.full_synced = self.full_meta;
        self.full_meta = !self.queue.is_empty();
    }

    /// Whether a complete level word is visible to the MCU this cycle.
    pub fn word_available(&self) -> bool {
        self.full_synced && !self.queue.is_empty()
    }

    /// MCU consumes the buffered word (the level-0 write commits this
    /// cycle); with a single-entry buffer this asserts `reset_buffer`
    /// toward the external domain.
    pub fn consume(&mut self) -> (u64, Word) {
        debug_assert!(self.word_available());
        let entry = self.queue.pop_front().expect("word_available checked");
        self.transfers += 1;
        if self.queue.is_empty() {
            // Handshake reset: the fill register may be reused only after
            // the reset crosses back (next external edge). With depth > 1
            // the FIFO pointers are gray-code synchronized instead and no
            // round-trip is needed.
            if self.depth == 1 {
                self.resetting = true;
            }
            self.full_meta = false;
            self.full_synced = false;
        }
        entry
    }

    /// Whether the plan is exhausted and the buffer drained.
    pub fn done(&self, plan: &FetchPlan) -> bool {
        self.cursor.done(plan) && self.queue.is_empty() && self.filled == 0
    }

    /// Whether the two-flop `buffer_full` synchronizer has settled: both
    /// flops agree with the source signal, so the next internal-edge
    /// shift ([`Self::step_sync`]) is a no-op. This is the internal-
    /// domain half of the buffer's quiescence horizon
    /// ([`Stage::quiescent_for`]); the external-domain half is
    /// [`Self::fill_horizon`].
    pub fn sync_settled(&self) -> bool {
        let full = !self.queue.is_empty();
        self.full_meta == full && self.full_synced == full
    }

    /// The fill engine's quiescence horizon over the *external* clock
    /// domain, given its current cursor, occupancy, and the off-chip
    /// pipeline (see [`FillHorizon`]). Mirrors [`Self::step_external`]'s
    /// decision order exactly: the promise is that every external edge
    /// before the reported wake-up executes `step_external` as a no-op.
    pub fn fill_horizon(&self, plan: &FetchPlan, mem: &OffChipMemory) -> FillHorizon {
        if self.resetting {
            // The next external edge lands the handshake reset.
            return FillHorizon::Busy;
        }
        let capacity_units = (self.depth - self.queue.len()) as u64 * self.pack;
        if self.filled + self.outstanding < capacity_units && self.cursor.peek(plan).is_some() {
            // A request will be issued at the next external edge (the
            // memory accepts one request per cycle, and a fresh edge is
            // always a fresh cycle).
            return FillHorizon::Busy;
        }
        if self.queue.len() < self.depth {
            // Cannot issue, but data is in flight: nothing changes until
            // the oldest delivery lands.
            if let Some(t) = mem.next_delivery_at() {
                return FillHorizon::Delivery(t);
            }
        }
        // Nothing in flight the buffer could latch and nothing to issue:
        // external edges are no-ops until the internal domain consumes
        // from the queue (or forever, if the plan is exhausted).
        FillHorizon::Idle
    }

    /// Capture the buffer's run state (see [`InputBufferCheckpoint`]).
    pub fn snapshot(&self) -> InputBufferCheckpoint {
        InputBufferCheckpoint {
            queue: self.queue.clone(),
            reg: self.reg,
            filled: self.filled,
            reg_tag: self.reg_tag,
            resetting: self.resetting,
            full_meta: self.full_meta,
            full_synced: self.full_synced,
            cursor: self.cursor.clone(),
            outstanding: self.outstanding,
            transfers: self.transfers,
        }
    }

    /// Restore an [`InputBufferCheckpoint`] taken on a buffer armed for
    /// the same (config, program) pair. Reuses the queue allocation.
    pub fn restore(&mut self, ck: &InputBufferCheckpoint) {
        self.queue.clone_from(&ck.queue);
        self.reg = ck.reg;
        self.filled = ck.filled;
        self.reg_tag = ck.reg_tag;
        self.resetting = ck.resetting;
        self.full_meta = ck.full_meta;
        self.full_synced = ck.full_synced;
        self.cursor.clone_from(&ck.cursor);
        self.outstanding = ck.outstanding;
        self.transfers = ck.transfers;
    }
}

impl Stage for InputBuffer {
    /// Internal-domain edge: shift `buffer_full` through the two-flop
    /// synchronizer (the CDC crossing of Fig 3).
    fn on_internal_edge(&mut self) {
        self.step_sync();
    }

    /// Handshake: a complete level word is visible to the MCU this cycle.
    fn ready_out(&self) -> bool {
        self.word_available()
    }

    /// Internal-domain horizon: a settled synchronizer shifts the same
    /// values forever (until the external domain changes the queue), an
    /// unsettled one changes a flop on the very next edge. The external-
    /// domain horizon is context-dependent and reported separately by
    /// [`InputBuffer::fill_horizon`].
    fn quiescent_for(&self) -> u64 {
        if self.sync_settled() {
            u64::MAX
        } else {
            0
        }
    }

    /// Injectable state: queued level words ([`FaultSite::FifoEntry`],
    /// entry 0 = oldest), the two CDC synchronizer flops
    /// ([`FaultSite::SyncFlop`], 0 = meta / 1 = synced, always a toggle),
    /// and the fill register under construction ([`FaultSite::FillReg`]).
    fn inject(&mut self, site: &FaultSite) -> bool {
        match *site {
            FaultSite::FifoEntry { entry, bit, kind } => match self.queue.get_mut(entry) {
                Some((_, word)) => kind.perturb(word, bit),
                None => false,
            },
            FaultSite::SyncFlop { which: 0 } => {
                self.full_meta = !self.full_meta;
                true
            }
            FaultSite::SyncFlop { which: 1 } => {
                self.full_synced = !self.full_synced;
                true
            }
            FaultSite::FillReg { bit, kind } => kind.perturb(&mut self.reg, bit),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchyConfig;
    use crate::mem::mcu::McuProgram;
    use crate::mem::offchip::payload_for;

    fn plan(pack_width: u32) -> (FetchPlan, OffChipMemory) {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(pack_width, 64, 1, 1)
            .level(pack_width, 16, 1, 2)
            .build()
            .unwrap();
        let p = crate::pattern::PatternProgram::cyclic(0, 16).with_outputs(64);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        (m.plan, OffChipMemory::new(32, 1, 24))
    }

    #[test]
    fn fill_sync_consume_reset_cadence() {
        let (plan, mut mem) = plan(32);
        let mut ib = InputBuffer::new(32, 32, 1, &plan);
        // ext cycle 0: request addr 0.
        ib.step_external(&plan, &mut mem, 0);
        assert!(!ib.word_available());
        // ext cycle 1: data latched -> queued.
        ib.step_external(&plan, &mut mem, 1);
        // Two internal edges to cross the two-flop synchronizer.
        ib.step_sync();
        assert!(!ib.word_available(), "one sync stage is not enough");
        ib.step_sync();
        assert!(ib.word_available());
        let (tag, w) = ib.consume();
        assert_eq!(tag, 0);
        assert_eq!(w, payload_for(0, 32));
        assert!(!ib.word_available());
        // Next ext edges: reset lands, refill.
        ib.step_external(&plan, &mut mem, 2);
        ib.step_external(&plan, &mut mem, 3);
        ib.step_sync();
        ib.step_sync();
        assert!(ib.word_available());
        let (tag, w) = ib.consume();
        assert_eq!(tag, 1);
        assert_eq!(w, payload_for(1, 32));
        assert_eq!(ib.transfers, 2);
    }

    #[test]
    fn depth1_single_register_blocks_offchip() {
        // §4.1.1 depth-1 semantics: while the word awaits consumption the
        // fill engine cannot run ahead more than the single register.
        let (plan, mut mem) = plan(32);
        let mut ib = InputBuffer::new(32, 32, 1, &plan);
        for ext in 0..10 {
            ib.step_external(&plan, &mut mem, ext);
        }
        // Only one word buffered, one more at most in flight.
        assert!(mem.reads <= 2, "depth-1 must throttle requests, got {}", mem.reads);
    }

    #[test]
    fn deep_fifo_streams_without_reset_roundtrip() {
        let (plan, mut mem) = plan(32);
        let mut ib = InputBuffer::new(32, 32, 4, &plan);
        // Warm up the FIFO.
        for ext in 0..8 {
            ib.step_external(&plan, &mut mem, ext);
            ib.step_sync();
        }
        // Steady state: consume every internal cycle.
        let mut got = Vec::new();
        for ext in 8..16 {
            ib.step_external(&plan, &mut mem, ext);
            ib.step_sync();
            if ib.word_available() {
                got.push(ib.consume().0);
            }
        }
        assert!(got.len() >= 7, "FIFO should sustain ~1 word/cycle, got {}", got.len());
        assert_eq!(got, (got[0]..got[0] + got.len() as u64).collect::<Vec<_>>());
    }

    #[test]
    fn packing_concatenates_lsb_first() {
        let (plan, mut mem) = plan(128);
        let mut ib = InputBuffer::new(128, 32, 1, &plan);
        let mut ext = 0u64;
        while !ib.word_available() {
            ib.step_external(&plan, &mut mem, ext);
            ib.step_sync();
            ext += 1;
            assert!(ext < 20, "packing must complete");
        }
        let (tag, w) = ib.consume();
        assert_eq!(tag, 0);
        for j in 0..4 {
            assert_eq!(
                w.bits(j * 32, 32),
                payload_for(j as u64, 32),
                "sub-word {j} packed at bits {}..{}",
                j * 32,
                (j + 1) * 32
            );
        }
    }

    #[test]
    fn fill_horizon_mirrors_step_external() {
        // Whenever the horizon says the span is dead, the external step
        // must be a no-op — and Busy edges must act.
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .offchip_latency(8)
            .level(32, 64, 1, 1)
            .level(32, 16, 1, 2)
            .build()
            .unwrap();
        let p = crate::pattern::PatternProgram::sequential(0, 4);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        let mut mem = OffChipMemory::new(32, 8, 24);
        let mut ib = InputBuffer::new(32, 32, 1, &m.plan);
        assert!(ib.sync_settled(), "fresh buffer is settled");
        for ext in 0..60u64 {
            let predicted = ib.fill_horizon(&m.plan, &mem);
            let acted = ib.step_external(&m.plan, &mut mem, ext);
            match predicted {
                FillHorizon::Busy => {
                    assert!(acted, "Busy horizon must act at ext cycle {ext}")
                }
                FillHorizon::Delivery(t) => {
                    assert_eq!(acted, t <= ext, "delivery at {t}, edge {ext}");
                }
                FillHorizon::Idle => assert!(!acted, "Idle edge acted at {ext}"),
            }
            ib.step_sync();
            if ib.word_available() {
                ib.consume();
            }
        }
        assert!(ib.done(&m.plan));
        // Exhausted and drained: idle forever.
        assert_eq!(ib.fill_horizon(&m.plan, &mem), FillHorizon::Idle);
    }

    #[test]
    fn sync_settles_after_two_shifts() {
        let (plan, mut mem) = plan(32);
        let mut ib = InputBuffer::new(32, 32, 1, &plan);
        ib.step_external(&plan, &mut mem, 0);
        ib.step_external(&plan, &mut mem, 1); // word queued
        assert!(!ib.sync_settled(), "flops lag the queue");
        ib.step_sync();
        assert!(!ib.sync_settled());
        ib.step_sync();
        assert!(ib.sync_settled(), "two shifts settle the synchronizer");
        ib.step_sync();
        assert!(ib.sync_settled(), "further shifts are no-ops");
    }

    #[test]
    fn plan_exhaustion() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 8, 1, 1)
            .level(32, 4, 1, 2)
            .build()
            .unwrap();
        let p = crate::pattern::PatternProgram::sequential(0, 2);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        let mut mem = OffChipMemory::new(32, 1, 24);
        let mut ib = InputBuffer::new(32, 32, 1, &m.plan);
        for ext in 0..20 {
            ib.step_external(&m.plan, &mut mem, ext);
            ib.step_sync();
            if ib.word_available() {
                ib.consume();
            }
        }
        assert!(ib.done(&m.plan));
        assert_eq!(ib.transfers, 2);
        assert_eq!(mem.reads, 2);
    }
}

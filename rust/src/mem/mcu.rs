//! MCU program compilation: turning a user-facing [`PatternProgram`] into
//! per-level roles, level-unit pattern parameters, and the off-chip fetch
//! plan (`global_read_address_o` sequence).
//!
//! ## Units
//!
//! User programs are expressed in **off-chip word units** (the paper's
//! evaluation counts 32-bit data words). Levels store **level words** of
//! `word_width` bits; the input buffer packs `pack = word_width /
//! offchip.data_width` off-chip words into one level word (§4.1.1). All
//! per-level pattern parameters are therefore scaled by `pack`.
//!
//! ## Roles
//!
//! The deepest level whose capacity holds one full pattern window
//! (`cycle_length` level words) becomes the **resident** level: it stores
//! the window, replays it toward the accelerator, and requests each unique
//! word exactly once from upstream. Every other level acts as a **FIFO**:
//! words stream through in arrival order and each slot is cleared after its
//! read (§4.1.2: "higher levels do not retain subsets of data from lower
//! levels. They instantly clear memory space after the last specified
//! pattern read"). If no level can hold the window, the whole hierarchy
//! streams and the fetch plan replays duplicate addresses from off-chip
//! (§5.3: "data from a single off-chip address must be stored several
//! times").

use crate::config::HierarchyConfig;
use crate::pattern::{LevelProgram, PatternProgram};
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};

/// Role a level plays for the loaded program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Pass-through FIFO: read order = arrival order, clear after read.
    Fifo,
    /// Holds the pattern window and performs the reuse reads (Listing 1).
    Resident,
}

/// Compiled per-level program in level-word units.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelUnits {
    /// Role of this level.
    pub role: Role,
    /// Cycle length in level words (resident levels only).
    pub cycle_length: u64,
    /// Inter-cycle shift in level words.
    pub inter_cycle_shift: u64,
    /// Cycles before a shift is applied.
    pub skip_shift: u64,
    /// Total level words this level will ingest (writes).
    pub total_writes: u64,
    /// Total level-word reads this level will serve.
    pub total_reads: u64,
}

/// The compiled MCU program for a whole hierarchy.
///
/// `PartialEq` compares every compiled parameter (roles, level units,
/// fetch plan, totals): two equal `McuProgram`s under the same
/// configuration drive bit-identical simulations, which is what
/// [`crate::mem::Hierarchy::restore`] keys its program check on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct McuProgram {
    /// Off-chip words per level word.
    pub pack: u64,
    /// Index of the resident level, if any.
    pub resident: Option<usize>,
    /// Per-level compiled units.
    pub levels: Vec<LevelUnits>,
    /// Total output *level words* the last level emits.
    pub total_output_words: u64,
    /// Total *off-chip word units* emitted (outputs × pack... see OSR).
    pub total_output_units: u64,
    /// The expected level-word *tag* stream at the hierarchy output.
    /// Tags index the fetch plan; see [`FetchPlan`].
    pub output_program: LevelProgram,
    /// Number of unique level words fetched from off-chip.
    pub unique_level_words: u64,
    /// The fetch plan (lazily enumerable off-chip address sequence).
    pub plan: FetchPlan,
}

impl McuProgram {
    /// Compile `prog` for `cfg`. Validates unit alignment.
    pub fn compile(cfg: &HierarchyConfig, prog: &PatternProgram) -> Result<Self> {
        prog.validate()?;
        let w_level = cfg.levels[0].word_width as u64;
        let w_off = cfg.offchip.data_width as u64;
        if w_level % w_off != 0 {
            return Err(Error::Pattern(format!(
                "level word width {w_level} not a multiple of off-chip width {w_off}"
            )));
        }
        let pack = w_level / w_off;
        let op = prog.output;
        for (name, v) in [
            ("cycle_length", op.cycle_length),
            ("inter_cycle_shift", op.inter_cycle_shift),
            ("total_outputs", prog.total_outputs),
        ] {
            if v % pack != 0 {
                return Err(Error::Pattern(format!(
                    "{name} = {v} must be a multiple of the packing factor {pack}"
                )));
            }
        }
        if prog.total_outputs == 0 {
            return Err(Error::Pattern("total_outputs must be > 0".into()));
        }
        let l = op.cycle_length / pack;
        let s = op.inter_cycle_shift / pack;
        let k = op.skip_shift;
        let total_output_words = prog.total_outputs / pack;

        // Resident level: deepest *residency-capable* level whose capacity
        // holds the window. A pure sequential program (s == l) has no
        // reuse, so residency buys nothing and every level streams.
        // Double-buffered levels clear slots as they drain and can never
        // replay a window, so the scan skips them (they still stream the
        // resident level's output, or the full pattern, as FIFOs).
        let has_reuse = s < l;
        let resident = if has_reuse {
            cfg.levels
                .iter()
                .enumerate()
                .rev()
                .find(|(_, lv)| lv.kind.can_hold_resident_window() && lv.capacity_words() >= l)
                .map(|(i, _)| i)
        } else {
            None
        };

        // Tag stream the last level must emit = the pattern in level units
        // with tags starting at 0.
        let output_program = LevelProgram { cycle_length: l, inter_cycle_shift: s, skip_shift: k };

        // Unique level words = highest tag touched + 1 (windows are
        // contiguous in tag space), honoring the truncated final cycle.
        let unique_level_words = unique_words(l, s, k, total_output_words);

        let mut levels = Vec::with_capacity(cfg.levels.len());
        for (i, _lv) in cfg.levels.iter().enumerate() {
            let (role, total_writes, total_reads) = match resident {
                Some(r) if i == r => (Role::Resident, unique_level_words, total_output_words),
                Some(r) if i < r => (Role::Fifo, unique_level_words, unique_level_words),
                // Below the resident level (or no residency): the full
                // output stream passes through.
                _ => (Role::Fifo, total_output_words, total_output_words),
            };
            levels.push(LevelUnits {
                role,
                cycle_length: l,
                inter_cycle_shift: s,
                skip_shift: k,
                total_writes,
                total_reads,
            });
        }

        let plan = FetchPlan {
            start: prog.start_address,
            stride: prog.stride,
            pack,
            mode: if resident.is_some() {
                PlanMode::Unique
            } else {
                PlanMode::FullPattern
            },
            l,
            s,
            k,
            total_level_words: if resident.is_some() {
                unique_level_words
            } else {
                total_output_words
            },
        };

        Ok(Self {
            pack,
            resident,
            levels,
            total_output_words,
            total_output_units: prog.total_outputs,
            output_program,
            unique_level_words,
            plan,
        })
    }
}

/// Count unique level-word tags touched by the (possibly truncated)
/// shifted-cyclic stream.
fn unique_words(l: u64, s: u64, k: u64, total: u64) -> u64 {
    if total == 0 {
        return 0;
    }
    let full_cycles = total / l;
    let rem = total % l;
    // Offset after `c` completed cycles: floor(c / (k+1)) * s.
    let offset_after = |c: u64| (c / (k + 1)) * s.min(l);
    let mut max_tag = 0u64;
    if full_cycles > 0 {
        // Last full cycle reaches offset_after(full_cycles - 1) + l - 1.
        max_tag = max_tag.max(offset_after(full_cycles - 1) + l - 1);
    }
    if rem > 0 {
        max_tag = max_tag.max(offset_after(full_cycles) + rem - 1);
    }
    max_tag + 1
}

/// Plan enumeration mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PlanMode {
    /// Each unique tag fetched once, in first-use order.
    Unique,
    /// The full pattern stream is fetched (no resident level).
    FullPattern,
}

/// Lazily enumerable off-chip fetch plan. `addr_of(tag, j)` returns the
/// j-th off-chip address packed into the level word with sequence index
/// `tag`; `FetchCursor` walks the plan in fetch order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FetchPlan {
    start: u64,
    stride: u64,
    pack: u64,
    mode: PlanMode,
    l: u64,
    s: u64,
    k: u64,
    /// Total level words the plan fetches.
    pub total_level_words: u64,
}

impl FetchPlan {
    /// Off-chip *pattern unit* (position in the logical data stream) of
    /// sub-word `j` of plan entry `tag`.
    fn unit_of(&self, tag: u64, j: u64) -> u64 {
        debug_assert!(j < self.pack);
        match self.mode {
            // Unique stream: tags are the unique-word sequence itself.
            PlanMode::Unique => tag * self.pack + j,
            // Full pattern: tag t is the t-th level word of the pattern
            // stream; its units follow the shifted-cyclic stream.
            PlanMode::FullPattern => {
                let words_per_cycle = self.l;
                let cycle = tag / words_per_cycle;
                let pos = tag % words_per_cycle;
                let offset = (cycle / (self.k + 1)) * self.s.min(self.l);
                (offset + pos) * self.pack + j
            }
        }
    }

    /// Off-chip address of sub-word `j` of plan entry `tag`.
    pub fn addr_of(&self, tag: u64, j: u64) -> u64 {
        self.start + self.unit_of(tag, j) * self.stride
    }

    /// All `pack` off-chip addresses of plan entry `tag`.
    pub fn addrs_of(&self, tag: u64) -> Vec<u64> {
        (0..self.pack).map(|j| self.addr_of(tag, j)).collect()
    }

    /// Cursor over the plan in fetch order.
    pub fn cursor(&self) -> FetchCursor {
        FetchCursor { next_tag: 0, next_sub: 0 }
    }

    /// Off-chip words per level word.
    pub fn pack(&self) -> u64 {
        self.pack
    }
}

/// Mutable cursor walking a [`FetchPlan`] one off-chip word at a time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FetchCursor {
    next_tag: u64,
    next_sub: u64,
}

impl FetchCursor {
    /// Next (tag, sub-index, address) to fetch, if any.
    pub fn peek(&self, plan: &FetchPlan) -> Option<(u64, u64, u64)> {
        if self.next_tag >= plan.total_level_words {
            return None;
        }
        Some((self.next_tag, self.next_sub, plan.addr_of(self.next_tag, self.next_sub)))
    }

    /// Advance past the word returned by `peek`.
    pub fn advance(&mut self, plan: &FetchPlan) {
        self.next_sub += 1;
        if self.next_sub == plan.pack {
            self.next_sub = 0;
            self.next_tag += 1;
        }
    }

    /// Whether the plan is exhausted.
    pub fn done(&self, plan: &FetchPlan) -> bool {
        self.next_tag >= plan.total_level_words
    }

    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self { next_tag, next_sub } = self;
        w.put_u64(*next_tag);
        w.put_u64(*next_sub);
    }

    pub(crate) fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self { next_tag: r.get_u64()?, next_sub: r.get_u64()? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchyConfig;
    use crate::pattern::PatternProgram;

    fn cfg_2level(d0: u64, d1: u64) -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, d0, 1, 1)
            .level(32, d1, 1, 2)
            .build()
            .unwrap()
    }

    #[test]
    fn resident_selection_prefers_deepest() {
        let cfg = cfg_2level(1024, 128);
        // Window fits both levels -> resident at level 1 (deepest).
        let p = PatternProgram::cyclic(0, 64).with_outputs(1000);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        assert_eq!(m.resident, Some(1));
        assert_eq!(m.levels[0].role, Role::Fifo);
        assert_eq!(m.levels[1].role, Role::Resident);
        // Window fits only level 0.
        let p = PatternProgram::cyclic(0, 512).with_outputs(5000);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        assert_eq!(m.resident, Some(0));
        assert_eq!(m.levels[1].role, Role::Fifo);
        // Fits nowhere -> full streaming.
        let p = PatternProgram::cyclic(0, 2048).with_outputs(5000);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        assert_eq!(m.resident, None);
    }

    #[test]
    fn double_buffered_levels_never_resident() {
        // Window fits the DB level's capacity, but residency must fall
        // back to the deepest *standard* level: ping-pong halves clear as
        // they drain and cannot replay.
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 1024, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap();
        let p = PatternProgram::cyclic(0, 64).with_outputs(640);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        assert_eq!(m.resident, Some(0));
        assert_eq!(m.levels[1].role, Role::Fifo);
        assert_eq!(m.levels[1].total_writes, 640, "full output streams through");
        // All-DB hierarchy: no residency anywhere -> full streaming plan.
        let all_db = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 1024)
            .build()
            .unwrap();
        let m = McuProgram::compile(&all_db, &p).unwrap();
        assert_eq!(m.resident, None);
        assert_eq!(m.levels[0].total_writes, 640);
    }

    #[test]
    fn sequential_program_never_resident() {
        let cfg = cfg_2level(1024, 128);
        let p = PatternProgram::sequential(0, 500);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        assert_eq!(m.resident, None, "no reuse -> streaming");
        assert_eq!(m.unique_level_words, 500);
    }

    #[test]
    fn write_read_totals_cyclic() {
        let cfg = cfg_2level(1024, 128);
        let p = PatternProgram::cyclic(0, 64).with_outputs(640);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        // Level 0 passes each unique word once; level 1 replays.
        assert_eq!(m.unique_level_words, 64);
        assert_eq!(m.levels[0].total_writes, 64);
        assert_eq!(m.levels[0].total_reads, 64);
        assert_eq!(m.levels[1].total_writes, 64);
        assert_eq!(m.levels[1].total_reads, 640);
    }

    #[test]
    fn streaming_totals_when_window_too_big() {
        let cfg = cfg_2level(1024, 128);
        let p = PatternProgram::cyclic(0, 512).with_outputs(5120);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        // L0 resident; L1 streams the whole output.
        assert_eq!(m.levels[0].total_writes, 512);
        assert_eq!(m.levels[1].total_writes, 5120);
        assert_eq!(m.levels[1].total_reads, 5120);
    }

    #[test]
    fn unique_words_closed_form_matches_stream() {
        use crate::pattern::AccessPattern;
        for (l, s, k, total) in
            [(8, 2, 0, 100), (8, 8, 0, 64), (16, 3, 2, 200), (4, 0, 0, 37), (8, 2, 0, 5)]
        {
            let expect = {
                let cycles = crate::util::ceil_div(total, l);
                let mut v: Vec<u64> = AccessPattern::ShiftedCyclic {
                    start: 0,
                    cycle_length: l,
                    inter_cycle_shift: s,
                    skip_shift: k,
                    cycles,
                }
                .stream()
                .take(total as usize)
                .collect();
                v.sort_unstable();
                v.dedup();
                v.len() as u64
            };
            assert_eq!(unique_words(l, s, k, total), expect, "l={l} s={s} k={k} total={total}");
        }
    }

    #[test]
    fn packing_scales_units() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .build()
            .unwrap();
        let p = PatternProgram::cyclic(0, 64).with_outputs(5_000);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        assert_eq!(m.pack, 4);
        assert_eq!(m.output_program.cycle_length, 16);
        assert_eq!(m.total_output_words, 1_250);
        // Misaligned cycle length rejected.
        let bad = PatternProgram::cyclic(0, 30).with_outputs(5000);
        assert!(McuProgram::compile(&cfg, &bad).is_err());
    }

    #[test]
    fn fetch_plan_unique_mode() {
        let cfg = cfg_2level(1024, 128);
        let p = PatternProgram::shifted_cyclic(100, 4, 2).with_outputs(12);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        // Unique stream: tags 0..8 -> addresses 100..108.
        assert_eq!(m.unique_level_words, 8);
        let addrs: Vec<u64> = {
            let mut c = m.plan.cursor();
            let mut v = Vec::new();
            while let Some((_, _, a)) = c.peek(&m.plan) {
                v.push(a);
                c.advance(&m.plan);
            }
            v
        };
        assert_eq!(addrs, (100..108).collect::<Vec<_>>());
    }

    #[test]
    fn fetch_plan_full_pattern_mode() {
        let cfg = cfg_2level(4, 2); // tiny: nothing fits
        let p = PatternProgram::cyclic(10, 8).with_outputs(16);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        assert_eq!(m.resident, None);
        let mut c = m.plan.cursor();
        let mut v = Vec::new();
        while let Some((_, _, a)) = c.peek(&m.plan) {
            v.push(a);
            c.advance(&m.plan);
        }
        // Full pattern: the window replayed twice from off-chip.
        let mut expect: Vec<u64> = (10..18).collect();
        expect.extend(10..18);
        assert_eq!(v, expect);
    }

    #[test]
    fn strided_plan_addresses() {
        let cfg = cfg_2level(1024, 128);
        let p = PatternProgram::strided(0, 4, 8);
        let m = McuProgram::compile(&cfg, &p).unwrap();
        let mut c = m.plan.cursor();
        let mut v = Vec::new();
        while let Some((_, _, a)) = c.peek(&m.plan) {
            v.push(a);
            c.advance(&m.plan);
        }
        assert_eq!(v, vec![0, 4, 8, 12, 16, 20, 24, 28]);
    }
}

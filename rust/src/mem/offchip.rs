//! Off-chip memory model.
//!
//! The framework requests single words from the global address space
//! (`global_read_address_o`, Table 1) and receives them after a fixed
//! read latency in *external* clock cycles. Requests are pipelined — the
//! memory accepts one new request per external cycle, so a streaming
//! fetch sustains one word per cycle after the initial latency (the case
//! study's test bench delivered "data requests … with a latency of one
//! clock cycle", §5.3.2).
//!
//! Payloads are a deterministic hash of the address so that end-to-end
//! data integrity through the hierarchy is verifiable bit-for-bit.

use crate::sim::engine::Stage;
use crate::sim::fault::FaultSite;
use crate::util::bitword::Word;
use crate::util::frame::{ByteReader, ByteWriter};
use crate::Result;
use std::collections::VecDeque;

/// Deterministic payload for an off-chip address (SplitMix64 finalizer).
#[inline]
pub fn payload_for(addr: u64, width: u32) -> Word {
    let mut z = addr.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    if width <= 64 {
        Word::from_u64(z, width)
    } else {
        let hi = z.wrapping_mul(0xD6E8FEB86659FD93);
        Word::from_u128(((hi as u128) << 64) | z as u128, width)
    }
}

/// In-flight read request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Inflight {
    addr: u64,
    ready_at: u64, // external cycle when the data word is on the bus
}

/// Captured run state of the [`OffChipMemory`]: the in-flight request
/// pipeline (with absolute external-cycle deadlines) and the read
/// counter. The geometry (width, latency, address space) is re-derived by
/// `rearm` and not captured.
#[derive(Debug, Clone, PartialEq)]
pub struct OffChipCheckpoint {
    inflight: VecDeque<Inflight>,
    reads: u64,
}

impl OffChipCheckpoint {
    /// Serialize for the checkpoint wire format.
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self { inflight, reads } = self;
        w.put_u32(inflight.len() as u32);
        for f in inflight {
            let Inflight { addr, ready_at } = f;
            w.put_u64(*addr);
            w.put_u64(*ready_at);
        }
        w.put_u64(*reads);
    }

    /// Checked decode (any in-flight address/deadline pair is valid — the
    /// payload is a pure function of the address).
    pub(crate) fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        let n = r.get_count(16)?;
        let mut inflight = VecDeque::with_capacity(n);
        for _ in 0..n {
            inflight.push_back(Inflight { addr: r.get_u64()?, ready_at: r.get_u64()? });
        }
        Ok(Self { inflight, reads: r.get_u64()? })
    }
}

/// Latency-modelled off-chip memory.
#[derive(Debug)]
pub struct OffChipMemory {
    data_width: u32,
    latency: u64,
    max_addr: u64,
    inflight: VecDeque<Inflight>,
    /// Total words read (energy accounting, Figs 7/12).
    pub reads: u64,
}

impl OffChipMemory {
    /// New memory with `data_width`-bit words, `latency` external cycles,
    /// and an `addr_width`-bit address space.
    pub fn new(data_width: u32, latency: u64, addr_width: u32) -> Self {
        Self {
            data_width,
            latency: latency.max(1),
            max_addr: 1u64 << addr_width.min(48),
            inflight: VecDeque::new(),
            reads: 0,
        }
    }

    /// In-place re-arm: equivalent to `*self = OffChipMemory::new(..)` but
    /// keeps the request-queue allocation (warm-session path).
    pub fn rearm(&mut self, data_width: u32, latency: u64, addr_width: u32) {
        self.data_width = data_width;
        self.latency = latency.max(1);
        self.max_addr = 1u64 << addr_width.min(48);
        self.inflight.clear();
        self.reads = 0;
    }

    /// Issue a read for `addr` at external cycle `now`. Returns false if
    /// the request pipeline is busy this cycle (one request per cycle).
    pub fn request(&mut self, addr: u64, now: u64) -> bool {
        debug_assert!(addr < self.max_addr, "address {addr:#x} outside address space");
        if self.inflight.back().is_some_and(|r| r.ready_at >= now + self.latency) {
            // Already accepted a request this cycle.
            return false;
        }
        self.reads += 1;
        self.inflight.push_back(Inflight { addr, ready_at: now + self.latency });
        true
    }

    /// Pop a word whose data is ready at external cycle `now`.
    pub fn poll(&mut self, now: u64) -> Option<(u64, Word)> {
        if self.inflight.front().is_some_and(|r| r.ready_at <= now) {
            let r = self.inflight.pop_front().unwrap();
            Some((r.addr, payload_for(r.addr, self.data_width)))
        } else {
            None
        }
    }

    /// Whether requests are still outstanding.
    pub fn busy(&self) -> bool {
        !self.inflight.is_empty()
    }

    /// External cycle at which the oldest in-flight word becomes
    /// deliverable (`None` when nothing is in flight) — the off-chip
    /// pipeline's contribution to the hierarchy's quiescence horizon: a
    /// read with `k` cycles left in flight cannot change anything for `k`
    /// external edges.
    pub fn next_delivery_at(&self) -> Option<u64> {
        self.inflight.front().map(|r| r.ready_at)
    }

    /// Capture the memory's run state (see [`OffChipCheckpoint`]).
    pub fn snapshot(&self) -> OffChipCheckpoint {
        OffChipCheckpoint { inflight: self.inflight.clone(), reads: self.reads }
    }

    /// Restore an [`OffChipCheckpoint`] taken on a memory armed for the
    /// same configuration. Reuses the queue allocation.
    pub fn restore(&mut self, ck: &OffChipCheckpoint) {
        self.inflight.clone_from(&ck.inflight);
        self.reads = ck.reads;
    }
}

/// The off-chip memory lives entirely in the external clock domain; its
/// request pipeline advances with the wall-clock cycle numbers passed to
/// [`OffChipMemory::request`]/[`OffChipMemory::poll`], so the edge hooks
/// are the defaults and data availability is answered by `poll` (which
/// needs `now`), not by a cycle-free `ready_out` — advertising in-flight
/// responses as ready would let a generic scheduler read them early.
impl Stage for OffChipMemory {
    /// Edge hooks are no-ops (all mutation goes through the
    /// `request`/`poll` handshakes), so the edge-driven state is inert
    /// indefinitely; the *time-dependent* part of the horizon — when an
    /// in-flight word becomes deliverable — is exposed via
    /// [`OffChipMemory::next_delivery_at`] because it needs the current
    /// external cycle to be interpreted.
    fn quiescent_for(&self) -> u64 {
        u64::MAX
    }

    /// Injectable state: the *oldest* in-flight request. An address-bit
    /// flip keeps the request in flight but delivers the wrong payload
    /// (vacant if nothing is in flight or the flip would leave the
    /// address space); a delay pushes its deadline out (head-of-line
    /// blocking — `poll` is front-gated); a drop loses the word entirely.
    fn inject(&mut self, site: &FaultSite) -> bool {
        match *site {
            FaultSite::InflightAddr { bit } => {
                let max_addr = self.max_addr;
                match self.inflight.front_mut() {
                    Some(f) if bit < 48 && (f.addr ^ (1u64 << bit)) < max_addr => {
                        f.addr ^= 1u64 << bit;
                        true
                    }
                    _ => false,
                }
            }
            FaultSite::DelayDelivery { extra } => match self.inflight.front_mut() {
                Some(f) if extra > 0 => {
                    f.ready_at += extra;
                    true
                }
                _ => false,
            },
            FaultSite::DropDelivery => self.inflight.pop_front().is_some(),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_is_deterministic_and_width_masked() {
        let a = payload_for(42, 32);
        let b = payload_for(42, 32);
        assert_eq!(a, b);
        assert_eq!(a.width(), 32);
        assert!(a.as_u64() <= u32::MAX as u64);
        assert_ne!(payload_for(42, 32), payload_for(43, 32));
        // 128-bit payloads have entropy in the high half.
        let w = payload_for(7, 128);
        assert_ne!(w.bits(64, 64).as_u64(), 0);
    }

    #[test]
    fn latency_is_respected() {
        let mut m = OffChipMemory::new(32, 3, 20);
        assert!(m.request(100, 0));
        assert!(m.poll(0).is_none());
        assert!(m.poll(2).is_none());
        let (addr, w) = m.poll(3).unwrap();
        assert_eq!(addr, 100);
        assert_eq!(w, payload_for(100, 32));
        assert!(!m.busy());
    }

    #[test]
    fn pipelined_streaming() {
        let mut m = OffChipMemory::new(32, 2, 20);
        // One request per cycle: cycles 0,1,2 -> data at 2,3,4.
        assert!(m.request(0, 0));
        assert!(!m.request(1, 0), "second request same cycle rejected");
        assert!(m.request(1, 1));
        assert!(m.request(2, 2));
        assert_eq!(m.poll(2).unwrap().0, 0);
        assert_eq!(m.poll(3).unwrap().0, 1);
        assert!(m.poll(3).is_none());
        assert_eq!(m.poll(4).unwrap().0, 2);
        assert_eq!(m.reads, 3);
    }

    #[test]
    fn next_delivery_tracks_oldest_inflight() {
        let mut m = OffChipMemory::new(32, 3, 20);
        assert_eq!(m.next_delivery_at(), None);
        assert!(m.request(1, 10));
        assert!(m.request(2, 11));
        assert_eq!(m.next_delivery_at(), Some(13), "oldest request lands first");
        m.poll(13).unwrap();
        assert_eq!(m.next_delivery_at(), Some(14));
        m.poll(14).unwrap();
        assert_eq!(m.next_delivery_at(), None);
    }

    #[test]
    fn zero_latency_clamped_to_one() {
        let mut m = OffChipMemory::new(32, 0, 20);
        assert!(m.request(5, 10));
        assert!(m.poll(10).is_none());
        assert!(m.poll(11).is_some());
    }
}

//! Functional (untimed) reference model — the oracle the cycle-accurate
//! simulator is verified against, mirroring the role of the paper's Python
//! golden model (§5.1).
//!
//! Given a configuration and a pattern program it produces the exact
//! expected output stream (addresses + payloads) and analytic cycle
//! bounds. Differential tests assert:
//!
//! * the simulator's output stream equals the functional stream
//!   bit-for-bit (data integrity);
//! * the simulator's cycle count lies between the analytic lower bound
//!   and a documented upper bound (timing sanity).

use super::mcu::McuProgram;
use super::offchip::payload_for;
use crate::config::HierarchyConfig;
use crate::pattern::PatternProgram;
use crate::util::bitword::Word;
use crate::Result;

/// Untimed reference model.
pub struct FunctionalModel {
    cfg: HierarchyConfig,
    prog: PatternProgram,
    compiled: McuProgram,
}

impl FunctionalModel {
    /// Build for a config + program (validates both).
    pub fn new(cfg: &HierarchyConfig, prog: &PatternProgram) -> Result<Self> {
        let compiled = McuProgram::compile(cfg, prog)?;
        Ok(Self { cfg: cfg.clone(), prog: prog.clone(), compiled })
    }

    /// The expected output stream at off-chip-unit granularity:
    /// `(address, payload)` pairs in emission order.
    pub fn expected_units(&self) -> Vec<(u64, Word)> {
        let w = self.cfg.offchip.data_width;
        self.prog
            .expected_outputs()
            .into_iter()
            .map(|addr| (addr, payload_for(addr, w)))
            .collect()
    }

    /// Number of outputs the accelerator sees (OSR emissions if an OSR is
    /// configured, level words otherwise).
    pub fn expected_output_count(&self) -> u64 {
        match &self.cfg.osr {
            Some(o) => {
                let units_per_emit = (o.shifts[0] / self.cfg.offchip.data_width) as u64;
                self.prog.total_outputs / units_per_emit
            }
            None => self.compiled.total_output_words,
        }
    }

    /// Unique off-chip words fetched.
    pub fn expected_offchip_reads(&self) -> u64 {
        self.compiled.plan.total_level_words * self.compiled.pack
    }

    /// Total OSR emissions (equals output words if no OSR is configured).
    fn emissions(&self) -> u64 {
        self.expected_output_count()
    }

    /// Analytic lower bound on internal cycles (ignoring all fill and
    /// handshake overhead): the OSR emits at most once per cycle, the last
    /// level reads at most one word per cycle, and streamed words cannot
    /// beat the 3-cycle CDC cadence when they all cross the input buffer.
    pub fn cycle_lower_bound(&self) -> u64 {
        let out_words = self.compiled.total_output_words;
        let base = match self.compiled.resident {
            // Resident somewhere: steady state can reach 1 word/cycle.
            Some(_) => out_words,
            // Fully streamed: every level word crosses the CDC (3-cycle
            // cadence at the depth-1 buffer; deeper buffers can stream
            // faster, so only the raw word count bounds then).
            None if self.cfg.offchip.ib_depth == 1 => {
                out_words.max(3 * self.compiled.plan.total_level_words)
            }
            None => out_words.max(self.compiled.plan.total_level_words),
        };
        base.max(self.emissions())
    }

    /// Documented upper bound: every level word through the CDC at the
    /// 3-cycle cadence, a 2-cycles-per-word replay penalty, one cycle per
    /// OSR emission, a ping-pong drain allowance, and a pipeline flush
    /// allowance. A simulator result above this indicates a scheduling
    /// bug.
    ///
    /// The ping-pong term covers the overlapped fill/drain cadence of
    /// double-buffered levels: in steady state a ping-pong level is never
    /// slower than the stream feeding it (fill and drain proceed in the
    /// same cycle), but its reads idle while the *first* half fills and
    /// the final partial buffer swaps in only once writes complete — at
    /// most one half depth of latency per double-buffered level.
    pub fn cycle_upper_bound(&self) -> u64 {
        let through_cdc = 3 * self.compiled.plan.total_level_words;
        let replay = 3 * self.compiled.total_output_words;
        let pingpong: u64 = self
            .cfg
            .levels
            .iter()
            .filter(|l| l.kind.is_double_buffered())
            .map(|l| l.half_depth())
            .sum();
        through_cdc + replay + self.emissions() + pingpong
            + 8 * (self.cfg.levels.len() as u64 + 2)
    }

    /// The compiled program (role assignment, fetch plan).
    pub fn compiled(&self) -> &McuProgram {
        &self.compiled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Hierarchy;
    use crate::pattern::PatternProgram;

    fn cfg() -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap()
    }

    fn cfg_db() -> HierarchyConfig {
        // Same shape as `cfg` with a ping-pong last level.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap()
    }

    /// The central differential test: simulator output stream ==
    /// functional stream, cycles within analytic bounds.
    fn check(prog: PatternProgram) {
        check_cfg(&cfg(), prog);
    }

    fn check_cfg(c: &HierarchyConfig, prog: PatternProgram) {
        let f = FunctionalModel::new(c, &prog).unwrap();
        let mut h = Hierarchy::new(c).unwrap();
        h.set_collect(true);
        h.load_program(&prog).unwrap();
        let r = h.run().unwrap();
        // Flatten the simulator outputs to unit granularity.
        let mut sim_units = Vec::new();
        for out in &r.outputs {
            for (j, &a) in out.addrs.iter().enumerate() {
                sim_units.push((a, out.word.bits(j as u32 * 32, 32)));
            }
        }
        assert_eq!(sim_units, f.expected_units(), "output stream mismatch");
        assert_eq!(r.stats.outputs, f.expected_output_count());
        let cyc = r.stats.internal_cycles;
        assert!(cyc >= f.cycle_lower_bound(), "cycles {cyc} below lower bound");
        assert!(
            cyc <= f.cycle_upper_bound(),
            "cycles {cyc} above upper bound {}",
            f.cycle_upper_bound()
        );
    }

    #[test]
    fn differential_cyclic() {
        check(PatternProgram::cyclic(0, 32).with_outputs(640));
        check(PatternProgram::cyclic(7, 100).with_outputs(1_000));
    }

    #[test]
    fn differential_shifted() {
        check(PatternProgram::shifted_cyclic(0, 32, 8).with_outputs(640));
        check(PatternProgram::shifted_cyclic(3, 50, 25).with_outputs(1_000));
        check(PatternProgram::shifted_cyclic(0, 64, 64).with_outputs(1_024));
    }

    #[test]
    fn differential_sequential_and_strided() {
        check(PatternProgram::sequential(0, 500));
        check(PatternProgram::strided(100, 4, 400));
    }

    #[test]
    fn differential_skip_shift() {
        check(PatternProgram::shifted_cyclic(0, 24, 6).with_skip_shift(2).with_outputs(720));
    }

    #[test]
    fn differential_streaming_window() {
        // Exceeds both levels: full off-chip replay.
        check(PatternProgram::cyclic(0, 1024).with_outputs(4_096));
    }

    #[test]
    fn differential_double_buffered() {
        // The same battery through a ping-pong last level: the output
        // stream and bounds must hold for every family, including the
        // truncated final buffer and the swap-latency tail.
        for prog in [
            PatternProgram::sequential(0, 500),
            PatternProgram::strided(100, 4, 400),
            PatternProgram::cyclic(0, 32).with_outputs(640),
            PatternProgram::cyclic(0, 256).with_outputs(1_024),
            PatternProgram::shifted_cyclic(0, 32, 8).with_outputs(640),
            PatternProgram::shifted_cyclic(0, 24, 6).with_skip_shift(2).with_outputs(720),
        ] {
            check_cfg(&cfg_db(), prog);
        }
        // And an all-ping-pong hierarchy (no residency anywhere).
        let all_db = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap();
        check_cfg(&all_db, PatternProgram::cyclic(0, 16).with_outputs(320));
        check_cfg(&all_db, PatternProgram::sequential(0, 300));
    }

    #[test]
    fn expected_counts() {
        let c = cfg();
        let p = PatternProgram::shifted_cyclic(0, 64, 8).with_outputs(640);
        let f = FunctionalModel::new(&c, &p).unwrap();
        assert_eq!(f.expected_output_count(), 640);
        assert_eq!(f.expected_offchip_reads(), 136);
        assert!(f.cycle_lower_bound() >= 640);
    }
}

//! Functional (untimed) reference model — the oracle the cycle-accurate
//! simulator is verified against, mirroring the role of the paper's Python
//! golden model (§5.1).
//!
//! Given a configuration and a pattern program it produces the exact
//! expected output stream (addresses + payloads) and analytic cycle
//! bounds. Differential tests assert:
//!
//! * the simulator's output stream equals the functional stream
//!   bit-for-bit (data integrity);
//! * the simulator's cycle count lies between the analytic lower bound
//!   and a documented upper bound (timing sanity).

use super::mcu::McuProgram;
use super::offchip::payload_for;
use crate::config::HierarchyConfig;
use crate::pattern::PatternProgram;
use crate::sim::SimStats;
use crate::util::bitword::Word;
use crate::Result;

/// Untimed reference model.
pub struct FunctionalModel {
    cfg: HierarchyConfig,
    prog: PatternProgram,
    compiled: McuProgram,
}

impl FunctionalModel {
    /// Build for a config + program (validates both).
    pub fn new(cfg: &HierarchyConfig, prog: &PatternProgram) -> Result<Self> {
        let compiled = McuProgram::compile(cfg, prog)?;
        Ok(Self { cfg: cfg.clone(), prog: prog.clone(), compiled })
    }

    /// The expected output stream at off-chip-unit granularity:
    /// `(address, payload)` pairs in emission order.
    pub fn expected_units(&self) -> Vec<(u64, Word)> {
        let w = self.cfg.offchip.data_width;
        self.prog
            .expected_outputs()
            .into_iter()
            .map(|addr| (addr, payload_for(addr, w)))
            .collect()
    }

    /// Number of outputs the accelerator sees (OSR emissions if an OSR is
    /// configured, level words otherwise).
    pub fn expected_output_count(&self) -> u64 {
        match &self.cfg.osr {
            Some(o) => {
                let units_per_emit = (o.shifts[0] / self.cfg.offchip.data_width) as u64;
                self.prog.total_outputs / units_per_emit
            }
            None => self.compiled.total_output_words,
        }
    }

    /// Unique off-chip words fetched.
    pub fn expected_offchip_reads(&self) -> u64 {
        self.compiled.plan.total_level_words * self.compiled.pack
    }

    /// Total OSR emissions (equals output words if no OSR is configured).
    fn emissions(&self) -> u64 {
        self.expected_output_count()
    }

    /// Analytic lower bound on internal (measured-run) cycles. This bound
    /// is **admissible** — never above the simulated count — for every
    /// config the builder accepts; the bound-and-prune DSE front end
    /// ([`crate::dse`]) rests on that, and `tests/bounds.rs` polices it
    /// across the full pattern-family × level-kind × clock-ratio matrix.
    ///
    /// Terms (each individually a valid lower bound, so their max is):
    ///
    /// * **Output words / OSR emissions** — the last level reads at most
    ///   one word per cycle and the OSR emits at most once per cycle.
    /// * **CDC cadence** (non-preload, no resident level, depth-1 input
    ///   buffer): every fetched word crosses the clock-domain sync. The
    ///   word's accept empties the depth-1 buffer, and the full/empty
    ///   flag needs two internal edges through the synchronizer before
    ///   the next word can be accepted; refilling additionally waits one
    ///   external-domain request per off-chip unit when the external
    ///   clock is not faster than the internal one — `pack + 2` internal
    ///   cycles per word then, `2` per word at any ratio. Deeper input
    ///   buffers pipeline the fetches, so only the raw word count
    ///   remains.
    /// * **Write-enable toggle** (non-preload, multi-level, standard last
    ///   level): writes into level `l >= 1` are paced by the write-enable
    ///   toggle protocol — at most one write per two cycles — so `2w - 1`
    ///   cycles must elapse from the first to the last of `w` writes.
    ///
    /// Preloaded runs prime the hierarchy before the measured run starts,
    /// so only the output-side terms apply there.
    pub fn cycle_lower_bound(&self) -> u64 {
        let out_words = self.compiled.total_output_words;
        let mut base = out_words.max(self.emissions());
        if !self.cfg.preload {
            match self.compiled.resident {
                // Resident somewhere: steady state can reach 1 word/cycle.
                Some(_) => {}
                None if self.cfg.offchip.ib_depth == 1 => {
                    let per_word =
                        if self.cfg.offchip.external_hz <= self.cfg.offchip.internal_hz {
                            self.compiled.pack + 2
                        } else {
                            2
                        };
                    base = base.max(per_word * self.compiled.plan.total_level_words);
                }
                None => base = base.max(self.compiled.plan.total_level_words),
            }
            let last_standard =
                self.cfg.levels.last().is_some_and(|l| !l.kind.is_double_buffered());
            if self.cfg.levels.len() >= 2 && last_standard {
                let w = self.compiled.levels.last().map(|u| u.total_writes).unwrap_or(0);
                base = base.max((2 * w).saturating_sub(1));
            }
        }
        base
    }

    /// Documented upper bound on internal cycles. A simulator result
    /// above this indicates a scheduling bug; `tests/bounds.rs` asserts
    /// it across the full config matrix, and the bound-and-prune DSE uses
    /// it as the worst case a candidate is charged before simulation.
    ///
    /// The dominant term is the serialized fetch path: each of the
    /// `total_level_words` fetched words is charged a full
    /// request→latency→sync round trip with no pipelining —
    /// `(2 + pack + latency)` external periods (clock-edge alignment,
    /// one request per off-chip unit, the off-chip latency) each costing
    /// up to `ipe = ceil(f_int / f_ext)` internal cycles, plus 4 internal
    /// cycles of synchronizer/consume overhead. On top of that: every
    /// level write at the 2-cycle toggle cadence, one read per last-level
    /// word, one cycle per OSR emission (a no-OSR emission shares its
    /// cycle with the last-level read), the ping-pong first-fill/swap
    /// allowance of one half depth per double-buffered level, and
    /// startup/flush allowances for the preload hand-off and pipeline
    /// drain.
    pub fn cycle_upper_bound(&self) -> u64 {
        let o = &self.cfg.offchip;
        let ipe = o.internal_hz.div_ceil(o.external_hz).max(1);
        let per_word = (2 + self.compiled.pack + o.latency) * ipe + 4;
        let writes: u64 = self.compiled.levels.iter().map(|u| 2 * u.total_writes).sum();
        let last_reads = self.compiled.levels.last().map(|u| u.total_reads).unwrap_or(0);
        let osr_emissions = if self.cfg.osr.is_some() { self.emissions() } else { 0 };
        let pingpong: u64 = self
            .cfg
            .levels
            .iter()
            .filter(|l| l.kind.is_double_buffered())
            .map(|l| l.half_depth())
            .sum();
        let startup = 2 * (o.latency + self.compiled.pack + 2) * ipe;
        let flush = 8 * (self.cfg.levels.len() as u64 + 2) * ipe;
        per_word * self.compiled.plan.total_level_words
            + writes
            + last_reads
            + osr_emissions
            + pingpong
            + startup
            + flush
    }

    /// Exact per-run activity counts as a synthetic [`SimStats`], with the
    /// cycle counters pinned to `internal_cycles`.
    ///
    /// Every *event* count (level reads/writes, CDC transfers, off-chip
    /// reads, OSR shifts, outputs) is known in closed form from the
    /// compiled program — the simulator merely replays them — so a
    /// [`crate::cost::run_power`] evaluation over these stats is exact up
    /// to the cycle count. Feeding `cycle_lower_bound()` gives an upper
    /// bound on run power and `cycle_upper_bound()` a lower bound:
    /// at fixed event counts, average power is weakly decreasing in run
    /// time (dynamic energy is divided by it; leakage is
    /// time-independent).
    pub fn activity_stats(&self, internal_cycles: u64) -> SimStats {
        let level_writes: Vec<u64> = self.compiled.levels.iter().map(|u| u.total_writes).collect();
        let level_reads: Vec<u64> = self.compiled.levels.iter().map(|u| u.total_reads).collect();
        let n = level_writes.len();
        SimStats {
            internal_cycles,
            external_cycles: 0,
            outputs: self.expected_output_count(),
            offchip_reads: self.expected_offchip_reads(),
            level_writes,
            level_reads,
            write_over_read_stalls: vec![0; n],
            write_waits: vec![0; n],
            osr_shifts: if self.cfg.osr.is_some() { self.emissions() } else { 0 },
            cdc_transfers: self.compiled.plan.total_level_words,
            ..SimStats::default()
        }
    }

    /// The compiled program (role assignment, fetch plan).
    pub fn compiled(&self) -> &McuProgram {
        &self.compiled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Hierarchy;
    use crate::pattern::PatternProgram;

    fn cfg() -> HierarchyConfig {
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap()
    }

    fn cfg_db() -> HierarchyConfig {
        // Same shape as `cfg` with a ping-pong last level.
        HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level_double_buffered(32, 128)
            .build()
            .unwrap()
    }

    /// The central differential test: simulator output stream ==
    /// functional stream, cycles within analytic bounds.
    fn check(prog: PatternProgram) {
        check_cfg(&cfg(), prog);
    }

    fn check_cfg(c: &HierarchyConfig, prog: PatternProgram) {
        let f = FunctionalModel::new(c, &prog).unwrap();
        let mut h = Hierarchy::new(c).unwrap();
        h.set_collect(true);
        h.load_program(&prog).unwrap();
        let r = h.run().unwrap();
        // Flatten the simulator outputs to unit granularity.
        let mut sim_units = Vec::new();
        for out in &r.outputs {
            for (j, &a) in out.addrs.iter().enumerate() {
                sim_units.push((a, out.word.bits(j as u32 * 32, 32)));
            }
        }
        assert_eq!(sim_units, f.expected_units(), "output stream mismatch");
        assert_eq!(r.stats.outputs, f.expected_output_count());
        let cyc = r.stats.internal_cycles;
        assert!(cyc >= f.cycle_lower_bound(), "cycles {cyc} below lower bound");
        assert!(
            cyc <= f.cycle_upper_bound(),
            "cycles {cyc} above upper bound {}",
            f.cycle_upper_bound()
        );
    }

    #[test]
    fn differential_cyclic() {
        check(PatternProgram::cyclic(0, 32).with_outputs(640));
        check(PatternProgram::cyclic(7, 100).with_outputs(1_000));
    }

    #[test]
    fn differential_shifted() {
        check(PatternProgram::shifted_cyclic(0, 32, 8).with_outputs(640));
        check(PatternProgram::shifted_cyclic(3, 50, 25).with_outputs(1_000));
        check(PatternProgram::shifted_cyclic(0, 64, 64).with_outputs(1_024));
    }

    #[test]
    fn differential_sequential_and_strided() {
        check(PatternProgram::sequential(0, 500));
        check(PatternProgram::strided(100, 4, 400));
    }

    #[test]
    fn differential_skip_shift() {
        check(PatternProgram::shifted_cyclic(0, 24, 6).with_skip_shift(2).with_outputs(720));
    }

    #[test]
    fn differential_streaming_window() {
        // Exceeds both levels: full off-chip replay.
        check(PatternProgram::cyclic(0, 1024).with_outputs(4_096));
    }

    #[test]
    fn differential_double_buffered() {
        // The same battery through a ping-pong last level: the output
        // stream and bounds must hold for every family, including the
        // truncated final buffer and the swap-latency tail.
        for prog in [
            PatternProgram::sequential(0, 500),
            PatternProgram::strided(100, 4, 400),
            PatternProgram::cyclic(0, 32).with_outputs(640),
            PatternProgram::cyclic(0, 256).with_outputs(1_024),
            PatternProgram::shifted_cyclic(0, 32, 8).with_outputs(640),
            PatternProgram::shifted_cyclic(0, 24, 6).with_skip_shift(2).with_outputs(720),
        ] {
            check_cfg(&cfg_db(), prog);
        }
        // And an all-ping-pong hierarchy (no residency anywhere).
        let all_db = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level_double_buffered(32, 64)
            .build()
            .unwrap();
        check_cfg(&all_db, PatternProgram::cyclic(0, 16).with_outputs(320));
        check_cfg(&all_db, PatternProgram::sequential(0, 300));
    }

    #[test]
    fn expected_counts() {
        let c = cfg();
        let p = PatternProgram::shifted_cyclic(0, 64, 8).with_outputs(640);
        let f = FunctionalModel::new(&c, &p).unwrap();
        assert_eq!(f.expected_output_count(), 640);
        assert_eq!(f.expected_offchip_reads(), 136);
        assert!(f.cycle_lower_bound() >= 640);
    }
}

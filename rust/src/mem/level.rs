//! Hierarchy levels: the standard banked level ([`Level`]) with the MCU
//! register state of Listing 1 (writing pointer, pattern pointer, offset
//! pointer, skips, write-enable toggle), and the [`LevelStage`] dispatcher
//! that selects the datapath implementation per configured
//! [`LevelKind`] — standard here, ping-pong in
//! [`super::pingpong::PingPongLevel`].
//!
//! Bank interleaving: with two single-ported banks, even slots live in
//! bank 0 and odd slots in bank 1, so a write and a read that target
//! different parities proceed in the same cycle — the "two single-ported
//! banks emulate a dual-ported module" design of §4.1.2.

use super::mcu::{LevelUnits, Role};
use super::pingpong::PingPongLevel;
use crate::config::{LevelConfig, LevelKind, PortKind, Protection};
use crate::sim::engine::Stage;
use crate::sim::fault::{FaultKind, FaultSite};
use crate::util::bitword::Word;
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};

/// Re-export of the compiled role for convenience.
pub type LevelRole = Role;

/// Perturb one payload bit of the word stored at `idx` within `slots` —
/// the fault-injection primitive shared by every level implementation.
/// Returns false if the upset is vacant: empty slot, out-of-range index
/// or bit, or a stuck-at matching the stored value.
pub(super) fn perturb_in(slots: &mut [Option<Slot>], idx: u64, bit: u32, kind: FaultKind) -> bool {
    let Some(s) = slots.get_mut(idx as usize).and_then(|s| s.as_mut()) else {
        return false;
    };
    kind.perturb(&mut s.word, bit)
}

/// Flip one payload bit of the word stored at `idx` within `slots`.
/// Returns false if the slot is empty or out of range.
pub(super) fn corrupt_in(slots: &mut [Option<Slot>], idx: u64, bit: u32) -> bool {
    perturb_in(slots, idx, bit, FaultKind::Flip)
}

/// Read one payload bit of the word stored at `idx` within `slots`
/// without mutating anything: `None` if the upset would be vacant
/// (empty slot, out of range). Protection accounting uses this to decide
/// whether a scheduled upset on a parity/SECDED level actually *lands*.
pub(super) fn probe_in(slots: &[Option<Slot>], idx: u64, bit: u32) -> Option<bool> {
    let s = slots.get(idx as usize)?.as_ref()?;
    if bit >= s.word.width() {
        return None;
    }
    Some(s.word.bits(bit, 1).as_u64() != 0)
}

/// A stored level word: the fetch-plan tag plus its payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slot {
    /// Fetch-plan tag (sequence index of this level word).
    pub tag: u64,
    /// Payload bits.
    pub word: Word,
}

impl Slot {
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self { tag, word } = self;
        w.put_u64(*tag);
        word.wire_write(w);
    }

    pub(crate) fn wire_read(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self { tag: r.get_u64()?, word: Word::wire_read(r)? })
    }
}

/// Encode an optional slot (presence byte, then the slot).
pub(crate) fn wire_write_opt_slot(s: &Option<Slot>, w: &mut ByteWriter) {
    w.put_bool(s.is_some());
    if let Some(s) = s {
        s.wire_write(w);
    }
}

/// Decode an optional slot written by [`wire_write_opt_slot`].
pub(crate) fn wire_read_opt_slot(r: &mut ByteReader<'_>) -> Result<Option<Slot>> {
    Ok(if r.get_bool()? { Some(Slot::wire_read(r)?) } else { None })
}

/// Encode a slot array (count-prefixed optional slots).
pub(crate) fn wire_write_slots(slots: &[Option<Slot>], w: &mut ByteWriter) {
    w.put_u32(slots.len() as u32);
    for s in slots {
        wire_write_opt_slot(s, w);
    }
}

/// Decode a slot array written by [`wire_write_slots`]; the count is
/// validated against the remaining input before allocation.
pub(crate) fn wire_read_slots(r: &mut ByteReader<'_>) -> Result<Vec<Option<Slot>>> {
    let n = r.get_count(1)?;
    let mut slots = Vec::with_capacity(n);
    for _ in 0..n {
        slots.push(wire_read_opt_slot(r)?);
    }
    Ok(slots)
}

/// Captured run state of one standard [`Level`] at a cycle boundary: the
/// slot contents plus every MCU register of Listing 1. The static
/// configuration and compiled program are *not* captured — a checkpoint is
/// only valid on a level re-armed for the same (config, program) pair,
/// which [`crate::mem::Hierarchy::restore`] checks at the hierarchy level.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelCheckpoint {
    slots: Vec<Option<Slot>>,
    occupied: u64,
    writing_ptr: u64,
    pattern_ptr: u64,
    offset_slot: u64,
    offset_units: u64,
    skips: u64,
    fifo_read_ptr: u64,
    we_last: bool,
    out_reg: Option<Slot>,
    writes_done: u64,
    reads_done: u64,
}

impl LevelCheckpoint {
    /// Serialize for the checkpoint wire format (destructured so a newly
    /// added register must be encoded here explicitly).
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self {
            slots,
            occupied,
            writing_ptr,
            pattern_ptr,
            offset_slot,
            offset_units,
            skips,
            fifo_read_ptr,
            we_last,
            out_reg,
            writes_done,
            reads_done,
        } = self;
        wire_write_slots(slots, w);
        w.put_u64(*occupied);
        w.put_u64(*writing_ptr);
        w.put_u64(*pattern_ptr);
        w.put_u64(*offset_slot);
        w.put_u64(*offset_units);
        w.put_u64(*skips);
        w.put_u64(*fifo_read_ptr);
        w.put_bool(*we_last);
        wire_write_opt_slot(out_reg, w);
        w.put_u64(*writes_done);
        w.put_u64(*reads_done);
    }

    /// Checked decode against the level's static configuration: the slot
    /// count must match the configured capacity and the wrapping slot
    /// pointers must be in range (both invariants of every legitimately
    /// captured checkpoint), so corrupt bytes fail here instead of
    /// indexing out of bounds mid-simulation.
    pub(crate) fn wire_read(r: &mut ByteReader<'_>, cfg: &LevelConfig) -> Result<Self> {
        let ck = Self {
            slots: wire_read_slots(r)?,
            occupied: r.get_u64()?,
            writing_ptr: r.get_u64()?,
            pattern_ptr: r.get_u64()?,
            offset_slot: r.get_u64()?,
            offset_units: r.get_u64()?,
            skips: r.get_u64()?,
            fifo_read_ptr: r.get_u64()?,
            we_last: r.get_bool()?,
            out_reg: wire_read_opt_slot(r)?,
            writes_done: r.get_u64()?,
            reads_done: r.get_u64()?,
        };
        let cap = cfg.capacity_words();
        if ck.slots.len() as u64 != cap {
            return Err(Error::Parse(format!(
                "wire: level checkpoint has {} slots, configured capacity is {cap}",
                ck.slots.len()
            )));
        }
        if ck.writing_ptr >= cap || ck.offset_slot >= cap || ck.fifo_read_ptr >= cap {
            return Err(Error::Parse("wire: level checkpoint pointer out of range".into()));
        }
        Ok(ck)
    }
}

/// One standard memory hierarchy level with its MCU registers.
#[derive(Debug)]
pub struct Level {
    /// Static configuration (`kind` is `Standard`).
    pub cfg: LevelConfig,
    /// Compiled program for the current pattern.
    pub units: LevelUnits,
    slots: Vec<Option<Slot>>,
    occupied: u64,
    // --- MCU registers (Listing 1) ---
    writing_ptr: u64,
    pattern_ptr: u64,
    offset_slot: u64,
    offset_units: u64,
    skips: u64,
    fifo_read_ptr: u64,
    we_last: bool,
    /// Word presented to the next level (or the OSR / accelerator) after a
    /// read cycle; consumed by the downstream write.
    pub out_reg: Option<Slot>,
    /// Writes committed so far.
    pub writes_done: u64,
    /// Reads committed so far.
    pub reads_done: u64,
}

impl Level {
    /// Construct for a config + compiled program.
    pub fn new(cfg: LevelConfig, units: LevelUnits) -> Self {
        let depth = cfg.capacity_words();
        Self::from_storage(vec![None; depth as usize], cfg, units)
    }

    /// Rebuild from an existing slot allocation (warm re-arm across a
    /// level-kind change recycles the storage; state is bit-identical to
    /// [`Self::new`]).
    fn from_storage(mut slots: Vec<Option<Slot>>, cfg: LevelConfig, units: LevelUnits) -> Self {
        let depth = cfg.capacity_words() as usize;
        slots.clear();
        slots.resize(depth, None);
        Self {
            cfg,
            units,
            slots,
            occupied: 0,
            writing_ptr: 0,
            pattern_ptr: 0,
            offset_slot: 0,
            offset_units: 0,
            skips: 0,
            fifo_read_ptr: 0,
            we_last: false,
            out_reg: None,
            writes_done: 0,
            reads_done: 0,
        }
    }

    /// Surrender the slot storage (warm re-arm across a kind change).
    fn into_storage(self) -> Vec<Option<Slot>> {
        self.slots
    }

    /// Number of banks (1 unless configured dual-banked).
    #[inline]
    fn banks(&self) -> u32 {
        match self.cfg.kind {
            LevelKind::Standard { banks, .. } => banks,
            LevelKind::DoubleBuffered => 1,
        }
    }

    /// Port configuration of the macro(s).
    #[inline]
    fn ports(&self) -> PortKind {
        match self.cfg.kind {
            LevelKind::Standard { ports, .. } => ports,
            LevelKind::DoubleBuffered => PortKind::Single,
        }
    }

    /// In-place re-arm for a new program (and, on the warm-session DSE
    /// path, a new static configuration): equivalent to
    /// `*self = Level::new(cfg.clone(), units)` but reuses the slot
    /// storage allocation. The post-state is bit-identical to a fresh
    /// construction, which is what makes warm sessions indistinguishable
    /// from cold ones.
    pub fn rearm(&mut self, cfg: &LevelConfig, units: LevelUnits) {
        if self.cfg != *cfg {
            self.cfg = cfg.clone();
        }
        let depth = self.cfg.capacity_words() as usize;
        self.units = units;
        self.slots.clear();
        self.slots.resize(depth, None);
        self.occupied = 0;
        self.writing_ptr = 0;
        self.pattern_ptr = 0;
        self.offset_slot = 0;
        self.offset_units = 0;
        self.skips = 0;
        self.fifo_read_ptr = 0;
        self.we_last = false;
        self.out_reg = None;
        self.writes_done = 0;
        self.reads_done = 0;
    }

    /// Total slot count (all banks).
    pub fn depth(&self) -> u64 {
        self.slots.len() as u64
    }

    /// Occupied slot count.
    pub fn occupied(&self) -> u64 {
        self.occupied
    }

    /// Bank index of a slot (interleaved).
    #[inline]
    fn bank_of(&self, slot: u64) -> u32 {
        if self.banks() == 2 {
            (slot & 1) as u32
        } else {
            0
        }
    }

    /// Whether all programmed writes have been committed.
    pub fn writes_complete(&self) -> bool {
        self.writes_done >= self.units.total_writes
    }

    /// Whether all programmed reads have been committed.
    pub fn reads_complete(&self) -> bool {
        self.reads_done >= self.units.total_reads
    }

    /// The write-enable toggle: a write may fire only if the previous
    /// cycle was not a write cycle ("the MCU can at most activate the
    /// write mode every two clock cycles", §4.1.4).
    pub fn write_allowed_by_toggle(&self) -> bool {
        !self.we_last
    }

    /// Whether the slot the writing pointer targets is free.
    pub fn write_slot_free(&self) -> bool {
        self.slots[self.writing_ptr as usize].is_none()
    }

    /// Slot index the next write targets.
    pub fn write_slot(&self) -> u64 {
        self.writing_ptr
    }

    /// Slot index the next read targets, if a read is pending.
    pub fn read_slot(&self) -> Option<u64> {
        if self.reads_complete() {
            return None;
        }
        match self.units.role {
            Role::Fifo => Some(self.fifo_read_ptr),
            Role::Resident => Some((self.offset_slot + self.pattern_ptr) % self.depth()),
        }
    }

    /// Tag the next read is expected to deliver.
    pub fn expected_read_tag(&self) -> Option<u64> {
        if self.reads_complete() {
            return None;
        }
        match self.units.role {
            Role::Fifo => None, // FIFO order: whatever arrives
            Role::Resident => Some(self.offset_units + self.pattern_ptr),
        }
    }

    /// Whether the next read's data is present.
    pub fn read_data_ready(&self) -> bool {
        match self.read_slot() {
            None => false,
            Some(s) => match &self.slots[s as usize] {
                None => false,
                Some(slot) => match self.expected_read_tag() {
                    // Resident reads must see the exact expected tag —
                    // a stale word means a scheduling bug.
                    Some(t) => slot.tag == t,
                    None => true,
                },
            },
        }
    }

    /// Port arbitration: may a read proceed in a cycle where a write to
    /// `write_slot` does (or does not) occur? Implements write-over-read
    /// for single-ported banks and the same-address exclusion for
    /// dual-ported macros (§4.1.2).
    pub fn read_port_free(&self, write_this_cycle: bool) -> bool {
        let Some(rs) = self.read_slot() else { return false };
        if !write_this_cycle {
            return true;
        }
        let ws = self.write_slot();
        match self.ports() {
            PortKind::Dual => rs != ws,
            PortKind::Single => {
                if self.banks() == 2 {
                    self.bank_of(rs) != self.bank_of(ws)
                } else {
                    false // write wins the single port
                }
            }
        }
    }

    /// Commit a write of `slot` at the writing pointer. Caller must have
    /// checked `write_slot_free` and the toggle.
    pub fn commit_write(&mut self, incoming: Slot) -> Result<()> {
        let ws = self.writing_ptr as usize;
        if self.slots[ws].is_some() {
            return Err(Error::Integrity {
                cycle: 0,
                msg: format!("write to occupied slot {ws} (tag {})", incoming.tag),
            });
        }
        self.slots[ws] = Some(incoming);
        self.occupied += 1;
        self.writing_ptr = (self.writing_ptr + 1) % self.depth();
        self.writes_done += 1;
        self.we_last = true;
        Ok(())
    }

    /// Mark a cycle in which no write fired (releases the toggle).
    pub fn no_write_this_cycle(&mut self) {
        self.we_last = false;
    }

    /// Commit the pending read: pops (FIFO) or copies (resident) the slot,
    /// advances pattern state, applies the inter-cycle shift (clearing
    /// shifted-out slots), and loads `out_reg`.
    pub fn commit_read(&mut self, cycle: u64) -> Result<Slot> {
        let rs = self
            .read_slot()
            .ok_or_else(|| Error::Integrity { cycle, msg: "read with no reads pending".into() })?
            as usize;
        let slot = self.slots[rs].ok_or_else(|| Error::Integrity {
            cycle,
            msg: format!("read from empty slot {rs}"),
        })?;
        match self.units.role {
            Role::Fifo => {
                // Clear after read (§4.1.2).
                self.slots[rs] = None;
                self.occupied -= 1;
                self.fifo_read_ptr = (self.fifo_read_ptr + 1) % self.depth();
            }
            Role::Resident => {
                let expect = self.offset_units + self.pattern_ptr;
                if slot.tag != expect {
                    return Err(Error::Integrity {
                        cycle,
                        msg: format!("resident read tag {} != expected {expect}", slot.tag),
                    });
                }
                self.pattern_ptr += 1;
                if self.pattern_ptr == self.units.cycle_length {
                    // Listing 1 lines 19–28: cycle complete.
                    self.pattern_ptr = 0;
                    self.skips += 1;
                    if self.skips > self.units.skip_shift {
                        self.skips = 0;
                        let s = self.units.inter_cycle_shift.min(self.units.cycle_length);
                        // Clear the slots shifted out of the window so new
                        // words can be preloaded into them.
                        for i in 0..s {
                            let idx = ((self.offset_slot + i) % self.depth()) as usize;
                            if self.slots[idx].is_some() {
                                self.slots[idx] = None;
                                self.occupied -= 1;
                            }
                        }
                        self.offset_slot = (self.offset_slot + s) % self.depth();
                        self.offset_units += s;
                    }
                }
            }
        }
        self.reads_done += 1;
        self.out_reg = Some(slot);
        Ok(slot)
    }

    /// Peek a slot (tests / integrity checks).
    pub fn slot(&self, idx: u64) -> Option<&Slot> {
        self.slots[idx as usize].as_ref()
    }

    /// Fault injection: flip one payload bit of a stored word. Returns
    /// false if the slot is empty or out of range.
    pub fn corrupt_slot(&mut self, idx: u64, bit: u32) -> bool {
        corrupt_in(&mut self.slots, idx, bit)
    }

    /// Non-mutating fault probe: the current value of one stored payload
    /// bit, or `None` if an upset there would be vacant.
    pub fn probe_slot_bit(&self, idx: u64, bit: u32) -> Option<bool> {
        probe_in(&self.slots, idx, bit)
    }

    /// Capture the level's run state (see [`LevelCheckpoint`]).
    pub fn snapshot(&self) -> LevelCheckpoint {
        LevelCheckpoint {
            slots: self.slots.clone(),
            occupied: self.occupied,
            writing_ptr: self.writing_ptr,
            pattern_ptr: self.pattern_ptr,
            offset_slot: self.offset_slot,
            offset_units: self.offset_units,
            skips: self.skips,
            fifo_read_ptr: self.fifo_read_ptr,
            we_last: self.we_last,
            out_reg: self.out_reg,
            writes_done: self.writes_done,
            reads_done: self.reads_done,
        }
    }

    /// Restore a [`LevelCheckpoint`] taken on a level armed for the same
    /// (config, program) pair. Reuses the slot-storage allocation.
    pub fn restore(&mut self, ck: &LevelCheckpoint) {
        self.slots.clone_from(&ck.slots);
        self.occupied = ck.occupied;
        self.writing_ptr = ck.writing_ptr;
        self.pattern_ptr = ck.pattern_ptr;
        self.offset_slot = ck.offset_slot;
        self.offset_units = ck.offset_units;
        self.skips = ck.skips;
        self.fifo_read_ptr = ck.fifo_read_ptr;
        self.we_last = ck.we_last;
        self.out_reg = ck.out_reg;
        self.writes_done = ck.writes_done;
        self.reads_done = ck.reads_done;
    }
}

impl Stage for Level {
    /// Handshake: a word is presented in the out-register for the
    /// downstream level (or the OSR / accelerator).
    fn ready_out(&self) -> bool {
        self.out_reg.is_some()
    }

    /// Handshake: the slot targeted by the writing pointer is free. The
    /// write-enable toggle and program completion are scheduling
    /// concerns, owned by the composing core.
    fn ready_in(&self, _width: u32) -> bool {
        self.write_slot_free()
    }

    /// All slot/pointer mutation is handshake-driven (write/read
    /// commits), with one exception: a set write-enable toggle is
    /// released by the very next no-write cycle (`no_write_this_cycle`),
    /// so the level is mid-stride and the next edge changes it. A
    /// released toggle leaves every register inert until a handshake.
    fn quiescent_for(&self) -> u64 {
        if self.we_last {
            0
        } else {
            u64::MAX
        }
    }

    /// Injectable state: the stored slot words ([`FaultSite::Slot`]).
    fn inject(&mut self, site: &FaultSite) -> bool {
        match *site {
            FaultSite::Slot { slot, bit, kind } => perturb_in(&mut self.slots, slot, bit, kind),
            _ => false,
        }
    }
}

/// The per-level datapath dispatcher: one hierarchy slot holding whichever
/// [`Stage`] implementation the configured [`LevelKind`] selects. This is
/// the *single* explicit dispatch point — the composing core and every
/// model above it call through these methods and stay kind-agnostic.
#[derive(Debug)]
pub enum LevelStage {
    /// Standard banked level (Listing 1 MCU).
    Standard(Level),
    /// Double-buffered ping-pong level.
    DoubleBuffered(PingPongLevel),
}

/// Captured run state of one [`LevelStage`], tagged by level kind so a
/// restore onto the wrong variant is a checked error rather than silent
/// corruption.
#[derive(Debug, Clone, PartialEq)]
pub enum LevelStageCheckpoint {
    /// Standard banked level state.
    Standard(LevelCheckpoint),
    /// Double-buffered ping-pong level state.
    DoubleBuffered(super::pingpong::PingPongCheckpoint),
}

impl LevelStageCheckpoint {
    /// Serialize for the checkpoint wire format: a kind tag, then the
    /// variant's state.
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        match self {
            LevelStageCheckpoint::Standard(c) => {
                w.put_u8(0);
                c.wire_write(w);
            }
            LevelStageCheckpoint::DoubleBuffered(c) => {
                w.put_u8(1);
                c.wire_write(w);
            }
        }
    }

    /// Checked decode: the kind tag must match the configured level kind
    /// (a mismatch means the bytes do not belong to this configuration).
    pub(crate) fn wire_read(r: &mut ByteReader<'_>, cfg: &LevelConfig) -> Result<Self> {
        let tag = r.get_u8()?;
        match (tag, &cfg.kind) {
            (0, LevelKind::Standard { .. }) => {
                Ok(LevelStageCheckpoint::Standard(LevelCheckpoint::wire_read(r, cfg)?))
            }
            (1, LevelKind::DoubleBuffered) => Ok(LevelStageCheckpoint::DoubleBuffered(
                super::pingpong::PingPongCheckpoint::wire_read(r, cfg)?,
            )),
            (0 | 1, _) => Err(Error::Parse(
                "wire: level checkpoint kind does not match the configured level kind".into(),
            )),
            _ => Err(Error::Parse(format!("wire: unknown level checkpoint kind tag {tag}"))),
        }
    }
}

impl LevelStage {
    /// Construct the implementation `cfg.kind` selects.
    pub fn new(cfg: &LevelConfig, units: LevelUnits) -> Self {
        match cfg.kind {
            LevelKind::Standard { .. } => LevelStage::Standard(Level::new(cfg.clone(), units)),
            LevelKind::DoubleBuffered => {
                LevelStage::DoubleBuffered(PingPongLevel::new(cfg.clone(), units))
            }
        }
    }

    /// In-place re-arm; when the new config changes the level *kind* the
    /// variant is swapped while recycling the slot allocation. Either way
    /// the post-state is bit-identical to a fresh [`Self::new`].
    pub fn rearm(&mut self, cfg: &LevelConfig, units: LevelUnits) {
        let same_kind = matches!(
            (&*self, cfg.kind),
            (LevelStage::Standard(_), LevelKind::Standard { .. })
                | (LevelStage::DoubleBuffered(_), LevelKind::DoubleBuffered)
        );
        if same_kind {
            match self {
                LevelStage::Standard(l) => l.rearm(cfg, units),
                LevelStage::DoubleBuffered(p) => p.rearm(cfg, units),
            }
            return;
        }
        // Kind change: move the slot storage across variants. The
        // placeholder is a zero-capacity level, so the swap allocates
        // nothing beyond what `from_storage` reuses.
        let placeholder = LevelConfig {
            macro_name: String::new(),
            kind: LevelKind::Standard { banks: 1, ports: PortKind::Single },
            word_width: 1,
            ram_depth: 0,
            protection: Protection::None,
        };
        let old = std::mem::replace(
            self,
            LevelStage::Standard(Level::from_storage(Vec::new(), placeholder, units)),
        );
        let storage = match old {
            LevelStage::Standard(l) => l.into_storage(),
            LevelStage::DoubleBuffered(p) => p.into_storage(),
        };
        *self = match cfg.kind {
            LevelKind::Standard { .. } => {
                LevelStage::Standard(Level::from_storage(storage, cfg.clone(), units))
            }
            LevelKind::DoubleBuffered => {
                LevelStage::DoubleBuffered(PingPongLevel::from_storage(storage, cfg.clone(), units))
            }
        };
    }

    /// The static configuration.
    pub fn cfg(&self) -> &LevelConfig {
        match self {
            LevelStage::Standard(l) => &l.cfg,
            LevelStage::DoubleBuffered(p) => &p.cfg,
        }
    }

    /// Word width of the level in bits.
    pub fn word_width(&self) -> u32 {
        self.cfg().word_width
    }

    /// Whether all programmed writes have been committed.
    pub fn writes_complete(&self) -> bool {
        match self {
            LevelStage::Standard(l) => l.writes_complete(),
            LevelStage::DoubleBuffered(p) => p.writes_complete(),
        }
    }

    /// Whether all programmed reads have been committed.
    pub fn reads_complete(&self) -> bool {
        match self {
            LevelStage::Standard(l) => l.reads_complete(),
            LevelStage::DoubleBuffered(p) => p.reads_complete(),
        }
    }

    /// Write pacing: the §4.1.4 toggle for standard levels; ping-pong
    /// fill controllers latch on their own handshake and are never
    /// toggle-limited.
    pub fn write_allowed_by_toggle(&self) -> bool {
        match self {
            LevelStage::Standard(l) => l.write_allowed_by_toggle(),
            LevelStage::DoubleBuffered(_) => true,
        }
    }

    /// Whether the next read's data is present.
    pub fn read_data_ready(&self) -> bool {
        match self {
            LevelStage::Standard(l) => l.read_data_ready(),
            LevelStage::DoubleBuffered(p) => p.read_data_ready(),
        }
    }

    /// Port arbitration for a read given a concurrent write.
    pub fn read_port_free(&self, write_this_cycle: bool) -> bool {
        match self {
            LevelStage::Standard(l) => l.read_port_free(write_this_cycle),
            LevelStage::DoubleBuffered(p) => p.read_port_free(write_this_cycle),
        }
    }

    /// Commit a write (see the implementations for preconditions).
    pub fn commit_write(&mut self, incoming: Slot) -> Result<()> {
        match self {
            LevelStage::Standard(l) => l.commit_write(incoming),
            LevelStage::DoubleBuffered(p) => p.commit_write(incoming),
        }
    }

    /// Mark a cycle in which no write fired.
    pub fn no_write_this_cycle(&mut self) {
        match self {
            LevelStage::Standard(l) => l.no_write_this_cycle(),
            LevelStage::DoubleBuffered(p) => p.no_write_this_cycle(),
        }
    }

    /// Commit the pending read.
    pub fn commit_read(&mut self, cycle: u64) -> Result<Slot> {
        match self {
            LevelStage::Standard(l) => l.commit_read(cycle),
            LevelStage::DoubleBuffered(p) => p.commit_read(cycle),
        }
    }

    /// Whether a word is presented in the out-register.
    pub fn has_out_reg(&self) -> bool {
        match self {
            LevelStage::Standard(l) => l.out_reg.is_some(),
            LevelStage::DoubleBuffered(p) => p.out_reg.is_some(),
        }
    }

    /// Consume the out-register (the downstream write's data).
    pub fn take_out_reg(&mut self) -> Option<Slot> {
        match self {
            LevelStage::Standard(l) => l.out_reg.take(),
            LevelStage::DoubleBuffered(p) => p.out_reg.take(),
        }
    }

    /// Drop the out-register (last level: the word went to the OSR /
    /// output sink instead of a downstream level).
    pub fn clear_out_reg(&mut self) {
        match self {
            LevelStage::Standard(l) => l.out_reg = None,
            LevelStage::DoubleBuffered(p) => p.out_reg = None,
        }
    }

    /// Fault injection: flip one payload bit of a stored word.
    pub fn corrupt_slot(&mut self, idx: u64, bit: u32) -> bool {
        match self {
            LevelStage::Standard(l) => l.corrupt_slot(idx, bit),
            LevelStage::DoubleBuffered(p) => p.corrupt_slot(idx, bit),
        }
    }

    /// Non-mutating fault probe: the current value of one stored payload
    /// bit, or `None` if an upset there would be vacant.
    pub fn probe_slot_bit(&self, idx: u64, bit: u32) -> Option<bool> {
        match self {
            LevelStage::Standard(l) => l.probe_slot_bit(idx, bit),
            LevelStage::DoubleBuffered(p) => p.probe_slot_bit(idx, bit),
        }
    }

    /// Capture the armed implementation's run state.
    pub fn snapshot(&self) -> LevelStageCheckpoint {
        match self {
            LevelStage::Standard(l) => LevelStageCheckpoint::Standard(l.snapshot()),
            LevelStage::DoubleBuffered(p) => LevelStageCheckpoint::DoubleBuffered(p.snapshot()),
        }
    }

    /// Restore a checkpoint taken on a stage armed for the same (config,
    /// program) pair. A kind mismatch (which the hierarchy-level config
    /// check rules out) is reported instead of corrupting state.
    pub fn restore(&mut self, ck: &LevelStageCheckpoint) -> Result<()> {
        match (self, ck) {
            (LevelStage::Standard(l), LevelStageCheckpoint::Standard(c)) => {
                l.restore(c);
                Ok(())
            }
            (LevelStage::DoubleBuffered(p), LevelStageCheckpoint::DoubleBuffered(c)) => {
                p.restore(c);
                Ok(())
            }
            _ => Err(Error::Config(
                "checkpoint level kind does not match the armed level".into(),
            )),
        }
    }
}

impl Stage for LevelStage {
    fn ready_out(&self) -> bool {
        match self {
            LevelStage::Standard(l) => l.ready_out(),
            LevelStage::DoubleBuffered(p) => p.ready_out(),
        }
    }

    fn ready_in(&self, width: u32) -> bool {
        match self {
            LevelStage::Standard(l) => l.ready_in(width),
            LevelStage::DoubleBuffered(p) => p.ready_in(width),
        }
    }

    fn quiescent_for(&self) -> u64 {
        match self {
            LevelStage::Standard(l) => l.quiescent_for(),
            LevelStage::DoubleBuffered(p) => p.quiescent_for(),
        }
    }

    fn inject(&mut self, site: &FaultSite) -> bool {
        match self {
            LevelStage::Standard(l) => l.inject(site),
            LevelStage::DoubleBuffered(p) => p.inject(site),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LevelKind, PortKind};
    use crate::mem::mcu::LevelUnits;
    use crate::util::bitword::Word;

    fn mk(depth: u64, banks: u32, ports: u32, role: Role, l: u64, s: u64) -> Level {
        let cfg = LevelConfig {
            macro_name: "t".into(),
            kind: LevelKind::Standard {
                banks,
                ports: if ports == 2 { PortKind::Dual } else { PortKind::Single },
            },
            word_width: 32,
            ram_depth: depth / banks as u64,
            protection: Protection::None,
        };
        let units = LevelUnits {
            role,
            cycle_length: l,
            inter_cycle_shift: s,
            skip_shift: 0,
            total_writes: 1_000,
            total_reads: 1_000,
        };
        Level::new(cfg, units)
    }

    fn w(tag: u64) -> Slot {
        Slot { tag, word: Word::from_u64(tag * 7 + 1, 32) }
    }

    #[test]
    fn fifo_pops_in_arrival_order_and_clears() {
        let mut lv = mk(4, 1, 1, Role::Fifo, 4, 0);
        lv.commit_write(w(10)).unwrap();
        lv.no_write_this_cycle();
        lv.commit_write(w(11)).unwrap();
        assert_eq!(lv.occupied(), 2);
        let a = lv.commit_read(0).unwrap();
        assert_eq!(a.tag, 10);
        let b = lv.commit_read(1).unwrap();
        assert_eq!(b.tag, 11);
        assert_eq!(lv.occupied(), 0, "cleared after read");
        assert!(!lv.read_data_ready());
    }

    #[test]
    fn write_toggle_alternates() {
        let mut lv = mk(4, 1, 1, Role::Fifo, 4, 0);
        assert!(lv.write_allowed_by_toggle());
        lv.commit_write(w(0)).unwrap();
        assert!(!lv.write_allowed_by_toggle(), "no write two cycles in a row");
        lv.no_write_this_cycle();
        assert!(lv.write_allowed_by_toggle());
    }

    #[test]
    fn single_port_write_over_read() {
        let mut lv = mk(4, 1, 1, Role::Fifo, 4, 0);
        lv.commit_write(w(0)).unwrap();
        lv.no_write_this_cycle();
        // Read wants the port; a concurrent write blocks it (1 bank).
        assert!(lv.read_data_ready());
        assert!(!lv.read_port_free(true), "write wins the single port");
        assert!(lv.read_port_free(false));
    }

    #[test]
    fn dual_bank_parallel_access() {
        let mut lv = mk(4, 2, 1, Role::Fifo, 4, 0);
        lv.commit_write(w(0)).unwrap(); // slot 0 (bank 0)
        lv.no_write_this_cycle();
        // Next write targets slot 1 (bank 1); read targets slot 0 (bank 0).
        assert!(lv.read_port_free(true), "different banks proceed together");
        // Drain slot 0; next read slot 1, next write slot 1... conflict.
        lv.commit_read(0).unwrap();
        lv.commit_write(w(1)).unwrap(); // slot 1
        lv.no_write_this_cycle();
        // read slot = 1 (bank 1), write slot = 2 (bank 0): free.
        assert!(lv.read_port_free(true));
    }

    #[test]
    fn dual_port_same_address_excluded() {
        let mut lv = mk(4, 1, 2, Role::Fifo, 4, 0);
        lv.commit_write(w(0)).unwrap();
        lv.no_write_this_cycle();
        lv.commit_read(0).unwrap();
        lv.commit_write(w(1)).unwrap();
        lv.no_write_this_cycle();
        lv.commit_read(1).unwrap();
        lv.commit_write(w(2)).unwrap();
        lv.no_write_this_cycle();
        lv.commit_read(2).unwrap();
        lv.commit_write(w(3)).unwrap();
        lv.no_write_this_cycle();
        // read slot 3, write slot 3 -> wrap: writing_ptr = 0? After 4 writes
        // writing_ptr wrapped to 0; read slot = 3; no conflict.
        assert!(lv.read_port_free(true));
    }

    #[test]
    fn resident_replays_window_and_shifts() {
        let mut lv = mk(8, 1, 2, Role::Resident, 4, 2);
        for t in 0..6 {
            lv.commit_write(w(t)).unwrap();
            lv.no_write_this_cycle();
        }
        // First cycle: tags 0..4.
        let tags: Vec<u64> = (0..4).map(|c| lv.commit_read(c).unwrap().tag).collect();
        assert_eq!(tags, vec![0, 1, 2, 3]);
        // Shift by 2 applied; slots of tags 0,1 cleared.
        assert_eq!(lv.occupied(), 4);
        assert!(lv.slot(0).is_none());
        assert!(lv.slot(1).is_none());
        // Second cycle: tags 2..6.
        let tags: Vec<u64> = (0..4).map(|c| lv.commit_read(c).unwrap().tag).collect();
        assert_eq!(tags, vec![2, 3, 4, 5]);
    }

    #[test]
    fn resident_requires_exact_tag() {
        let mut lv = mk(4, 1, 2, Role::Resident, 4, 0);
        // Write tag 5 first -> resident expects tag 0 at slot 0... the slot
        // holds tag 5 but read expects 0: data not "ready".
        lv.commit_write(w(5)).unwrap();
        assert!(!lv.read_data_ready());
    }

    #[test]
    fn resident_prefetch_headroom() {
        // depth 8, window 4: up to 4 future words can be preloaded.
        let mut lv = mk(8, 1, 2, Role::Resident, 4, 1);
        for t in 0..8 {
            lv.commit_write(w(t)).unwrap();
            lv.no_write_this_cycle();
        }
        assert_eq!(lv.occupied(), 8);
        assert!(!lv.write_slot_free(), "full: writing ptr wrapped onto live slot");
        // After one full cycle the shift clears one slot.
        for c in 0..4 {
            lv.commit_read(c).unwrap();
        }
        assert_eq!(lv.occupied(), 7);
        assert!(lv.write_slot_free());
    }

    #[test]
    fn rearm_restores_fresh_state() {
        let mut lv = mk(8, 1, 2, Role::Resident, 4, 2);
        for t in 0..6 {
            lv.commit_write(w(t)).unwrap();
            lv.no_write_this_cycle();
        }
        for c in 0..4 {
            lv.commit_read(c).unwrap();
        }
        // Re-arm with a smaller depth/different role: identical to new.
        let small = mk(4, 1, 1, Role::Fifo, 4, 0);
        lv.rearm(&small.cfg, small.units);
        assert_eq!(lv.depth(), 4);
        assert_eq!(lv.occupied(), 0);
        assert!(lv.out_reg.is_none());
        assert!(lv.write_allowed_by_toggle());
        assert_eq!(lv.write_slot(), 0);
        assert!(!lv.read_data_ready());
        // And it behaves like a fresh FIFO.
        lv.commit_write(w(10)).unwrap();
        assert_eq!(lv.commit_read(0).unwrap().tag, 10);
    }

    #[test]
    fn write_to_occupied_slot_is_integrity_error() {
        let mut lv = mk(2, 1, 1, Role::Fifo, 2, 0);
        lv.commit_write(w(0)).unwrap();
        lv.no_write_this_cycle();
        lv.commit_write(w(1)).unwrap();
        lv.no_write_this_cycle();
        assert!(lv.commit_write(w(2)).is_err(), "wrap onto occupied slot");
    }

    #[test]
    fn stage_dispatch_swaps_kind_on_rearm() {
        // A LevelStage re-armed across a kind change behaves exactly like
        // a freshly constructed stage of the new kind.
        let std_cfg = mk(4, 1, 1, Role::Fifo, 4, 0).cfg;
        let pp_cfg = LevelConfig {
            macro_name: "pp".into(),
            kind: LevelKind::DoubleBuffered,
            word_width: 32,
            ram_depth: 4,
            protection: Protection::None,
        };
        let units = LevelUnits {
            role: Role::Fifo,
            cycle_length: 4,
            inter_cycle_shift: 0,
            skip_shift: 0,
            total_writes: 1_000,
            total_reads: 1_000,
        };
        let mut stage = LevelStage::new(&std_cfg, units);
        assert!(matches!(stage, LevelStage::Standard(_)));
        assert!(stage.write_allowed_by_toggle());
        stage.commit_write(w(0)).unwrap();
        assert!(!stage.write_allowed_by_toggle(), "standard toggle active");
        // Switch to ping-pong.
        stage.rearm(&pp_cfg, units);
        assert!(matches!(stage, LevelStage::DoubleBuffered(_)));
        assert!(stage.write_allowed_by_toggle(), "no toggle on ping-pong");
        assert!(!stage.read_data_ready());
        stage.commit_write(w(1)).unwrap();
        stage.commit_write(w(2)).unwrap(); // half full -> swap
        assert!(stage.read_data_ready());
        assert_eq!(stage.commit_read(0).unwrap().tag, 1);
        // And back to standard, fresh again.
        stage.rearm(&std_cfg, units);
        assert!(matches!(stage, LevelStage::Standard(_)));
        assert!(!stage.read_data_ready());
        stage.commit_write(w(3)).unwrap();
        assert_eq!(stage.commit_read(0).unwrap().tag, 3);
    }
}

//! The paper's system: the configurable memory hierarchy (§4), with the
//! §6 future-work double-buffered level kind.
//!
//! ```text
//!  off-chip ──► [OffChipMemory] ──► [InputBuffer] ──CDC──► [LevelStage 0] ──► … ──► [LevelStage N-1] ──► [OSR] ──► accelerator
//!                (ext. clock)        (ext. clock)            (internal clock domain)
//!
//!  LevelStage ::= Standard [Level]            1–2 banks, single/dual ported, Listing 1 MCU
//!               | DoubleBuffered [PingPongLevel]   ┌───────────┐
//!                                     fill ───────►│ half A    │──┐
//!                                         (swap on │───────────│  ├──► drain
//!                                     fill-full /  │ half B    │──┘
//!                                     drain-empty) └───────────┘
//! ```
//!
//! * [`OffChipMemory`] — latency-modelled reader of the global address
//!   space; payloads are a deterministic function of the address so data
//!   integrity is checked end to end.
//! * [`InputBuffer`] — register file in the external clock domain; packs
//!   off-chip words to the level-0 word width and crosses the CDC with the
//!   `buffer_full` / `reset_buffer` handshake of Figure 3.
//! * [`LevelStage`] — the per-level dispatcher over the configured
//!   [`crate::config::LevelKind`]: a standard [`Level`] (1–2 banks,
//!   single- or dual-ported, with the MCU register state of Listing 1) or
//!   a double-buffered [`PingPongLevel`] (two half-depth single-ported
//!   macros with a ping-pong swap).
//! * [`Osr`] — the output shift register (§4.1.5).
//! * [`Hierarchy`] — thin composition of the above (each implements
//!   [`crate::sim::engine::Stage`]) driven by the
//!   [`crate::sim::engine::Engine`], which owns the clock interleaving,
//!   deadlock guard, output verification and waveform storage; produces
//!   [`crate::sim::SimStats`]. Every component carries a
//!   `snapshot()`/`restore()` pair, composed by
//!   [`Hierarchy::snapshot`]/[`Hierarchy::restore`] into a
//!   [`HierarchyCheckpoint`] — a suspended run resumes bit-identically on
//!   any hierarchy armed for the same (config, program) pair, which is
//!   what the successive-halving DSE uses to carry candidates across
//!   rungs without re-paying screened cycles. Checkpoints additionally
//!   serialize to a versioned binary format ([`wire`]) so the sharded
//!   DSE can ship them between coordinator and worker processes.
//! * [`FunctionalModel`] — untimed oracle: expected output stream and
//!   analytic cycle bounds, used by differential and property tests.
//!
//! ## Timing semantics (derived from §4.1, Listing 1 and Figure 4)
//!
//! 1. **Write-enable toggling**: a standard level's write strobe fires at
//!    most every second internal cycle — a write requires the *preceding*
//!    level to have presented a word with an active read in the prior
//!    cycle.
//! 2. **Write-over-read**: on single-ported banks a ready write wins the
//!    port; the pattern read is postponed one cycle (Fig 4, address 8/9).
//! 3. **Input-buffer handshake**: `buffer_full` needs one internal cycle of
//!    synchronization; the MCU writes the buffered word into level 0 in the
//!    next free write slot; `reset_buffer` then needs one external edge to
//!    restart filling. With equal clocks the steady-state cadence is one
//!    level-0 word every **3 internal cycles** — this single constant
//!    reproduces the paper's "optimal while the inter-cycle shift is below
//!    one-third of the cycle length" knee (Fig 8), the worst case of one
//!    output every three cycles, and the case study's three accelerator
//!    cycles per 128-bit weight (§5.3.2).
//! 4. **Residency**: a standard level whose capacity holds the full
//!    pattern window replays it internally (data reuse); smaller levels
//!    downstream stream words through, clearing each slot after its read
//!    (§4.1.2 "higher levels do not retain subsets").
//! 5. **Ping-pong swap handshake** (double-buffered levels): writes land
//!    in the *fill* half, reads are served FIFO from the *drain* half, so
//!    a write and a read proceed in the same cycle on single-ported
//!    macros — and the §4.1.4 toggle does not apply (the fill controller
//!    latches on its own handshake, like the input-buffer path into
//!    level 0). The halves swap when the drain half runs empty and the
//!    fill half is ready (full, or holding the program's final truncated
//!    buffer). The swap is registered: read enables always see the
//!    pre-swap occupancy, and a swap committed this cycle takes effect at
//!    the next cycle boundary. Because drained slots are cleared, a
//!    double-buffered level can never be the resident level — it streams
//!    every pattern family instead (at one word per cycle once fed at
//!    rate, versus the standard level's toggle-limited word every two
//!    cycles).
//!
//! ## Quiescence horizons (event-horizon fast-forward)
//!
//! Every component additionally answers the engine's quiescence query
//! ([`crate::sim::engine::Stage::quiescent_for`]): for how many upcoming
//! edges in its own clock domain its registered state provably cannot
//! change, *absent port handshakes*. What each may promise follows from
//! the RTL it models:
//!
//! * [`Level`] — all slot/pointer state moves on write/read handshakes;
//!   the one self-timed register is the §4.1.4 write-enable toggle, which
//!   a no-write cycle releases: a set toggle means horizon 0, a released
//!   one means inert-until-handshake.
//! * [`PingPongLevel`] — fully handshake-driven (the swap commits inside
//!   the committing access): always inert absent handshakes.
//! * [`InputBuffer`] — split per domain: the internal-domain horizon is
//!   the two-flop `buffer_full` synchronizer (settled = inert, mid-flight
//!   = horizon 0); the external-domain horizon
//!   ([`InputBuffer::fill_horizon`]) mirrors the fill engine's decision
//!   order — busy (reset landing / request issuing), waiting on the
//!   off-chip delivery at a known external cycle, or idle until the
//!   internal domain consumes.
//! * [`OffChipMemory`] — passive between handshakes; its time-dependent
//!   contribution is [`OffChipMemory::next_delivery_at`], the external
//!   cycle at which a read with `k` cycles left in flight lands.
//! * [`Osr`] — a bit-FIFO mutated only by push/shift handshakes.
//!
//! The composition lives in the hierarchy core's `horizon`
//! (`mem::hierarchy`): the core is quiescent only when *no* internal edge
//! activity is possible — synchronizer settled, no toggle pending, no
//! presented word a level could latch (or wait-count), no serviceable
//! read, no OSR shift — and then the whole-core horizon is the fill
//! engine's external wake-up. CDC edges need no special casing: a
//! quiescent span by definition carries nothing across the crossing, and
//! the span ends *at* the external edge that next delivers, so the
//! synchronizer's two-cycle discipline is ticked out naively as always.
//! Checkpoints compose with skipping transparently — a skipped span
//! changes no component state, so [`Hierarchy::snapshot`] at any cycle
//! boundary equals the tick-by-tick machine's snapshot
//! (`tests/engine_ff.rs` asserts this across the matrix), and the
//! `force_naive` oracle switch is session state, never checkpointed.
//!
//! ## Fault injection and protection
//!
//! Every stateful component implements the
//! [`crate::sim::engine::Stage::inject`] hook: a deterministic upset
//! ([`crate::sim::fault::FaultSite`]) lands at an exact
//! (component, cycle, bit) coordinate scheduled by a
//! [`crate::sim::fault::FaultPlan`] armed via [`Hierarchy::arm_faults`].
//! Injectable state: standard [`Level`] slots, [`PingPongLevel`] halves
//! (entry indices `[0, half_depth)` address half 0), the
//! [`InputBuffer`]'s FIFO, fill register, and CDC synchronizer flops,
//! the [`Osr`] bit-FIFO, and the [`OffChipMemory`] in-flight pipeline
//! (address flips plus *timing* faults: delayed or dropped deliveries).
//! The hook contract is strict inertness: with no plan armed — or an
//! empty one — runs, stats, outputs, and checkpoint bytes are
//! bitwise-identical to a hierarchy that has no fault machinery at all
//! (`tests/fault.rs` pins this per pattern family × level kind). A
//! pending plan pins the quiescence horizon to `Active` so fast-forward
//! can never skip over a scheduled upset, and checkpoints never carry a
//! plan — a restored run is fault-free unless re-armed.
//!
//! **Protection contract** ([`crate::config::Protection`], per level):
//! upsets against a protected level are resolved at injection time from
//! the stored word the upset would have hit. `None` mutates state (the
//! run sees the corruption); `Parity` detects a single-bit upset — the
//! run is flagged in the [`crate::sim::fault::FaultReport`] but the data
//! path stays clean, so a parity-protected level can never corrupt
//! silently; `Secded` corrects it — outputs are bit-identical to
//! fault-free. An upset that would not change a stored bit (empty slot,
//! out-of-range bit, stuck-at matching the value) is *vacant* under any
//! protection. The storage and codec overheads are modeled in
//! [`crate::cost::sram`] (extra check-bit columns, encode/decode
//! energy/area); the codec is pipelined and adds no cycles.

pub mod functional;
pub mod hierarchy;
pub mod input_buffer;
pub mod level;
pub mod mcu;
pub mod offchip;
pub mod osr;
pub mod pingpong;
pub mod wire;

pub use functional::FunctionalModel;
pub use hierarchy::{BudgetedRun, Hierarchy, HierarchyCheckpoint, OutputWord, RunResult};
pub use input_buffer::InputBuffer;
pub use level::{Level, LevelRole, LevelStage};
pub use mcu::{FetchPlan, McuProgram};
pub use offchip::OffChipMemory;
pub use osr::Osr;
pub use pingpong::PingPongLevel;
pub use wire::{decode_checkpoint, encode_checkpoint};

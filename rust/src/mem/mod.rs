//! The paper's system: the configurable memory hierarchy (§4), with the
//! §6 future-work double-buffered level kind.
//!
//! ```text
//!  off-chip ──► [OffChipMemory] ──► [InputBuffer] ──CDC──► [LevelStage 0] ──► … ──► [LevelStage N-1] ──► [OSR] ──► accelerator
//!                (ext. clock)        (ext. clock)            (internal clock domain)
//!
//!  LevelStage ::= Standard [Level]            1–2 banks, single/dual ported, Listing 1 MCU
//!               | DoubleBuffered [PingPongLevel]   ┌───────────┐
//!                                     fill ───────►│ half A    │──┐
//!                                         (swap on │───────────│  ├──► drain
//!                                     fill-full /  │ half B    │──┘
//!                                     drain-empty) └───────────┘
//! ```
//!
//! * [`OffChipMemory`] — latency-modelled reader of the global address
//!   space; payloads are a deterministic function of the address so data
//!   integrity is checked end to end.
//! * [`InputBuffer`] — register file in the external clock domain; packs
//!   off-chip words to the level-0 word width and crosses the CDC with the
//!   `buffer_full` / `reset_buffer` handshake of Figure 3.
//! * [`LevelStage`] — the per-level dispatcher over the configured
//!   [`crate::config::LevelKind`]: a standard [`Level`] (1–2 banks,
//!   single- or dual-ported, with the MCU register state of Listing 1) or
//!   a double-buffered [`PingPongLevel`] (two half-depth single-ported
//!   macros with a ping-pong swap).
//! * [`Osr`] — the output shift register (§4.1.5).
//! * [`Hierarchy`] — thin composition of the above (each implements
//!   [`crate::sim::engine::Stage`]) driven by the
//!   [`crate::sim::engine::Engine`], which owns the clock interleaving,
//!   deadlock guard, output verification and waveform storage; produces
//!   [`crate::sim::SimStats`]. Every component carries a
//!   `snapshot()`/`restore()` pair, composed by
//!   [`Hierarchy::snapshot`]/[`Hierarchy::restore`] into a
//!   [`HierarchyCheckpoint`] — a suspended run resumes bit-identically on
//!   any hierarchy armed for the same (config, program) pair, which is
//!   what the successive-halving DSE uses to carry candidates across
//!   rungs without re-paying screened cycles.
//! * [`FunctionalModel`] — untimed oracle: expected output stream and
//!   analytic cycle bounds, used by differential and property tests.
//!
//! ## Timing semantics (derived from §4.1, Listing 1 and Figure 4)
//!
//! 1. **Write-enable toggling**: a standard level's write strobe fires at
//!    most every second internal cycle — a write requires the *preceding*
//!    level to have presented a word with an active read in the prior
//!    cycle.
//! 2. **Write-over-read**: on single-ported banks a ready write wins the
//!    port; the pattern read is postponed one cycle (Fig 4, address 8/9).
//! 3. **Input-buffer handshake**: `buffer_full` needs one internal cycle of
//!    synchronization; the MCU writes the buffered word into level 0 in the
//!    next free write slot; `reset_buffer` then needs one external edge to
//!    restart filling. With equal clocks the steady-state cadence is one
//!    level-0 word every **3 internal cycles** — this single constant
//!    reproduces the paper's "optimal while the inter-cycle shift is below
//!    one-third of the cycle length" knee (Fig 8), the worst case of one
//!    output every three cycles, and the case study's three accelerator
//!    cycles per 128-bit weight (§5.3.2).
//! 4. **Residency**: a standard level whose capacity holds the full
//!    pattern window replays it internally (data reuse); smaller levels
//!    downstream stream words through, clearing each slot after its read
//!    (§4.1.2 "higher levels do not retain subsets").
//! 5. **Ping-pong swap handshake** (double-buffered levels): writes land
//!    in the *fill* half, reads are served FIFO from the *drain* half, so
//!    a write and a read proceed in the same cycle on single-ported
//!    macros — and the §4.1.4 toggle does not apply (the fill controller
//!    latches on its own handshake, like the input-buffer path into
//!    level 0). The halves swap when the drain half runs empty and the
//!    fill half is ready (full, or holding the program's final truncated
//!    buffer). The swap is registered: read enables always see the
//!    pre-swap occupancy, and a swap committed this cycle takes effect at
//!    the next cycle boundary. Because drained slots are cleared, a
//!    double-buffered level can never be the resident level — it streams
//!    every pattern family instead (at one word per cycle once fed at
//!    rate, versus the standard level's toggle-limited word every two
//!    cycles).

pub mod functional;
pub mod hierarchy;
pub mod input_buffer;
pub mod level;
pub mod mcu;
pub mod offchip;
pub mod osr;
pub mod pingpong;

pub use functional::FunctionalModel;
pub use hierarchy::{BudgetedRun, Hierarchy, HierarchyCheckpoint, OutputWord, RunResult};
pub use input_buffer::InputBuffer;
pub use level::{Level, LevelRole, LevelStage};
pub use mcu::{FetchPlan, McuProgram};
pub use offchip::OffChipMemory;
pub use osr::Osr;
pub use pingpong::PingPongLevel;

//! Output shift register (§4.1.5).
//!
//! A register file between the last hierarchy level and the accelerator's
//! processing units. Its bit width may exceed the last level's word width
//! (the UltraTrail case study assembles a 384-bit weight port from three
//! 128-bit words). Each clock cycle it can execute a left shift of a
//! runtime-selectable width, emitting the shifted-out bits toward the
//! accelerator; when enough register space is free it requests the next
//! word from the hierarchy.
//!
//! Implementation note: modelled as a bit-FIFO carrying (off-chip address,
//! sub-word) pairs so emitted bits stay attributable for the end-to-end
//! data-integrity check. Shift widths must be multiples of the off-chip
//! word width — the paper's configurations (32-bit shifts over 128-bit
//! words; one 384-bit shift) all satisfy this.

use crate::sim::engine::Stage;
use crate::sim::fault::FaultSite;
use crate::util::bitword::Word;
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};
use std::collections::VecDeque;

/// One emitted output: `width` bits plus the off-chip addresses they came
/// from (in LSB-first order).
#[derive(Debug, Clone, PartialEq)]
pub struct OsrOutput {
    /// Emitted bits.
    pub word: Word,
    /// Source off-chip addresses, one per packed off-chip word.
    pub addrs: Vec<u64>,
}

/// Captured run state of the [`Osr`] at a cycle boundary: the bit-FIFO
/// contents, the runtime shift selection, and the shift counter. The
/// static geometry (width, shift list) is re-derived by `rearm` and not
/// captured; a checkpoint is only valid on an OSR re-armed for the same
/// configuration, checked by [`crate::mem::Hierarchy::restore`].
#[derive(Debug, Clone, PartialEq)]
pub struct OsrCheckpoint {
    queue: VecDeque<(u64, Word)>,
    shift_sel: usize,
    shifts_executed: u64,
}

impl OsrCheckpoint {
    /// Serialize for the checkpoint wire format.
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self { queue, shift_sel, shifts_executed } = self;
        w.put_u32(queue.len() as u32);
        for (addr, word) in queue {
            w.put_u64(*addr);
            word.wire_write(w);
        }
        w.put_usize(*shift_sel);
        w.put_u64(*shifts_executed);
    }

    /// Checked decode. `sub_width` is the off-chip word width (every
    /// queued sub-word has exactly that width) and `max_sel` the length
    /// of the configured shift list (`shift_sel` is 1-based into it) —
    /// both invariants of legitimately captured checkpoints, so corrupt
    /// bytes fail here instead of panicking mid-simulation.
    pub(crate) fn wire_read(
        r: &mut ByteReader<'_>,
        sub_width: u32,
        max_sel: usize,
    ) -> Result<Self> {
        let n = r.get_count(12)?;
        let mut queue = VecDeque::with_capacity(n);
        for _ in 0..n {
            let addr = r.get_u64()?;
            let word = Word::wire_read(r)?;
            if word.width() != sub_width {
                return Err(Error::Parse(format!(
                    "wire: OSR queue word is {} bits, expected {sub_width}",
                    word.width()
                )));
            }
            queue.push_back((addr, word));
        }
        let ck = Self { queue, shift_sel: r.get_usize()?, shifts_executed: r.get_u64()? };
        if ck.shift_sel == 0 || ck.shift_sel > max_sel {
            return Err(Error::Parse(format!(
                "wire: OSR shift selection {} out of range 1..={max_sel}",
                ck.shift_sel
            )));
        }
        Ok(ck)
    }
}

/// The output shift register.
#[derive(Debug)]
pub struct Osr {
    width: u32,
    sub_width: u32,
    shifts: Vec<u32>,
    shift_sel: usize,
    /// FIFO of (addr, sub-word) pairs; front = next bits out.
    queue: VecDeque<(u64, Word)>,
    /// Total shift operations executed (energy accounting).
    pub shifts_executed: u64,
}

impl Osr {
    /// New OSR of `width` bits fed by `level_width`-bit hierarchy words
    /// that pack `sub_width`-bit off-chip words. `shifts` is the
    /// configured shift list; `shift_sel` selects the active one
    /// (Table 1 `shift_select_i`, 1-based; 0 would disable output).
    pub fn new(width: u32, sub_width: u32, shifts: Vec<u32>, shift_sel: usize) -> Result<Self> {
        check_sel(&shifts, sub_width, shift_sel)?;
        Ok(Self { width, sub_width, shifts, shift_sel, queue: VecDeque::new(), shifts_executed: 0 })
    }

    /// In-place re-arm for a new program/configuration: equivalent to
    /// `*self = Osr::new(width, sub_width, shifts.to_vec(), shift_sel)?`
    /// but keeps the FIFO and shift-list allocations (warm-session path).
    pub fn rearm(
        &mut self,
        width: u32,
        sub_width: u32,
        shifts: &[u32],
        shift_sel: usize,
    ) -> Result<()> {
        check_sel(shifts, sub_width, shift_sel)?;
        self.width = width;
        self.sub_width = sub_width;
        self.shifts.clear();
        self.shifts.extend_from_slice(shifts);
        self.shift_sel = shift_sel;
        self.queue.clear();
        self.shifts_executed = 0;
        Ok(())
    }

    /// Currently selected shift width in bits.
    pub fn shift_width(&self) -> u32 {
        self.shifts[self.shift_sel - 1]
    }

    /// Select a different shift at runtime (µC control, §4.1.5).
    pub fn select_shift(&mut self, shift_sel: usize) -> Result<()> {
        if shift_sel == 0 || shift_sel > self.shifts.len() {
            return Err(Error::Config(format!("shift_select {shift_sel} out of range")));
        }
        let sel = self.shifts[shift_sel - 1];
        if sel % self.sub_width != 0 {
            return Err(Error::Config(format!("OSR shift {sel} incompatible with sub-width")));
        }
        self.shift_sel = shift_sel;
        Ok(())
    }

    /// Valid bits currently held.
    pub fn valid_bits(&self) -> u32 {
        self.queue.len() as u32 * self.sub_width
    }

    /// Free register space in bits.
    pub fn free_bits(&self) -> u32 {
        self.width - self.valid_bits()
    }

    /// Whether the OSR can accept a hierarchy word of `level_width` bits.
    pub fn can_accept(&self, level_width: u32) -> bool {
        self.free_bits() >= level_width
    }

    /// Push a hierarchy word (split into sub-words with their addresses).
    pub fn push_word(&mut self, word: &Word, addrs: &[u64]) {
        debug_assert!(self.can_accept(word.width()));
        debug_assert_eq!(word.width() % self.sub_width, 0);
        let n = word.width() / self.sub_width;
        debug_assert_eq!(n as usize, addrs.len());
        for j in 0..n {
            self.queue.push_back((addrs[j as usize], word.bits(j * self.sub_width, self.sub_width)));
        }
    }

    /// Execute one clock cycle: if enough valid bits are present, shift
    /// out `shift_width` bits and return them.
    pub fn step(&mut self) -> Option<OsrOutput> {
        let mut addrs = Vec::new();
        self.step_into(&mut addrs).map(|word| OsrOutput { word, addrs })
    }

    /// Allocation-free variant of [`Self::step`]: source addresses are
    /// appended to `addrs` (hot-loop path).
    pub fn step_into(&mut self, addrs: &mut Vec<u64>) -> Option<Word> {
        let sel = self.shift_width();
        if self.valid_bits() < sel {
            return None;
        }
        self.shifts_executed += 1;
        let n = (sel / self.sub_width) as usize;
        let mut word = Word::zero(sel);
        for j in 0..n {
            let (a, w) = self.queue.pop_front().expect("checked valid bits");
            word.set_bits(j as u32 * self.sub_width, &w);
            addrs.push(a);
        }
        Some(word)
    }

    /// Whether the register is completely empty.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Capture the register's run state (see [`OsrCheckpoint`]).
    pub fn snapshot(&self) -> OsrCheckpoint {
        OsrCheckpoint {
            queue: self.queue.clone(),
            shift_sel: self.shift_sel,
            shifts_executed: self.shifts_executed,
        }
    }

    /// Restore an [`OsrCheckpoint`] taken on an OSR armed for the same
    /// configuration. Reuses the FIFO allocation.
    pub fn restore(&mut self, ck: &OsrCheckpoint) {
        self.queue.clone_from(&ck.queue);
        self.shift_sel = ck.shift_sel;
        self.shifts_executed = ck.shifts_executed;
    }
}

/// Shared validation of a shift list + selection (construction and
/// re-arm).
fn check_sel(shifts: &[u32], sub_width: u32, shift_sel: usize) -> Result<()> {
    if shift_sel == 0 || shift_sel > shifts.len() {
        return Err(Error::Config(format!(
            "shift_select {shift_sel} out of range 1..={}",
            shifts.len()
        )));
    }
    let sel = shifts[shift_sel - 1];
    if sel % sub_width != 0 {
        return Err(Error::Config(format!(
            "OSR shift {sel} must be a multiple of the off-chip word width {sub_width}"
        )));
    }
    Ok(())
}

impl Stage for Osr {
    /// Handshake: enough valid bits are present to execute the selected
    /// shift this cycle.
    fn ready_out(&self) -> bool {
        self.valid_bits() >= self.shift_width()
    }

    /// Handshake: enough register space is free to latch a hierarchy word
    /// of `width` bits.
    fn ready_in(&self, width: u32) -> bool {
        self.can_accept(width)
    }

    /// The bit-FIFO mutates only through the push/shift handshakes (the
    /// composing core drives the shift each cycle it is ready), so the
    /// register is inert indefinitely absent handshakes; whether a shift
    /// *would* fire is what `ready_out` answers and the core checks.
    fn quiescent_for(&self) -> u64 {
        u64::MAX
    }

    /// Injectable state: queued sub-words awaiting their shift out
    /// ([`FaultSite::FifoEntry`], entry 0 = next bits out).
    fn inject(&mut self, site: &FaultSite) -> bool {
        match *site {
            FaultSite::FifoEntry { entry, bit, kind } => match self.queue.get_mut(entry) {
                Some((_, word)) => kind.perturb(word, bit),
                None => false,
            },
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::offchip::payload_for;

    fn word_for(addrs: &[u64], sub: u32) -> Word {
        let mut w = Word::zero(sub * addrs.len() as u32);
        for (j, &a) in addrs.iter().enumerate() {
            w.set_bits(j as u32 * sub, &payload_for(a, sub));
        }
        w
    }

    #[test]
    fn narrowing_shift_splits_words() {
        // Fig 6 config: 128-bit level words, 32-bit outputs, 256-bit OSR.
        let mut osr = Osr::new(256, 32, vec![32], 1).unwrap();
        let addrs = [10, 11, 12, 13];
        osr.push_word(&word_for(&addrs, 32), &addrs);
        assert_eq!(osr.valid_bits(), 128);
        assert!(osr.can_accept(128));
        for &a in &addrs {
            let out = osr.step().expect("one 32-bit output per cycle");
            assert_eq!(out.word, payload_for(a, 32));
            assert_eq!(out.addrs, vec![a]);
        }
        assert!(osr.step().is_none(), "drained");
        assert_eq!(osr.shifts_executed, 4);
    }

    #[test]
    fn widening_assembles_case_study_port() {
        // Case study: three 128-bit words -> one 384-bit weight port.
        let mut osr = Osr::new(384, 32, vec![384], 1).unwrap();
        let a1 = [0, 1, 2, 3];
        let a2 = [4, 5, 6, 7];
        let a3 = [8, 9, 10, 11];
        osr.push_word(&word_for(&a1, 32), &a1);
        assert!(osr.step().is_none(), "needs all three words");
        osr.push_word(&word_for(&a2, 32), &a2);
        assert!(!osr.can_accept(256), "only 128 bits free");
        assert!(osr.can_accept(128));
        osr.push_word(&word_for(&a3, 32), &a3);
        let out = osr.step().unwrap();
        assert_eq!(out.word.width(), 384);
        assert_eq!(out.addrs, (0..12).collect::<Vec<u64>>());
        assert_eq!(out.word.bits(0, 32), payload_for(0, 32));
        assert_eq!(out.word.bits(352, 32), payload_for(11, 32));
    }

    #[test]
    fn runtime_shift_selection() {
        let mut osr = Osr::new(128, 32, vec![32, 64], 1).unwrap();
        let addrs = [0, 1, 2, 3];
        osr.push_word(&word_for(&addrs, 32), &addrs);
        assert_eq!(osr.step().unwrap().word.width(), 32);
        osr.select_shift(2).unwrap();
        let out = osr.step().unwrap();
        assert_eq!(out.word.width(), 64);
        assert_eq!(out.addrs, vec![1, 2]);
        assert!(osr.select_shift(0).is_err());
        assert!(osr.select_shift(3).is_err());
    }

    #[test]
    fn invalid_configuration_rejected() {
        assert!(Osr::new(128, 32, vec![48], 1).is_err(), "shift not multiple of sub-width");
        assert!(Osr::new(128, 32, vec![32], 0).is_err());
        assert!(Osr::new(128, 32, vec![32], 2).is_err());
    }
}

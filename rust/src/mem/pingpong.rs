//! The double-buffered (ping-pong) hierarchy level — the §6 future-work
//! level kind, implemented as a second [`Stage`]-conforming datapath
//! component next to the standard [`super::level::Level`].
//!
//! ## Structure
//!
//! Two half-depth single-ported macros ("halves"). At any moment one half
//! is the **fill** half (accepting writes from the previous level / input
//! buffer) and the other is the **drain** half (serving FIFO reads toward
//! the next level / OSR / accelerator). Because fill and drain target
//! different physical macros, a write and a read proceed in the *same*
//! cycle without dual-port macros and without bank-parity luck — the
//! overlap a dual-ported level buys, at two single-ported macros plus an
//! output mux.
//!
//! ## Swap handshake
//!
//! The halves swap when the drain half has run empty **and** the fill
//! half is ready: either completely full, or holding the final words of
//! the program (`writes_done == total_writes`, the truncated last
//! buffer). The swap is registered — read enables computed in a cycle see
//! the pre-swap occupancy, so a swap performed while committing this
//! cycle's write/read takes effect at the next cycle boundary, like an
//! RTL flag flip.
//!
//! Because each drained slot is cleared (the §4.1.2 streaming rule), a
//! ping-pong level can never hold a pattern window resident:
//! [`crate::mem::mcu::McuProgram::compile`] therefore never assigns it
//! the `Resident` role, and its reads are always in FIFO arrival order.
//!
//! ## Pacing
//!
//! The §4.1.4 write-enable toggle does not apply: the fill controller
//! latches on its own handshake (like the input-buffer path into level
//! 0), so a fill half accepts one word per cycle while the other half
//! drains one word per cycle. In steady state with a rate-matched
//! upstream the level sustains one word per cycle in *and* out — this is
//! what lets a double-buffered level stream a full output at 1
//! word/cycle where a standard level is toggle-limited to one word every
//! two cycles.

use super::level::{
    corrupt_in, perturb_in, probe_in, wire_read_opt_slot, wire_read_slots, wire_write_opt_slot,
    wire_write_slots, Slot,
};
use super::mcu::LevelUnits;
use crate::config::LevelConfig;
use crate::sim::engine::Stage;
use crate::sim::fault::FaultSite;
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};

/// Captured run state of one [`PingPongLevel`] at a cycle boundary: both
/// halves' slot contents plus the fill/drain registers and the swap
/// counter. The static configuration and compiled program are not
/// captured; a checkpoint is only valid on a level re-armed for the same
/// (config, program) pair, checked by
/// [`crate::mem::Hierarchy::restore`].
#[derive(Debug, Clone, PartialEq)]
pub struct PingPongCheckpoint {
    slots: Vec<Option<Slot>>,
    fill_half: u64,
    fill_count: u64,
    drain_ptr: u64,
    drain_count: u64,
    swaps: u64,
    out_reg: Option<Slot>,
    writes_done: u64,
    reads_done: u64,
}

impl PingPongCheckpoint {
    /// Serialize for the checkpoint wire format (destructured so a newly
    /// added register must be encoded here explicitly).
    pub(crate) fn wire_write(&self, w: &mut ByteWriter) {
        let Self {
            slots,
            fill_half,
            fill_count,
            drain_ptr,
            drain_count,
            swaps,
            out_reg,
            writes_done,
            reads_done,
        } = self;
        wire_write_slots(slots, w);
        w.put_u64(*fill_half);
        w.put_u64(*fill_count);
        w.put_u64(*drain_ptr);
        w.put_u64(*drain_count);
        w.put_u64(*swaps);
        wire_write_opt_slot(out_reg, w);
        w.put_u64(*writes_done);
        w.put_u64(*reads_done);
    }

    /// Checked decode against the level's static configuration: slot
    /// count, half selector and fill/drain registers must satisfy the
    /// invariants every legitimately captured checkpoint holds, so
    /// corrupt bytes fail here instead of indexing out of bounds.
    pub(crate) fn wire_read(r: &mut ByteReader<'_>, cfg: &LevelConfig) -> Result<Self> {
        let ck = Self {
            slots: wire_read_slots(r)?,
            fill_half: r.get_u64()?,
            fill_count: r.get_u64()?,
            drain_ptr: r.get_u64()?,
            drain_count: r.get_u64()?,
            swaps: r.get_u64()?,
            out_reg: wire_read_opt_slot(r)?,
            writes_done: r.get_u64()?,
            reads_done: r.get_u64()?,
        };
        let half = cfg.half_depth();
        if ck.slots.len() as u64 != half * 2 {
            return Err(Error::Parse(format!(
                "wire: ping-pong checkpoint has {} slots, configured capacity is {}",
                ck.slots.len(),
                half * 2
            )));
        }
        if ck.fill_half > 1
            || ck.fill_count > half
            || ck.drain_ptr > half
            || ck.drain_count > half
        {
            return Err(Error::Parse("wire: ping-pong checkpoint register out of range".into()));
        }
        Ok(ck)
    }
}

/// One double-buffered hierarchy level (two half-depth ping-pong macros).
#[derive(Debug)]
pub struct PingPongLevel {
    /// Static configuration (`kind` is `DoubleBuffered`).
    pub cfg: LevelConfig,
    /// Compiled program for the current pattern (always a FIFO role).
    pub units: LevelUnits,
    /// Backing storage: slots `[0, half)` are half 0, `[half, 2*half)`
    /// are half 1.
    slots: Vec<Option<Slot>>,
    half_depth: u64,
    /// Which half is currently filling (0 or 1); the other drains.
    fill_half: u64,
    /// Words currently held by the fill half (the next write lands at
    /// offset `fill_count` within it).
    fill_count: u64,
    /// Next read offset within the drain half.
    drain_ptr: u64,
    /// Words currently held by the drain half.
    drain_count: u64,
    /// Ping-pong swaps performed (diagnostics).
    pub swaps: u64,
    /// Word presented to the next level (or the OSR / accelerator) after
    /// a read cycle; consumed by the downstream write.
    pub out_reg: Option<Slot>,
    /// Writes committed so far.
    pub writes_done: u64,
    /// Reads committed so far.
    pub reads_done: u64,
}

impl PingPongLevel {
    /// Construct for a config + compiled program.
    pub fn new(cfg: LevelConfig, units: LevelUnits) -> Self {
        Self::from_storage(Vec::new(), cfg, units)
    }

    /// Rebuild from an existing slot allocation (warm re-arm across a
    /// level-kind change: the storage vector is recycled, the state is
    /// bit-identical to [`Self::new`]).
    pub(super) fn from_storage(slots: Vec<Option<Slot>>, cfg: LevelConfig, units: LevelUnits) -> Self {
        let mut lvl = Self {
            cfg,
            units,
            slots,
            half_depth: 0,
            fill_half: 0,
            fill_count: 0,
            drain_ptr: 0,
            drain_count: 0,
            swaps: 0,
            out_reg: None,
            writes_done: 0,
            reads_done: 0,
        };
        lvl.reset();
        lvl
    }

    /// Surrender the slot storage (warm re-arm across a kind change).
    pub(super) fn into_storage(self) -> Vec<Option<Slot>> {
        self.slots
    }

    /// In-place re-arm for a new program/config: equivalent to
    /// `*self = PingPongLevel::new(cfg.clone(), units)` but reuses the
    /// slot allocation. The post-state is bit-identical to a fresh
    /// construction (the warm-session guarantee).
    pub fn rearm(&mut self, cfg: &LevelConfig, units: LevelUnits) {
        if self.cfg != *cfg {
            self.cfg = cfg.clone();
        }
        self.units = units;
        self.reset();
    }

    /// The single authoritative state reset, shared by construction
    /// ([`Self::from_storage`]) and [`Self::rearm`] so the warm==cold
    /// bit-identity cannot drift when fields are added: sizes the slot
    /// storage for `cfg` and zeroes every mutable register.
    fn reset(&mut self) {
        self.half_depth = self.cfg.half_depth();
        self.slots.clear();
        self.slots.resize((self.half_depth * 2) as usize, None);
        self.fill_half = 0;
        self.fill_count = 0;
        self.drain_ptr = 0;
        self.drain_count = 0;
        self.swaps = 0;
        self.out_reg = None;
        self.writes_done = 0;
        self.reads_done = 0;
    }

    /// Total slot count (both halves).
    pub fn depth(&self) -> u64 {
        self.slots.len() as u64
    }

    /// Occupied slot count (both halves).
    pub fn occupied(&self) -> u64 {
        self.fill_count + self.drain_count
    }

    /// First slot index of a half.
    #[inline]
    fn base(&self, half: u64) -> u64 {
        half * self.half_depth
    }

    /// Whether all programmed writes have been committed.
    pub fn writes_complete(&self) -> bool {
        self.writes_done >= self.units.total_writes
    }

    /// Whether all programmed reads have been committed.
    pub fn reads_complete(&self) -> bool {
        self.reads_done >= self.units.total_reads
    }

    /// The fill half can latch a word this cycle (it has a free slot; a
    /// full fill half awaiting its swap refuses, which is what paces the
    /// upstream handshake).
    pub fn write_slot_free(&self) -> bool {
        self.fill_count < self.half_depth
    }

    /// Slot index the next write targets.
    pub fn write_slot(&self) -> u64 {
        self.base(self.fill_half) + self.fill_count
    }

    /// Slot index the next read targets, if the drain half holds data.
    pub fn read_slot(&self) -> Option<u64> {
        if self.reads_complete() || self.drain_count == 0 {
            return None;
        }
        Some(self.base(1 - self.fill_half) + self.drain_ptr)
    }

    /// Whether the next read's data is present (FIFO order: whatever
    /// arrived; the end-to-end verifier checks the stream).
    pub fn read_data_ready(&self) -> bool {
        match self.read_slot() {
            None => false,
            Some(s) => self.slots[s as usize].is_some(),
        }
    }

    /// Port arbitration: fill and drain target different macros, so a
    /// pending read always proceeds regardless of a concurrent write.
    pub fn read_port_free(&self, _write_this_cycle: bool) -> bool {
        self.read_slot().is_some()
    }

    /// Commit a write into the fill half. Caller must have checked
    /// [`Self::write_slot_free`]; violating the precondition is reported
    /// as an integrity error, matching the standard level.
    pub fn commit_write(&mut self, incoming: Slot) -> Result<()> {
        if self.fill_count >= self.half_depth {
            return Err(Error::Integrity {
                cycle: 0,
                msg: format!(
                    "ping-pong write to a full fill half (tag {})",
                    incoming.tag
                ),
            });
        }
        let ws = self.write_slot() as usize;
        if self.slots[ws].is_some() {
            return Err(Error::Integrity {
                cycle: 0,
                msg: format!("ping-pong write to occupied slot {ws} (tag {})", incoming.tag),
            });
        }
        self.slots[ws] = Some(incoming);
        self.fill_count += 1;
        self.writes_done += 1;
        self.maybe_swap();
        Ok(())
    }

    /// A cycle with no write: nothing to release (there is no toggle; the
    /// swap handshake does the pacing).
    pub fn no_write_this_cycle(&mut self) {}

    /// Commit the pending read: pops the slot from the drain half
    /// (clearing it), loads `out_reg`, and swaps if the drain ran empty
    /// with the fill half ready.
    pub fn commit_read(&mut self, cycle: u64) -> Result<Slot> {
        let rs = self
            .read_slot()
            .ok_or_else(|| Error::Integrity { cycle, msg: "ping-pong read with empty drain half".into() })?
            as usize;
        let slot = self.slots[rs].take().ok_or_else(|| Error::Integrity {
            cycle,
            msg: format!("ping-pong read from empty slot {rs}"),
        })?;
        self.drain_ptr += 1;
        self.drain_count -= 1;
        self.reads_done += 1;
        self.out_reg = Some(slot);
        self.maybe_swap();
        Ok(slot)
    }

    /// Swap the halves when the drain half is empty and the fill half is
    /// ready (full, or holding the program's final truncated buffer).
    fn maybe_swap(&mut self) {
        let fill_ready = self.fill_count == self.half_depth || self.writes_complete();
        if self.drain_count == 0 && self.fill_count > 0 && fill_ready {
            self.fill_half = 1 - self.fill_half;
            self.drain_count = self.fill_count;
            self.drain_ptr = 0;
            self.fill_count = 0;
            self.swaps += 1;
        }
    }

    /// Peek a slot (tests / integrity checks).
    pub fn slot(&self, idx: u64) -> Option<&Slot> {
        self.slots[idx as usize].as_ref()
    }

    /// Fault injection: flip one payload bit of a stored word. Returns
    /// false if the slot is empty or out of range.
    pub fn corrupt_slot(&mut self, idx: u64, bit: u32) -> bool {
        corrupt_in(&mut self.slots, idx, bit)
    }

    /// Non-mutating fault probe: the current value of one stored payload
    /// bit, or `None` if an upset there would be vacant.
    pub fn probe_slot_bit(&self, idx: u64, bit: u32) -> Option<bool> {
        probe_in(&self.slots, idx, bit)
    }

    /// Capture the level's run state (see [`PingPongCheckpoint`]).
    pub fn snapshot(&self) -> PingPongCheckpoint {
        PingPongCheckpoint {
            slots: self.slots.clone(),
            fill_half: self.fill_half,
            fill_count: self.fill_count,
            drain_ptr: self.drain_ptr,
            drain_count: self.drain_count,
            swaps: self.swaps,
            out_reg: self.out_reg,
            writes_done: self.writes_done,
            reads_done: self.reads_done,
        }
    }

    /// Restore a [`PingPongCheckpoint`] taken on a level armed for the
    /// same (config, program) pair. Reuses the slot-storage allocation.
    pub fn restore(&mut self, ck: &PingPongCheckpoint) {
        self.slots.clone_from(&ck.slots);
        self.fill_half = ck.fill_half;
        self.fill_count = ck.fill_count;
        self.drain_ptr = ck.drain_ptr;
        self.drain_count = ck.drain_count;
        self.swaps = ck.swaps;
        self.out_reg = ck.out_reg;
        self.writes_done = ck.writes_done;
        self.reads_done = ck.reads_done;
    }
}

impl Stage for PingPongLevel {
    /// Handshake: a word is presented in the out-register for the
    /// downstream level (or the OSR / accelerator).
    fn ready_out(&self) -> bool {
        self.out_reg.is_some()
    }

    /// Handshake: the fill half has a free slot.
    fn ready_in(&self, _width: u32) -> bool {
        self.write_slot_free()
    }

    /// Every register (halves, fill/drain counters, swap) mutates only
    /// through the write/read handshakes — there is no §4.1.4 toggle and
    /// the swap commits inside the committing handshake — so the level is
    /// inert indefinitely absent handshakes.
    fn quiescent_for(&self) -> u64 {
        u64::MAX
    }

    /// Injectable state: the stored slot words of both halves
    /// ([`FaultSite::Slot`]; `[0, half_depth)` is half 0).
    fn inject(&mut self, site: &FaultSite) -> bool {
        match *site {
            FaultSite::Slot { slot, bit, kind } => perturb_in(&mut self.slots, slot, bit, kind),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LevelConfig, LevelKind};
    use crate::mem::mcu::Role;
    use crate::util::bitword::Word;

    fn mk(total_depth: u64, total_writes: u64) -> PingPongLevel {
        let cfg = LevelConfig {
            macro_name: "pp".into(),
            kind: LevelKind::DoubleBuffered,
            word_width: 32,
            ram_depth: total_depth,
            protection: crate::config::Protection::None,
        };
        let units = LevelUnits {
            role: Role::Fifo,
            cycle_length: 4,
            inter_cycle_shift: 0,
            skip_shift: 0,
            total_writes,
            total_reads: total_writes,
        };
        PingPongLevel::new(cfg, units)
    }

    fn w(tag: u64) -> Slot {
        Slot { tag, word: Word::from_u64(tag * 7 + 1, 32) }
    }

    #[test]
    fn no_reads_until_first_swap() {
        let mut pp = mk(8, 100);
        assert!(!pp.read_data_ready(), "both halves empty");
        for t in 0..3 {
            pp.commit_write(w(t)).unwrap();
            assert!(!pp.read_data_ready(), "fill half not full yet ({t})");
        }
        pp.commit_write(w(3)).unwrap(); // fill half full -> swap
        assert_eq!(pp.swaps, 1);
        assert!(pp.read_data_ready());
    }

    #[test]
    fn fifo_order_across_swaps() {
        let mut pp = mk(4, 100);
        let mut got = Vec::new();
        let mut next = 0u64;
        // Interleave: one write and (when ready) one read per "cycle".
        for cycle in 0..24u64 {
            if pp.write_slot_free() && next < 12 {
                pp.commit_write(w(next)).unwrap();
                next += 1;
            }
            if pp.read_data_ready() {
                got.push(pp.commit_read(cycle).unwrap().tag);
            }
        }
        assert_eq!(got, (0..12).collect::<Vec<u64>>(), "arrival order preserved");
        assert!(pp.swaps >= 6, "halves of depth 2 swap every 2 words: {}", pp.swaps);
    }

    #[test]
    fn concurrent_fill_and_drain() {
        let mut pp = mk(8, 100);
        for t in 0..4 {
            pp.commit_write(w(t)).unwrap();
        }
        // Drain half now holds 0..4; fill half is free: a write and a
        // read proceed the same cycle.
        assert!(pp.write_slot_free());
        assert!(pp.read_port_free(true), "different macros never conflict");
        pp.commit_write(w(4)).unwrap();
        assert_eq!(pp.commit_read(0).unwrap().tag, 0);
        assert_eq!(pp.occupied(), 4);
    }

    #[test]
    fn truncated_final_buffer_swaps_on_writes_complete() {
        // 6 words through halves of depth 4: the last buffer holds 2.
        let mut pp = mk(8, 6);
        for t in 0..4 {
            pp.commit_write(w(t)).unwrap();
        }
        for c in 0..4 {
            pp.commit_read(c).unwrap();
        }
        // Drain empty, fill has nothing yet: no swap possible.
        assert!(!pp.read_data_ready());
        pp.commit_write(w(4)).unwrap();
        assert!(!pp.read_data_ready(), "writes not complete, fill not full");
        pp.commit_write(w(5)).unwrap(); // final write -> swap despite partial fill
        assert!(pp.read_data_ready());
        assert_eq!(pp.commit_read(4).unwrap().tag, 4);
        assert_eq!(pp.commit_read(5).unwrap().tag, 5);
        assert!(pp.reads_complete());
    }

    #[test]
    fn full_fill_half_blocks_writes_until_swap() {
        let mut pp = mk(4, 100);
        pp.commit_write(w(0)).unwrap();
        pp.commit_write(w(1)).unwrap(); // half full -> swap (drain empty)
        pp.commit_write(w(2)).unwrap();
        pp.commit_write(w(3)).unwrap(); // second half full, drain busy
        assert!(!pp.write_slot_free(), "fill full and drain not empty");
        assert!(pp.commit_write(w(9)).is_err(), "full fill half must refuse the write");
        pp.commit_read(0).unwrap();
        assert!(!pp.write_slot_free(), "swap waits for the drain to empty");
        pp.commit_read(1).unwrap(); // drain empty -> swap
        assert!(pp.write_slot_free());
        assert_eq!(pp.swaps, 2);
    }

    #[test]
    fn rearm_restores_fresh_state() {
        let mut pp = mk(8, 100);
        for t in 0..6 {
            pp.commit_write(w(t)).unwrap();
        }
        pp.commit_read(0).unwrap();
        let fresh = mk(4, 10);
        pp.rearm(&fresh.cfg, fresh.units);
        assert_eq!(pp.depth(), 4);
        assert_eq!(pp.occupied(), 0);
        assert_eq!(pp.swaps, 0);
        assert!(pp.out_reg.is_none());
        assert!(!pp.read_data_ready());
        assert!(pp.write_slot_free());
        // And it behaves like a fresh level.
        pp.commit_write(w(10)).unwrap();
        pp.commit_write(w(11)).unwrap();
        assert_eq!(pp.commit_read(0).unwrap().tag, 10);
    }
}

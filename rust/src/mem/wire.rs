//! Versioned binary wire format for [`HierarchyCheckpoint`]s.
//!
//! Checkpoints cross process boundaries in the sharded DSE
//! ([`crate::dse::shard`]): the coordinator ships a candidate's suspended
//! state to whichever worker steals it next, and workers ship the
//! re-suspended state back. The format is zero-dependency (hand-rolled
//! little-endian encoding over [`crate::util::frame`]) and fully checked:
//! `decode_checkpoint(encode_checkpoint(ck)) == ck` bit-for-bit, and any
//! byte string that is not a valid encoding returns a checked
//! [`Error`] — never a panic.
//!
//! ## Layout
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//! 0       4     magic "MHCP"
//! 4       2     version (u16 LE) — currently 1
//! 6       4+n   configuration, as the TOML-subset text (u32 length
//!               prefix + UTF-8 bytes), re-parsed and re-validated on
//!               decode
//! …       …     source pattern program (see `write_program`): fixed
//!               scalars + per-level override flags
//! …       …     checkpoint body (see the "Wire format" section on
//!               [`HierarchyCheckpoint`]): levels, input buffer,
//!               off-chip pipeline, OSR, flags, engine state
//! ```
//!
//! All multi-byte integers are little-endian and fixed-width; `f64`
//! values travel as their IEEE-754 bit patterns (`to_bits`/`from_bits`),
//! so floating-point state round-trips bitwise. Containers carry a `u32`
//! element count. There is no padding and no trailing slack — decode
//! rejects leftover bytes.
//!
//! ## Keying and versioning
//!
//! The envelope carries the checkpoint's two compatibility keys — the
//! *configuration* (as canonical TOML text) and the *source program*
//! (structurally) — rather than the compiled [`McuProgram`]. Decode
//! re-parses the configuration, re-validates the program, and re-runs
//! [`McuProgram::compile`]; the body is then decoded *against* those
//! keys, so structural invariants (slot-vector lengths, pointer bounds,
//! word widths, tag ranges) are enforced relative to the configuration
//! the checkpoint claims. Encode performs the inverse check: the caller
//! supplies the source program, and encoding fails unless it compiles to
//! exactly the compiled program the checkpoint is bound to.
//!
//! A version bump is required for any layout change; decoders reject
//! unknown versions (and bad magic) before touching the payload, so a
//! newer producer degrades into a checked [`Error::Parse`] on an older
//! consumer.
//!
//! ## Trust boundary
//!
//! `decode_checkpoint` guarantees *no panic* and *structural* validity
//! on arbitrary input — every invariant the simulator's `restore` paths
//! index or assert on is re-checked. It does not (and cannot cheaply)
//! prove *semantic* reachability: a hand-crafted, structurally valid
//! body may describe a state no real run visits. Those are caught
//! downstream by [`crate::mem::Hierarchy::restore`]'s config/program/
//! switch keying, the deadlock guard, and the output verifier — the same
//! layers that police an in-process checkpoint.

use super::hierarchy::HierarchyCheckpoint;
use super::mcu::McuProgram;
use crate::config::HierarchyConfig;
use crate::pattern::{LevelProgram, PatternProgram};
use crate::util::frame::{ByteReader, ByteWriter};
use crate::{Error, Result};

/// File/stream magic identifying a serialized checkpoint ("MHCP").
pub const WIRE_MAGIC: [u8; 4] = *b"MHCP";

/// Current wire-format version. Bumped on any layout change; decoders
/// reject everything else.
pub const WIRE_VERSION: u16 = 1;

/// Serialize `ck` to the versioned wire format.
///
/// `workload` must be the source program the checkpoint's compiled
/// program was built from — the envelope ships the *source* (compact,
/// auditable) and decode re-compiles it, so encoding verifies that
/// `McuProgram::compile(ck.config(), workload)` reproduces the bound
/// program exactly and fails with [`Error::Config`] otherwise.
pub fn encode_checkpoint(ck: &HierarchyCheckpoint, workload: &PatternProgram) -> Result<Vec<u8>> {
    let compiled = McuProgram::compile(ck.config(), workload)?;
    if compiled != *ck.prog() {
        return Err(Error::Config(
            "wire: workload does not compile to the checkpoint's bound program".into(),
        ));
    }
    let mut w = ByteWriter::new();
    w.put_raw(&WIRE_MAGIC);
    w.put_u16(WIRE_VERSION);
    w.put_str(&ck.config().to_toml());
    write_program(workload, &mut w);
    ck.wire_write_body(&mut w);
    Ok(w.into_bytes())
}

/// Decode a checkpoint (and the source program it is keyed to) from
/// `bytes`.
///
/// Returns [`Error::Parse`] for bad magic, unknown versions, truncated
/// or trailing bytes, and any structural-invariant violation; config
/// and program re-validation surface their own checked errors. Never
/// panics on arbitrary input.
pub fn decode_checkpoint(bytes: &[u8]) -> Result<(HierarchyCheckpoint, PatternProgram)> {
    let mut r = ByteReader::new(bytes);
    let magic = r.get_raw(WIRE_MAGIC.len())?;
    if magic != WIRE_MAGIC {
        return Err(Error::Parse(format!("wire: bad magic {magic:02x?}")));
    }
    let version = r.get_u16()?;
    if version != WIRE_VERSION {
        return Err(Error::Parse(format!(
            "wire: unsupported version {version} (this build reads {WIRE_VERSION})"
        )));
    }
    let config = HierarchyConfig::from_toml(r.get_str()?)?;
    let workload = read_program(&mut r)?;
    workload.validate()?;
    let compiled = McuProgram::compile(&config, &workload)?;
    let ck = HierarchyCheckpoint::wire_read_body(&mut r, config, compiled)?;
    r.finish()?;
    Ok((ck, workload))
}

/// Serialize a source [`PatternProgram`] (structural, not TOML — the
/// program is small and fixed-shape). Shared with the shard protocol's
/// evaluation requests ([`crate::dse::shard`]).
pub(crate) fn write_program(p: &PatternProgram, w: &mut ByteWriter) {
    let PatternProgram { start_address, output, level_overrides, stride, total_outputs } = p;
    w.put_u64(*start_address);
    write_level_program(output, w);
    w.put_u32(level_overrides.len() as u32);
    for ov in level_overrides {
        w.put_bool(ov.is_some());
        if let Some(lp) = ov {
            write_level_program(lp, w);
        }
    }
    w.put_u64(*stride);
    w.put_u64(*total_outputs);
}

/// Checked decode of [`write_program`] output. Callers still run
/// [`PatternProgram::validate`] on the result.
pub(crate) fn read_program(r: &mut ByteReader<'_>) -> Result<PatternProgram> {
    let start_address = r.get_u64()?;
    let output = read_level_program(r)?;
    let n = r.get_count(1)?;
    let mut level_overrides = Vec::with_capacity(n);
    for _ in 0..n {
        level_overrides.push(if r.get_bool()? { Some(read_level_program(r)?) } else { None });
    }
    Ok(PatternProgram {
        start_address,
        output,
        level_overrides,
        stride: r.get_u64()?,
        total_outputs: r.get_u64()?,
    })
}

/// Serialize one [`LevelProgram`] (three scalars).
fn write_level_program(p: &LevelProgram, w: &mut ByteWriter) {
    let LevelProgram { cycle_length, inter_cycle_shift, skip_shift } = p;
    w.put_u64(*cycle_length);
    w.put_u64(*inter_cycle_shift);
    w.put_u64(*skip_shift);
}

/// Decode one [`LevelProgram`].
fn read_level_program(r: &mut ByteReader<'_>) -> Result<LevelProgram> {
    Ok(LevelProgram {
        cycle_length: r.get_u64()?,
        inter_cycle_shift: r.get_u64()?,
        skip_shift: r.get_u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::PatternProgram;

    fn program() -> PatternProgram {
        PatternProgram::shifted_cyclic(64, 16, 4).with_outputs(400)
    }

    #[test]
    fn program_roundtrip() {
        let mut p = program();
        p.level_overrides =
            vec![None, Some(LevelProgram { cycle_length: 8, inter_cycle_shift: 2, skip_shift: 0 })];
        let mut w = ByteWriter::new();
        write_program(&p, &mut w);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let back = read_program(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn bad_magic_and_version_are_checked() {
        let mut w = ByteWriter::new();
        w.put_raw(b"NOPE");
        w.put_u16(WIRE_VERSION);
        let err = decode_checkpoint(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, Error::Parse(_)), "bad magic: {err}");

        let mut w = ByteWriter::new();
        w.put_raw(&WIRE_MAGIC);
        w.put_u16(WIRE_VERSION + 1);
        let err = decode_checkpoint(&w.into_bytes()).unwrap_err();
        assert!(matches!(err, Error::Parse(_)), "bad version: {err}");
    }

    #[test]
    fn empty_and_truncated_never_panic() {
        assert!(decode_checkpoint(&[]).is_err());
        assert!(decode_checkpoint(&WIRE_MAGIC).is_err());
    }
}

//! Synthesis-proxy cost model: chip area and power for memory macros,
//! register files, and whole hierarchy configurations.
//!
//! The paper reports synthesis numbers from a commercial flow we do not
//! have; this parametric model is **calibrated to the paper's anchors**
//! (see [`calibrate`]):
//!
//! * Fig 7 — 32-bit two-level framework = 7 566 µm²; equal-capacity
//!   128-bit framework + OSR = 15 202 µm², ≈2.5× the power.
//! * Fig 9 — dual-ported SRAM banks vs framework areas per unrolling.
//! * Fig 12 — UltraTrail: 3×(1024×128) single-ported weight macros are
//!   >70 % of chip area; replacing them with one 104×128 dual-ported
//!   level + 384-bit OSR shrinks the chip by 62.2 % and raises power by
//!   6.2 % (dual-ported leakage dominates).
//!
//! All comparisons in the paper are *within one technology*, so ratios are
//! set by bit counts, port counts, geometry, and access counts — which the
//! parametric form captures; calibration pins the absolute scale.

pub mod calibrate;
pub mod energy;
pub mod sram;

pub use calibrate::constants;
pub use energy::{run_power, PowerBreakdown};
pub use sram::{
    access_energy, hierarchy_area, level_access_energy, level_area, level_leakage, sram_area,
    sram_leakage, AreaBreakdown,
};

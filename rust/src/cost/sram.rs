//! SRAM macro and framework area model (see [`super::calibrate`] for the
//! anchor fit), plus the per-level-kind dispatch helpers
//! ([`level_area`], [`level_leakage`], [`level_access_energy`]) the
//! higher-level cost models build on.

use super::calibrate::constants;
use crate::config::{HierarchyConfig, LevelConfig, LevelKind, PortKind};

/// Area of one SRAM macro in µm².
pub fn sram_area(word_width: u32, depth: u64, ports: PortKind) -> f64 {
    let c = constants();
    let w = word_width as f64;
    let d = depth as f64;
    let (pf, p) = match ports {
        PortKind::Single => (1.0, 1.0),
        PortKind::Dual => (c.pf_dp_area, 2.0),
    };
    w * d * c.a_bit * pf + w * p * c.a_col + d * c.a_row
}

/// Leakage of one SRAM macro in W.
pub fn sram_leakage(word_width: u32, depth: u64, ports: PortKind) -> f64 {
    let c = constants();
    let bits = word_width as f64 * depth as f64;
    let (lb, p) = match ports {
        PortKind::Single => (c.leak_bit_sp, 1.0),
        PortKind::Dual => (c.leak_bit_dp, 2.0),
    };
    bits * lb + word_width as f64 * p * c.leak_col
}

/// Energy of one read or write access in J.
pub fn access_energy(word_width: u32, depth: u64, ports: PortKind) -> f64 {
    let c = constants();
    let base = c.e_bit * word_width as f64 + c.e_depth * (depth as f64).sqrt();
    match ports {
        PortKind::Single => base,
        PortKind::Dual => base * c.pf_dp_energy,
    }
}

/// Stored macro word width in bits: the architectural word plus the
/// protection check-bit columns riding alongside it in every row (see
/// [`crate::config::Protection::check_bits`]). Unprotected levels store
/// exactly `word_width` bits, so every cost below reduces bit-identically
/// to the pre-protection model.
fn stored_width(l: &LevelConfig) -> u32 {
    l.word_width + l.protection.check_bits(l.word_width)
}

/// Encode/decode logic area of a protected level in µm² (0 when
/// unprotected): the parity/syndrome XOR trees are modelled as one
/// mux-equivalent gate column per check bit on each side of the array.
fn codec_area(l: &LevelConfig) -> f64 {
    let cb = l.protection.check_bits(l.word_width);
    if cb == 0 {
        return 0.0;
    }
    2.0 * cb as f64 * constants().a_mux
}

/// Per-access encode/decode energy of a protected level in J (0 when
/// unprotected): each check bit switches one extra bit-column's worth of
/// dynamic energy through the codec trees. The codec is pipelined with
/// the array access, so protection never costs cycles — only energy and
/// area (the contract [`crate::mem::FunctionalModel`] relies on).
fn codec_energy(l: &LevelConfig) -> f64 {
    let cb = l.protection.check_bits(l.word_width);
    if cb == 0 {
        return 0.0;
    }
    cb as f64 * constants().e_bit
}

/// Total macro area of one hierarchy level in µm², dispatching on the
/// level kind: standard levels instantiate `banks` macros of `ram_depth`
/// words; double-buffered levels instantiate **two half-depth
/// single-ported macros** plus the ping-pong steering mux — trading the
/// dual-port bit-cell premium for a second decoder and a mux. Protected
/// levels widen every macro by the check-bit columns and add the codec
/// logic.
pub fn level_area(l: &LevelConfig) -> f64 {
    let w = stored_width(l);
    let base = match l.kind {
        LevelKind::Standard { banks, ports } => {
            banks as f64 * sram_area(w, l.ram_depth, ports)
        }
        LevelKind::DoubleBuffered => {
            2.0 * sram_area(w, l.half_depth(), PortKind::Single)
                + w as f64 * constants().a_mux
        }
    };
    base + codec_area(l)
}

/// Total leakage of one hierarchy level in W (same dispatch as
/// [`level_area`]; the ping-pong mux and codec leakage are negligible
/// against the macro arrays and are not modelled — but the check-bit
/// columns themselves leak like any other column).
pub fn level_leakage(l: &LevelConfig) -> f64 {
    let w = stored_width(l);
    match l.kind {
        LevelKind::Standard { banks, ports } => {
            banks as f64 * sram_leakage(w, l.ram_depth, ports)
        }
        LevelKind::DoubleBuffered => {
            2.0 * sram_leakage(w, l.half_depth(), PortKind::Single)
        }
    }
}

/// Energy of one read or write access to the level in J. A standard
/// access hits one `ram_depth`-word bank; a double-buffered access hits
/// one half-depth single-ported macro (the other half is idle), so it is
/// *cheaper* than the equivalent standard access — shorter bitlines.
/// Protected accesses drive the check-bit columns too and pay the codec
/// switching energy on top.
pub fn level_access_energy(l: &LevelConfig) -> f64 {
    let w = stored_width(l);
    let base = match l.kind {
        LevelKind::Standard { ports, .. } => access_energy(w, l.ram_depth, ports),
        LevelKind::DoubleBuffered => access_energy(w, l.half_depth(), PortKind::Single),
    };
    base + codec_energy(l)
}

/// Area breakdown of a framework configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AreaBreakdown {
    /// Per-level macro area (all banks), µm².
    pub levels: Vec<f64>,
    /// Input buffer register file, µm².
    pub input_buffer: f64,
    /// OSR register file (0 if absent), µm².
    pub osr: f64,
    /// MCU + handshake control, µm².
    pub control: f64,
    /// Total, µm².
    pub total: f64,
}

/// Compute the synthesis-proxy area of a framework configuration.
pub fn hierarchy_area(cfg: &HierarchyConfig) -> AreaBreakdown {
    let c = constants();
    let levels: Vec<f64> = cfg.levels.iter().map(level_area).collect();
    let input_buffer = cfg.levels[0].word_width as f64 * c.a_ff;
    let osr = cfg.osr.as_ref().map(|o| o.width as f64 * c.a_ff).unwrap_or(0.0);
    let control = c.a_ctrl;
    let total = levels.iter().sum::<f64>() + input_buffer + osr + control;
    AreaBreakdown { levels, input_buffer, osr, control, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchyConfig;

    #[test]
    fn breakdown_sums_to_total() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .osr(64, vec![32])
            .build()
            .unwrap();
        let a = hierarchy_area(&cfg);
        let sum = a.levels.iter().sum::<f64>() + a.input_buffer + a.osr + a.control;
        assert!((sum - a.total).abs() < 1e-9);
        assert_eq!(a.levels.len(), 2);
        assert!(a.osr > 0.0);
    }

    #[test]
    fn banks_multiply_macro_area() {
        let one = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 256, 1, 1)
            .build()
            .unwrap();
        let two = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 256, 2, 1)
            .build()
            .unwrap();
        let a1 = hierarchy_area(&one).levels[0];
        let a2 = hierarchy_area(&two).levels[0];
        assert!((a2 - 2.0 * a1).abs() < 1e-9, "two banks = two macros");
    }

    #[test]
    fn double_buffered_cost_sits_between_sp_and_dp() {
        use crate::config::{LevelConfig, LevelKind, Protection};
        let mk = |kind| LevelConfig {
            macro_name: "x".into(),
            kind,
            word_width: 32,
            ram_depth: 128,
            protection: Protection::None,
        };
        let sp = mk(LevelKind::Standard { banks: 1, ports: PortKind::Single });
        let dp = mk(LevelKind::Standard { banks: 1, ports: PortKind::Dual });
        let db = mk(LevelKind::DoubleBuffered);
        assert!(level_area(&db) > level_area(&sp), "second decoder + mux cost area");
        assert!(level_area(&db) < level_area(&dp), "no dual-port bit-cell premium");
        assert!(level_leakage(&db) < 0.1 * level_leakage(&dp), "single-ported leakage");
        assert!(level_leakage(&db) > level_leakage(&sp), "two peripheries leak more");
        assert!(level_access_energy(&db) < level_access_energy(&sp), "half-depth bitlines");
    }

    #[test]
    fn protection_costs_are_monotone_and_none_is_free() {
        use crate::config::{LevelConfig, LevelKind, Protection};
        for kind in [
            LevelKind::Standard { banks: 1, ports: PortKind::Single },
            LevelKind::Standard { banks: 2, ports: PortKind::Single },
            LevelKind::Standard { banks: 1, ports: PortKind::Dual },
            LevelKind::DoubleBuffered,
        ] {
            let mk = |protection| LevelConfig {
                macro_name: "x".into(),
                kind,
                word_width: 32,
                ram_depth: 128,
                protection,
            };
            let (none, parity, secded) =
                (mk(Protection::None), mk(Protection::Parity), mk(Protection::Secded));
            // None reduces bit-identically to the raw macro primitives.
            let raw = match kind {
                LevelKind::Standard { banks, ports } => {
                    banks as f64 * sram_area(32, 128, ports)
                }
                LevelKind::DoubleBuffered => {
                    2.0 * sram_area(32, 64, PortKind::Single)
                        + 32.0 * constants().a_mux
                }
            };
            assert_eq!(level_area(&none).to_bits(), raw.to_bits(), "{kind:?}");
            // Protection strength orders area, leakage and energy.
            assert!(level_area(&parity) > level_area(&none), "{kind:?}");
            assert!(level_area(&secded) > level_area(&parity), "{kind:?}");
            assert!(level_leakage(&parity) > level_leakage(&none), "{kind:?}");
            assert!(level_leakage(&secded) > level_leakage(&parity), "{kind:?}");
            assert!(level_access_energy(&parity) > level_access_energy(&none), "{kind:?}");
            assert!(level_access_energy(&secded) > level_access_energy(&parity), "{kind:?}");
        }
    }

    #[test]
    fn dual_port_energy_premium() {
        let sp = access_energy(128, 1024, PortKind::Single);
        let dp = access_energy(128, 1024, PortKind::Dual);
        assert!((dp / sp - 1.9).abs() < 1e-9);
    }

    #[test]
    fn leakage_dp_dominates_sp() {
        // The Fig 12 mechanism: a small dual-ported macro can out-leak a
        // much larger single-ported one.
        let big_sp = sram_leakage(128, 1024, PortKind::Single) * 3.0;
        let small_dp = sram_leakage(128, 104, PortKind::Dual);
        assert!(
            small_dp > big_sp * 0.5,
            "104x128 DP leakage {small_dp:.3e} should rival 3x 1024x128 SP {big_sp:.3e}"
        );
    }
}

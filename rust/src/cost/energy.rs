//! Run-level power accounting: leakage × time + Σ events × energy, using
//! the activity counters of a [`SimStats`] run.

use super::calibrate::constants;
use super::sram::{level_access_energy, level_leakage};
use crate::config::HierarchyConfig;
use crate::sim::SimStats;

/// Power breakdown of a simulated run at a given internal clock frequency.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerBreakdown {
    /// Total leakage (W).
    pub leakage: f64,
    /// SRAM array dynamic power (W).
    pub sram_dynamic: f64,
    /// Register (input buffer + OSR) dynamic power (W).
    pub register_dynamic: f64,
    /// Off-chip interface dynamic power (W).
    pub io_dynamic: f64,
    /// Total (W).
    pub total: f64,
}

/// Compute average power of a run at internal frequency `f_int_hz`.
///
/// Dynamic energy = per-level (reads + writes) × access energy
/// + CDC transfers × input-buffer write energy
/// + OSR shifts × register toggle energy
/// + off-chip reads × interface energy.
/// Leakage = Σ macro leakage + register leakage (frequency independent).
pub fn run_power(cfg: &HierarchyConfig, stats: &SimStats, f_int_hz: f64) -> PowerBreakdown {
    let c = constants();
    let cycles = stats.internal_cycles.max(1) as f64;
    let time_s = cycles / f_int_hz;

    let mut leakage = 0.0;
    let mut sram_energy = 0.0;
    for (i, l) in cfg.levels.iter().enumerate() {
        // Per-kind dispatch: standard banks vs ping-pong half macros.
        leakage += level_leakage(l);
        let e_acc = level_access_energy(l);
        let events = stats.level_reads.get(i).copied().unwrap_or(0)
            + stats.level_writes.get(i).copied().unwrap_or(0);
        sram_energy += events as f64 * e_acc;
    }
    let ib_bits = cfg.levels[0].word_width as f64;
    let osr_bits = cfg.osr.as_ref().map(|o| o.width as f64).unwrap_or(0.0);
    leakage += (ib_bits + osr_bits) * c.leak_ff;

    // Each CDC transfer rewrites the full input-buffer register; each OSR
    // shift toggles the full OSR register; all register bits draw
    // clock-tree energy every internal cycle.
    let register_energy = stats.cdc_transfers as f64 * ib_bits * c.e_ff
        + stats.osr_shifts as f64 * osr_bits * c.e_ff
        + cycles * (ib_bits + osr_bits) * c.e_ff_clk;
    let io_energy = stats.offchip_reads as f64 * c.e_io;

    let sram_dynamic = sram_energy / time_s;
    let register_dynamic = register_energy / time_s;
    let io_dynamic = io_energy / time_s;
    PowerBreakdown {
        leakage,
        sram_dynamic,
        register_dynamic,
        io_dynamic,
        total: leakage + sram_dynamic + register_dynamic + io_dynamic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchyConfig;
    use crate::mem::Hierarchy;
    use crate::pattern::PatternProgram;

    fn run(cfg: &HierarchyConfig, prog: &PatternProgram) -> SimStats {
        let mut h = Hierarchy::new(cfg).unwrap();
        h.load_program(prog).unwrap();
        h.run().unwrap().stats
    }

    #[test]
    fn breakdown_sums() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap();
        let stats = run(&cfg, &PatternProgram::cyclic(0, 64).with_outputs(1_280));
        let p = run_power(&cfg, &stats, 100e6);
        let sum = p.leakage + p.sram_dynamic + p.register_dynamic + p.io_dynamic;
        assert!((sum - p.total).abs() < 1e-15);
        assert!(p.total > 0.0);
    }

    #[test]
    fn reuse_reduces_io_power() {
        // Cyclic reuse fetches each word once; sequential streams fetch
        // every word: IO power must reflect that.
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap();
        let cyc = run(&cfg, &PatternProgram::cyclic(0, 64).with_outputs(1_280));
        let seq = run(&cfg, &PatternProgram::sequential(0, 1_280));
        let p_cyc = run_power(&cfg, &cyc, 100e6);
        let p_seq = run_power(&cfg, &seq, 100e6);
        assert!(
            p_seq.io_dynamic > 5.0 * p_cyc.io_dynamic,
            "sequential IO {} vs cyclic IO {}",
            p_seq.io_dynamic,
            p_cyc.io_dynamic
        );
    }

    #[test]
    fn leakage_is_frequency_independent() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap();
        let stats = run(&cfg, &PatternProgram::cyclic(0, 64).with_outputs(640));
        let a = run_power(&cfg, &stats, 1e6);
        let b = run_power(&cfg, &stats, 100e6);
        assert!((a.leakage - b.leakage).abs() < 1e-18);
        assert!(b.sram_dynamic > 50.0 * a.sram_dynamic);
    }

    /// Fig 7 power shape: the 128-bit framework consumes ≈2.5× the 32-bit
    /// framework on the same workload (5 000 outputs, long cycle).
    #[test]
    fn fig7_power_ratio_shape() {
        let cfg32 = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap();
        let cfg128 = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(128, vec![32])
            .build()
            .unwrap();
        let s32 = run(&cfg32, &PatternProgram::cyclic(0, 512).with_outputs(5_120));
        let s128 = run(&cfg128, &PatternProgram::cyclic(0, 512).with_outputs(5_120));
        let p32 = run_power(&cfg32, &s32, 100e6);
        let p128 = run_power(&cfg128, &s128, 100e6);
        let ratio = p128.total / p32.total;
        assert!(
            (1.8..3.2).contains(&ratio),
            "expected ≈2.5x power for the wide framework, got {ratio:.2}"
        );
    }
}

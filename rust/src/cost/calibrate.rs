//! Calibration constants and the anchor equations they solve.
//!
//! ## Area (µm²) — anchored on Figure 7
//!
//! ```text
//! A_macro(w, d, p) = w·d·A_BIT·pf(p) + w·p·A_COL + d·A_ROW
//! A_ff(bits)       = bits·A_FF
//! A_framework      = Σ banks·A_macro + A_ff(IB) + A_ff(OSR) + A_CTRL
//! ```
//!
//! Solving the two Fig 7 anchors (7 566 µm² for the 32-bit {512,128}
//! two-level framework; 15 202 µm² for the equal-capacity 128-bit
//! {128,32} framework with a 128-bit OSR) with A_FF = 3 µm²/bit and
//! A_CTRL = 400 µm² fixed gives A_COL ≈ 25 and A_ROW ≈ 0.5:
//!
//! * 32-bit config: (2949.1 + 800 + 256) + (1400.8 + 1600 + 64)
//!   + 96 (IB) + 400 = **7 566.0** ✔
//! * 128-bit config: (2949.1 + 3200 + 64) + (1400.8 + 6400 + 16)
//!   + 384 (IB) + 384 (OSR) + 400 = **15 198.0** ≈ 15 202 (−0.03 %) ✔
//!
//! ## Power — anchored on Figure 12
//!
//! Leakage: the paper attributes the case study's +6.2 % chip power to the
//! "significantly greater leakage power of dual-ported memory"; the
//! calibrated dual-ported bit leakage is 100× the single-ported value
//! (low-leakage 6T vs fast 8T dual-port compiler corners differ by two
//! orders of magnitude in commercial libraries). The off-chip streaming
//! interface adds `E_IO` per off-chip word on the chip side. The UltraTrail
//! remainder (MAC array, FMEM, control) is `UT_REST_AREA` / `UT_REST_POWER`
//! — set so that the weight macros are ≈74 % of baseline chip area (paper:
//! ">70 %") and the area saving is 62.2 %.

/// All calibrated constants in one place.
#[derive(Debug, Clone, Copy)]
pub struct Constants {
    /// SRAM bit-cell area, single-ported (µm²/bit).
    pub a_bit: f64,
    /// Dual-port bit-cell area factor (8T vs 6T).
    pub pf_dp_area: f64,
    /// Column periphery (sense amps, drivers) per bit-column per port (µm²).
    pub a_col: f64,
    /// Row periphery (decoder slice) per row (µm²).
    pub a_row: f64,
    /// Register-file / flip-flop area per bit (µm²).
    pub a_ff: f64,
    /// MCU + handshake control overhead per framework (µm²).
    pub a_ctrl: f64,
    /// Ping-pong steering overhead per data bit (µm²): the 2:1 output mux
    /// plus the fill/drain select fanout of a double-buffered level —
    /// roughly one NAND2-equivalent per bit, far below the dual-port
    /// bit-cell premium it replaces.
    pub a_mux: f64,
    /// Single-ported bit leakage (W/bit).
    pub leak_bit_sp: f64,
    /// Dual-ported bit leakage (W/bit).
    pub leak_bit_dp: f64,
    /// Periphery leakage per bit-column per port (W).
    pub leak_col: f64,
    /// Flip-flop leakage (W/bit).
    pub leak_ff: f64,
    /// Read/write energy: per-bit term (J/bit).
    pub e_bit: f64,
    /// Read/write energy: per-√depth term (J/√word).
    pub e_depth: f64,
    /// Dual-port access-energy factor.
    pub pf_dp_energy: f64,
    /// Flip-flop toggle energy (J/bit).
    pub e_ff: f64,
    /// Flip-flop clock-tree energy per bit per clock cycle (J) — registers
    /// burn clock power every cycle regardless of data activity; this is
    /// what makes the wide-register Fig 7 configuration ≈2.5× the power.
    pub e_ff_clk: f64,
    /// On-chip interface energy per off-chip word transferred (J).
    pub e_io: f64,
    /// UltraTrail non-WMEM chip area (µm²) — MAC array, FMEM, control.
    pub ut_rest_area: f64,
    /// UltraTrail non-WMEM power at the 250 kHz case-study clock (W).
    pub ut_rest_power: f64,
}

/// The calibrated constant set (see module docs for the fit).
pub const fn constants() -> Constants {
    Constants {
        a_bit: 0.18,
        pf_dp_area: 1.9,
        a_col: 25.0,
        a_row: 0.5,
        a_ff: 3.0,
        a_ctrl: 400.0,
        a_mux: 0.6,
        leak_bit_sp: 0.3e-12,
        leak_bit_dp: 30.0e-12,
        leak_col: 50.0e-12,
        leak_ff: 4.0e-12,
        e_bit: 0.0036e-12,
        e_depth: 0.018e-12,
        pf_dp_energy: 1.9,
        e_ff: 0.0007e-12,
        e_ff_clk: 0.0072e-12,
        e_io: 1.0e-12,
        ut_rest_area: 28_976.0,
        ut_rest_power: 10.0e-6,
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{HierarchyConfig, PortKind};
    use crate::cost::sram::{hierarchy_area, sram_area};

    /// Fig 7 anchor: the 32-bit two-level framework synthesizes to
    /// 7 566 µm².
    #[test]
    fn fig7_anchor_32bit() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(32, 512, 1, 1)
            .level(32, 128, 1, 2)
            .build()
            .unwrap();
        let a = hierarchy_area(&cfg);
        let err = (a.total - 7_566.0).abs() / 7_566.0;
        assert!(err < 0.01, "32-bit framework area {} vs paper 7566 (err {err:.3})", a.total);
    }

    /// Fig 7 anchor: the equal-capacity 128-bit framework + OSR
    /// synthesizes to 15 202 µm².
    #[test]
    fn fig7_anchor_128bit() {
        let cfg = HierarchyConfig::builder()
            .offchip(32, 24, 1.0)
            .level(128, 128, 1, 1)
            .level(128, 32, 1, 2)
            .osr(128, vec![32])
            .build()
            .unwrap();
        let a = hierarchy_area(&cfg);
        let err = (a.total - 15_202.0).abs() / 15_202.0;
        assert!(err < 0.01, "128-bit framework area {} vs paper 15202 (err {err:.3})", a.total);
    }

    /// Both Fig 7 configurations hold the same bit capacity — the area
    /// difference is pure periphery + registers.
    #[test]
    fn fig7_equal_capacity() {
        let bits_a = (512 + 128) * 32u64;
        let bits_b = (128 + 32) * 128u64;
        assert_eq!(bits_a, bits_b);
    }

    /// Dual-porting a macro costs area in bit cells and column periphery.
    #[test]
    fn dual_port_area_premium() {
        let sp = sram_area(32, 512, PortKind::Single);
        let dp = sram_area(32, 512, PortKind::Dual);
        assert!(dp > 1.3 * sp, "dual-port premium too small: {sp} -> {dp}");
        assert!(dp < 2.5 * sp, "dual-port premium too large: {sp} -> {dp}");
    }

    /// Area model is monotone in every geometry parameter.
    #[test]
    fn area_monotonicity() {
        assert!(sram_area(64, 512, PortKind::Single) > sram_area(32, 512, PortKind::Single));
        assert!(sram_area(32, 1024, PortKind::Single) > sram_area(32, 512, PortKind::Single));
    }
}

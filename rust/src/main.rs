//! `memhier` CLI — leader entrypoint for the memory-hierarchy framework.
//!
//! Commands: `simulate`, `analyze`, `dse`, `dse-worker`, `casestudy`,
//! `report`, `infer`, `serve`, `waveform`. Run `memhier --help` for
//! usage.

use memhier::accel::UltraTrail;
use memhier::config::{HierarchyConfig, Protection};
use memhier::coordinator::{
    synth_request, KwsServer, ServerConfig, TrafficConfig, WarmingMode,
};
use memhier::dse::{
    explore, explore_halving, explore_halving_pruned, explore_halving_sharded, explore_joint,
    explore_joint_halving, explore_joint_halving_pruned, explore_joint_sharded, explore_parallel,
    explore_pruned, run_worker_chaos, HalvingSchedule, HierarchyPool, JointSpace, SearchSpace,
    ShardOptions,
};
use memhier::loopnest::unroll::paper_sweep;
use memhier::loopnest::{analyze_layer, LoopOrder};
use memhier::mem::Hierarchy;
use memhier::model::{LayerKind, LayerSpec};
use memhier::pattern::PatternProgram;
use memhier::report;
use memhier::util::cli::{Args, Cli, Command, OptSpec};
use memhier::util::table::{fnum, TextTable};

fn cli() -> Cli {
    Cli {
        bin: "memhier",
        about: "configurable memory hierarchy for NN accelerators (Bause et al. 2024 reproduction)",
        commands: vec![
            Command {
                name: "simulate",
                about: "run a pattern through a hierarchy config",
                opts: vec![
                    OptSpec { name: "config", help: "TOML config file (default: built-in 2-level)", takes_value: true, default: None },
                    OptSpec { name: "cycle-length", help: "pattern cycle length", takes_value: true, default: Some("64") },
                    OptSpec { name: "shift", help: "inter-cycle shift", takes_value: true, default: Some("0") },
                    OptSpec { name: "skip-shift", help: "cycles before each shift", takes_value: true, default: Some("0") },
                    OptSpec { name: "outputs", help: "data words to output", takes_value: true, default: Some("5000") },
                    OptSpec { name: "preload", help: "enable preloading", takes_value: false, default: None },
                    OptSpec { name: "stride", help: "address stride", takes_value: true, default: Some("1") },
                    OptSpec { name: "dump-outputs", help: "write the output stream (addr,payload CSV)", takes_value: true, default: None },
                ],
            },
            Command {
                name: "analyze",
                about: "loop-nest analysis of the TC-ResNet layers",
                opts: vec![OptSpec { name: "unroll", help: "unique addrs/step: 8|16|32|64", takes_value: true, default: Some("64") }],
            },
            Command {
                name: "dse",
                about: "design-space exploration for a workload pattern",
                opts: vec![
                    OptSpec { name: "cycle-length", help: "workload cycle length", takes_value: true, default: Some("128") },
                    OptSpec { name: "shift", help: "workload inter-cycle shift", takes_value: true, default: Some("0") },
                    OptSpec { name: "outputs", help: "workload size", takes_value: true, default: Some("5000") },
                    OptSpec { name: "threads", help: "worker threads (0 = all cores, 1 = serial)", takes_value: true, default: Some("0") },
                    OptSpec { name: "halving", help: "successive-halving sweep (checkpoint-resumed rungs)", takes_value: false, default: None },
                    OptSpec { name: "shards", help: "halving across worker processes (0 = in-process; needs --halving)", takes_value: true, default: Some("0") },
                    OptSpec { name: "prune", help: "analytical bound-and-prune prescreen (front stays bitwise-identical)", takes_value: false, default: None },
                    OptSpec { name: "joint", help: "joint mapping x hierarchy co-exploration (4-axis front incl. off-chip reads)", takes_value: false, default: None },
                    OptSpec { name: "protect", help: "sweep per-level protection (none|parity|secded) as a DSE dimension", takes_value: false, default: None },
                ],
            },
            Command {
                name: "dse-worker",
                about: "internal: evaluation worker for `dse --shards` (frames on stdin/stdout)",
                opts: vec![
                    OptSpec { name: "hang-after", help: "chaos: wedge (pipes open) on the request after N responses", takes_value: true, default: None },
                    OptSpec { name: "garbage-after", help: "chaos: answer the request after N responses with one corrupt frame", takes_value: true, default: None },
                ],
            },
            Command {
                name: "casestudy",
                about: "full UltraTrail case study (Fig 12 + per-layer timing)",
                opts: vec![OptSpec { name: "no-preload", help: "disable inter-layer preloading", takes_value: false, default: None }],
            },
            Command {
                name: "report",
                about: "regenerate a paper table/figure: fig5|fig6|fig7|fig8|fig9|fig10|fig12|table2|kinds|joint|all",
                opts: vec![OptSpec { name: "csv", help: "also write out/<id>.csv", takes_value: false, default: None }],
            },
            Command {
                name: "infer",
                about: "serve synthetic KWS requests through the compiled TC-ResNet",
                opts: vec![
                    OptSpec { name: "artifact", help: "HLO text artifact", takes_value: true, default: Some("artifacts/tcresnet.hlo.txt") },
                    OptSpec { name: "requests", help: "number of requests", takes_value: true, default: Some("32") },
                    OptSpec { name: "batch", help: "max batch size", takes_value: true, default: Some("8") },
                ],
            },
            Command {
                name: "serve",
                about: "multi-tenant serving tier over a seeded synthetic traffic trace",
                opts: vec![
                    OptSpec { name: "requests", help: "trace length", takes_value: true, default: Some("256") },
                    OptSpec { name: "tenants", help: "resident weight sets (Zipf-distributed)", takes_value: true, default: Some("48") },
                    OptSpec { name: "zipf", help: "tenant popularity skew exponent", takes_value: true, default: Some("1.1") },
                    OptSpec { name: "seed", help: "trace RNG seed", takes_value: true, default: Some("8058652") },
                    OptSpec { name: "batch", help: "max batch size", takes_value: true, default: Some("8") },
                    OptSpec { name: "warming", help: "speculative warming: off|sync|background", takes_value: true, default: Some("background") },
                    OptSpec { name: "slo-ms", help: "per-request SLO in ms (0 = best-effort)", takes_value: true, default: Some("0") },
                    OptSpec { name: "queue-depth", help: "admission queue bound (0 = unbounded)", takes_value: true, default: Some("1024") },
                    OptSpec { name: "tenant-cap", help: "per-tenant queue fairness cap (0 = uncapped)", takes_value: true, default: Some("0") },
                    OptSpec { name: "cached-bases", help: "cycle-cache capacity in tenants", takes_value: true, default: Some("8") },
                    OptSpec { name: "warm-capacity", help: "warm-store capacity in tenants", takes_value: true, default: Some("16") },
                ],
            },
            Command {
                name: "waveform",
                about: "dump a Fig-4-style waveform of the first cycles of a run",
                opts: vec![
                    OptSpec { name: "cycles", help: "cycles to render", takes_value: true, default: Some("32") },
                    OptSpec { name: "vcd", help: "write out/waveform.vcd", takes_value: false, default: None },
                ],
            },
        ],
    }
}

/// CLI result type: errors are printed and exit non-zero (offline build —
/// no `anyhow`; boxed errors carry the same context).
type CliResult = Result<(), Box<dyn std::error::Error>>;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let c = cli();
    let (cmd, args) = match c.parse(&argv) {
        Ok(x) => x,
        Err(help) => {
            println!("{help}");
            // Help requests exit 0; parse errors exit 2 so scripts fail loudly.
            let asked_for_help = argv.is_empty()
                || argv.iter().any(|a| a == "--help" || a == "-h" || a == "help");
            std::process::exit(if asked_for_help { 0 } else { 2 });
        }
    };
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> CliResult {
    match cmd {
        "simulate" => simulate(args),
        "analyze" => analyze(args),
        "dse" => dse(args),
        "dse-worker" => dse_worker(args),
        "casestudy" => casestudy(args),
        "report" => report_cmd(args),
        "infer" => infer(args),
        "serve" => serve(args),
        "waveform" => waveform(args),
        _ => unreachable!("cli validates commands"),
    }
}

fn default_config(preload: bool) -> HierarchyConfig {
    HierarchyConfig::builder()
        .offchip(32, 24, 1.0)
        .level(32, 1024, 1, 1)
        .level(32, 128, 1, 2)
        .preload(preload)
        .build()
        .expect("default config valid")
}

fn simulate(args: &Args) -> CliResult {
    let cfg = match args.get("config") {
        Some(path) => HierarchyConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => default_config(args.flag("preload")),
    };
    let l = args.get_parse("cycle-length", 64u64)?;
    let s = args.get_parse("shift", 0u64)?;
    let k = args.get_parse("skip-shift", 0u64)?;
    let n = args.get_parse("outputs", 5_000u64)?;
    let stride = args.get_parse("stride", 1u64)?;
    let mut prog = PatternProgram::shifted_cyclic(0, l, s).with_skip_shift(k).with_outputs(n);
    prog.stride = stride;
    let mut h = Hierarchy::new(&cfg)?;
    let dump = args.get("dump-outputs").map(str::to_string);
    h.set_collect(dump.is_some());
    h.load_program(&prog)?;
    let r = h.run()?;
    if let Some(path) = dump {
        // One row per off-chip unit: address, payload (hex) — the format
        // python/tests/test_cross_language.py compares against the golden
        // model.
        let mut out = String::from("addr,payload\n");
        let w_off = cfg.offchip.data_width;
        for ow in &r.outputs {
            for (j, &a) in ow.addrs.iter().enumerate() {
                let p = ow.word.bits(j as u32 * w_off, w_off).as_u64();
                out.push_str(&format!("{a},{p:x}\n"));
            }
        }
        std::fs::write(&path, out)?;
        println!("wrote output stream to {path}");
    }
    println!("outputs            : {}", r.stats.outputs);
    println!("internal cycles    : {}", r.stats.internal_cycles);
    println!("preload cycles     : {}", r.preload_cycles);
    println!("efficiency         : {:.3} outputs/cycle", r.stats.efficiency());
    println!("steady-state eff.  : {:.3}", r.stats.steady_state_efficiency());
    println!("off-chip reads     : {}", r.stats.offchip_reads);
    println!("reads/output       : {:.3}", r.stats.offchip_reads_per_output());
    println!("output stalls      : {}", r.stats.output_stalls);
    for (i, (w, rd)) in r.stats.level_writes.iter().zip(r.stats.level_reads.iter()).enumerate() {
        println!(
            "level {i}            : {w} writes, {rd} reads, {} write-over-read stalls",
            r.stats.write_over_read_stalls[i]
        );
    }
    let area = memhier::cost::hierarchy_area(&cfg);
    println!("chip area          : {:.0} um^2", area.total);
    let p = memhier::cost::run_power(&cfg, &r.stats, 100e6);
    println!("power @100MHz      : {:.3} mW", p.total * 1e3);
    Ok(())
}

fn analyze(args: &Args) -> CliResult {
    let u: u64 = args.get_parse("unroll", 64u64)?;
    let unroll = paper_sweep()
        .into_iter()
        .find(|(uu, _)| *uu == u)
        .map(|(_, un)| un)
        .ok_or("unroll must be 8|16|32|64")?;
    let mut t = TextTable::new(vec![
        "layer", "kind", "weight_unique", "weight_pattern", "reuse", "util", "mcu_ok",
    ]);
    for l in memhier::model::tc_resnet8() {
        let a = analyze_layer(&l, &unroll, LoopOrder::ultratrail());
        t.row(vec![
            a.layer.to_string(),
            format!("{:?}", a.kind),
            a.weight_unique.to_string(),
            format!("{:?}", a.weight_pattern).chars().take(44).collect(),
            fnum(a.weight_reuse, 1),
            fnum(a.utilization, 2),
            a.mcu_supported.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// The `dse` search space: the default space, with `--protect` widening
/// the per-level protection menu from unprotected-only to the full
/// none/parity/secded sweep (3x the candidates; protection never changes
/// cycle behavior, so the unprotected subset of the results is the plain
/// sweep bit for bit).
fn dse_space(args: &Args) -> SearchSpace {
    let mut space = SearchSpace::default();
    if args.flag("protect") {
        space.protections = vec![Protection::None, Protection::Parity, Protection::Secded];
    }
    space
}

fn dse(args: &Args) -> CliResult {
    if args.flag("joint") {
        return dse_joint(args);
    }
    let l = args.get_parse("cycle-length", 128u64)?;
    let s = args.get_parse("shift", 0u64)?;
    let n = args.get_parse("outputs", 5_000u64)?;
    let workload = PatternProgram::shifted_cyclic(0, l, s).with_outputs(n);
    let threads = args.get_parse("threads", 0usize)?;
    let shards = args.get_parse("shards", 0usize)?;
    let prune = args.flag("prune");
    let space = dse_space(args);
    if shards > 0 && !args.flag("halving") {
        return Err("--shards requires --halving (sharding drives the halving schedule)".into());
    }
    // The pool merge is deterministic: any thread count — and any shard
    // count — yields the serial result bit for bit, exhaustive and
    // halving alike; --prune keeps the front bitwise-identical too (it
    // only removes provably-dominated candidates).
    let (points, hstats, pstats) = if args.flag("halving") {
        let schedule = HalvingSchedule::for_workload(&workload);
        let outcome = if shards > 0 {
            let mut opts = ShardOptions::new(shards);
            opts.prune = prune;
            explore_halving_sharded(&space, &workload, &schedule, &opts)?
        } else if threads == 1 && prune {
            explore_halving_pruned(&space, &workload, &schedule)?
        } else if threads == 1 {
            explore_halving(&space, &workload, &schedule)?
        } else if prune {
            HierarchyPool::new(threads).explore_halving_pruned(&space, &workload, &schedule)?
        } else {
            HierarchyPool::new(threads).explore_halving(&space, &workload, &schedule)?
        };
        (outcome.points, Some(outcome.stats), None)
    } else if prune {
        let out = if threads == 1 {
            explore_pruned(&space, &workload)?
        } else {
            HierarchyPool::new(threads).explore_pruned(&space, &workload)?
        };
        (out.points, None, Some(out.stats))
    } else {
        let pts = if threads == 1 {
            explore(&space, &workload)?
        } else {
            explore_parallel(&space, &workload, threads)?
        };
        (pts, None, None)
    };
    let mut t = TextTable::new(vec!["config", "area_um2", "power_mW", "cycles", "eff", "pareto"]);
    for p in &points {
        t.row(vec![
            p.config.stack_desc(),
            fnum(p.area, 0),
            fnum(p.power * 1e3, 3),
            p.cycles.to_string(),
            fnum(p.efficiency, 3),
            if p.on_front { "*".to_string() } else { String::new() },
        ]);
    }
    println!("{}", t.render());
    println!("{} configurations evaluated, * = Pareto front", points.len());
    let (skipped, simulated, jumps) = memhier::dse::ff_totals(&points);
    println!(
        "engine fast-forward: {skipped} of {simulated} simulated cycles skipped in {jumps} jumps"
    );
    if let Some(ps) = pstats {
        println!(
            "bound-and-prune: {} enumerated, {} bound-pruned, {} simulated, {} skipped, \
             >= {} simulated cycles avoided",
            ps.enumerated, ps.bound_pruned, ps.simulated, ps.skipped, ps.cycles_saved_lb
        );
    }
    if let Some(st) = hstats {
        println!(
            "halving work: {} candidates -> {} exact-from-screen, {} pruned, {} resumed \
             completions, {} skipped",
            st.candidates, st.screen_exact, st.pruned, st.full_runs, st.skipped
        );
        if prune {
            println!(
                "bound-and-prune: {} of {} candidates bound-pruned before rung 0, \
                 >= {} simulated cycles avoided",
                st.bound_pruned, st.candidates, st.bound_cycles_saved
            );
        }
        println!(
            "resume accounting: {} cycles inherited from checkpoints (saved), {} cycles \
             simulated as resume deltas",
            st.saved_cycles, st.resumed_cycles
        );
        // Scheduling diagnostics vary with the worker/shard count, so
        // they are printed on their own greppable line — the CI shard
        // smoke diffs serial vs sharded output modulo this line (the
        // coordinator blob-store bytes ride along here for the same
        // reason: they exist only for sharded runs).
        if st.worker_items.len() > 1 {
            println!(
                "worker utilization: {:?} evaluations/worker, {} stolen from static owners, \
                 blob store {} bytes peak / {} inserted, {} respawns ({} backoffs)",
                st.worker_items,
                st.steals,
                st.blob_bytes_peak,
                st.blob_bytes_inserted,
                st.respawns,
                st.backoffs
            );
        }
    }
    Ok(())
}

/// `dse --joint`: joint mapping × hierarchy co-exploration. The mapping
/// menu is every spatial unrolling of a 16-MAC array on a small conv
/// layer crossed with the paper's two loop orders (each mapping's weight
/// stream derived and verified — see `memhier::dse::dims`); the config
/// half is the default space. Points carry their mapping and the front
/// is over four axes (area, power, cycles, off-chip reads). The
/// workload-shape flags (`--cycle-length`, `--shift`, `--outputs`) are
/// ignored here: joint workloads are derived from the mappings.
fn dse_joint(args: &Args) -> CliResult {
    let threads = args.get_parse("threads", 0usize)?;
    let shards = args.get_parse("shards", 0usize)?;
    let prune = args.flag("prune");
    if shards > 0 && !args.flag("halving") {
        return Err("--shards requires --halving (sharding drives the halving schedule)".into());
    }
    let layer = LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 };
    let joint = JointSpace::new(
        dse_space(args),
        layer,
        16,
        &[LoopOrder::ultratrail(), LoopOrder::output_stationary()],
    );
    let (points, hstats, jstats) = if args.flag("halving") {
        let schedule = HalvingSchedule::for_workloads(&joint.workloads);
        let outcome = if shards > 0 {
            let mut opts = ShardOptions::new(shards);
            opts.prune = prune;
            explore_joint_sharded(&joint, &schedule, &opts)?
        } else if threads == 1 && prune {
            explore_joint_halving_pruned(&joint, &schedule)?
        } else if threads == 1 {
            explore_joint_halving(&joint, &schedule)?
        } else if prune {
            HierarchyPool::new(threads).explore_joint_halving_pruned(&joint, &schedule)?
        } else {
            HierarchyPool::new(threads).explore_joint_halving(&joint, &schedule)?
        };
        (outcome.points, Some(outcome.stats), None)
    } else {
        let out = if threads == 1 {
            explore_joint(&joint)?
        } else {
            HierarchyPool::new(threads).explore_joint(&joint)?
        };
        (out.points, None, Some(out.stats))
    };
    let mut t = TextTable::new(vec![
        "config", "uk", "uc", "ux", "uf", "order", "area_um2", "power_mW", "cycles", "offchip",
        "eff", "pareto",
    ]);
    for p in &points {
        let m = p.mapping.expect("joint points carry their mapping");
        t.row(vec![
            p.config.stack_desc(),
            m.unrolling.uk.to_string(),
            m.unrolling.uc.to_string(),
            m.unrolling.ux.to_string(),
            m.unrolling.uf.to_string(),
            m.order_name(),
            fnum(p.area, 0),
            fnum(p.power * 1e3, 3),
            p.cycles.to_string(),
            p.offchip_reads.to_string(),
            fnum(p.efficiency, 3),
            if p.on_front { "*".to_string() } else { String::new() },
        ]);
    }
    println!("{}", t.render());
    println!(
        "{} (mapping, config) points over {} mappings, * = 4-axis Pareto front \
         (area, power, cycles, off-chip reads)",
        points.len(),
        joint.mappings.len()
    );
    if let Some(js) = jstats {
        println!(
            "joint pruning: {} enumerated, {} bound-pruned, {} simulated, {} memo hits, \
             {} skipped, >= {} simulated cycles avoided",
            js.enumerated, js.bound_pruned, js.simulated, js.memo_hits, js.skipped,
            js.cycles_saved_lb
        );
    }
    if let Some(st) = hstats {
        println!(
            "halving work: {} candidates -> {} exact-from-screen, {} pruned, {} resumed \
             completions, {} skipped",
            st.candidates, st.screen_exact, st.pruned, st.full_runs, st.skipped
        );
        if prune {
            println!(
                "bound-and-prune: {} of {} candidates bound-pruned before rung 0, \
                 >= {} simulated cycles avoided",
                st.bound_pruned, st.candidates, st.bound_cycles_saved
            );
        }
        println!(
            "resume accounting: {} cycles inherited from checkpoints (saved), {} cycles \
             simulated as resume deltas",
            st.saved_cycles, st.resumed_cycles
        );
        // Same greppable scheduling-diagnostics line as the config-only
        // sweep — the CI joint smoke diffs serial vs sharded modulo it.
        if st.worker_items.len() > 1 {
            println!(
                "worker utilization: {:?} evaluations/worker, {} stolen from static owners, \
                 blob store {} bytes peak / {} inserted, {} respawns ({} backoffs)",
                st.worker_items,
                st.steals,
                st.blob_bytes_peak,
                st.blob_bytes_inserted,
                st.respawns,
                st.backoffs
            );
        }
    }
    Ok(())
}

/// The `dse-worker` subcommand: serve shard evaluation requests over
/// stdin/stdout until the coordinator closes the pipe. Never invoked by
/// hand — see `memhier::dse::shard` for the protocol.
fn dse_worker(args: &Args) -> CliResult {
    let hang_after = args.get("hang-after").map(str::parse::<u64>).transpose()?;
    let garbage_after = args.get("garbage-after").map(str::parse::<u64>).transpose()?;
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    run_worker_chaos(stdin.lock(), stdout.lock(), hang_after, garbage_after)?;
    Ok(())
}

fn casestudy(args: &Args) -> CliResult {
    let preload = !args.flag("no-preload");
    let cs = UltraTrail::default().case_study(preload)?;
    println!("{}", report::fig12_table(preload)?.render());
    let mut t = TextTable::new(vec!["layer", "steps", "supply", "runtime", "rel"]);
    for lt in &cs.timing {
        t.row(vec![
            lt.layer.to_string(),
            lt.steps.to_string(),
            lt.supply.to_string(),
            lt.runtime.to_string(),
            fnum(lt.runtime as f64 / lt.steps as f64, 2),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn report_cmd(args: &Args) -> CliResult {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> = if which == "all" {
        vec!["table2", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig12", "kinds", "joint"]
    } else {
        vec![which]
    };
    for id in ids {
        let table = match id {
            "table2" => report::table2(),
            "fig5" => report::fig5_table()?,
            "fig6" => report::fig6_table()?,
            "fig7" => report::fig7_table()?,
            "fig8" => report::fig8_table()?,
            "fig9" => report::fig9_table(),
            "fig10" => report::fig10_table()?,
            "fig12" => report::fig12_table(true)?,
            "kinds" => report::level_kinds_table()?,
            "joint" => report::joint_table()?,
            other => return Err(format!("unknown report id {other:?}").into()),
        };
        println!("=== {id} ===");
        println!("{}", table.render());
        if args.flag("csv") {
            let path = report::save_csv(&table, id)?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

fn infer(args: &Args) -> CliResult {
    let artifact =
        std::path::PathBuf::from(args.get("artifact").unwrap_or("artifacts/tcresnet.hlo.txt"));
    let n = args.get_parse("requests", 32usize)?;
    let batch = args.get_parse("batch", 8usize)?;
    let mut server = KwsServer::new(
        &artifact,
        ServerConfig { max_batch: batch, ..ServerConfig::default() },
    )?;
    let requests: Vec<_> = (0..n as u64).map(synth_request).collect();
    let t0 = std::time::Instant::now();
    let results = server.serve_stream(requests)?;
    let wall = t0.elapsed();
    println!(
        "served {} requests in {:?} ({:.1} req/s)",
        results.len(),
        wall,
        results.len() as f64 / wall.as_secs_f64()
    );
    if let Some(c) = results.first().and_then(|r| r.accel_cycles) {
        println!(
            "co-simulated accelerator: {} cycles/inference = {:.1} ms @250kHz",
            c,
            c as f64 / 250e3 * 1e3
        );
    }
    let mut hist = vec![0usize; memhier::coordinator::N_CLASSES];
    for r in &results {
        hist[r.class] += 1;
    }
    println!("class histogram: {hist:?}");
    Ok(())
}

fn serve(args: &Args) -> CliResult {
    let slo_ms = args.get_parse("slo-ms", 0u64)?;
    let traffic = TrafficConfig {
        seed: args.get_parse("seed", 8_058_652u64)?,
        requests: args.get_parse("requests", 256usize)?,
        tenants: args.get_parse("tenants", 48usize)?,
        zipf_s: args.get_parse("zipf", 1.1f64)?,
        slo: (slo_ms > 0).then(|| std::time::Duration::from_millis(slo_ms)),
        ..TrafficConfig::default()
    };
    let warming = match args.get("warming").unwrap_or("background") {
        "off" => WarmingMode::Off,
        "sync" | "synchronous" => WarmingMode::Synchronous,
        "background" => WarmingMode::Background,
        other => return Err(format!("unknown warming mode {other:?} (off|sync|background)").into()),
    };
    let mut server = KwsServer::sim_only(ServerConfig {
        max_batch: args.get_parse("batch", 8usize)?,
        max_cached_bases: args.get_parse("cached-bases", 8usize)?,
        queue_depth: args.get_parse("queue-depth", 1024usize)?,
        tenant_cap: args.get_parse("tenant-cap", 0usize)?,
        warm_capacity: args.get_parse("warm-capacity", 16usize)?,
        warming,
        ..ServerConfig::default()
    })?;
    let trace = traffic.generate();
    let submitted = trace.len();
    let t0 = std::time::Instant::now();
    let results = server.serve_trace(trace)?;
    let wall = t0.elapsed();
    let s = server.stats();
    let us = |ns: u64| ns as f64 / 1e3;
    println!(
        "served {}/{} requests in {:?} ({:.1} req/s), {} batches",
        results.len(),
        submitted,
        wall,
        results.len() as f64 / wall.as_secs_f64(),
        s.batches
    );
    println!(
        "shed {} (queue-full {}, tenant-cap {}), deadline misses {}",
        s.shed, s.shed_queue_full, s.shed_tenant_cap, s.deadline_miss
    );
    println!(
        "cycle sources: {} cache hits, {} warm hits, {} cold sims",
        s.cache_hits, s.warm_hits, s.cold_sims
    );
    println!(
        "queue wait  p50/p95/p99: {:>8.1} {:>8.1} {:>8.1} us",
        us(s.queue_wait.p50()),
        us(s.queue_wait.p95()),
        us(s.queue_wait.p99())
    );
    println!(
        "service     p50/p95/p99: {:>8.1} {:>8.1} {:>8.1} us",
        us(s.service.p50()),
        us(s.service.p95()),
        us(s.service.p99())
    );
    println!(
        "accel cycles p50/p95/p99: {} {} {} (mean {:.0})",
        s.accel_cycles.p50(),
        s.accel_cycles.p95(),
        s.accel_cycles.p99(),
        s.mean_accel_cycles
    );
    if let Some(w) = server.warm_stats() {
        println!(
            "warmer: {} warmed, {} taken, {} evicted unused, {} oversize-rejected, {} parked now",
            w.warmed,
            w.taken,
            w.evicted,
            w.oversize_rejects,
            server.warm_parked().unwrap_or(0)
        );
    }
    let busiest = s.tenants.iter().max_by_key(|(_, t)| t.served);
    if let Some((base, t)) = busiest {
        println!(
            "hottest tenant {base:#x}: {} served ({} cache, {} warm, {} cold), {} shed",
            t.served, t.cache_hits, t.warm_hits, t.cold_sims, t.shed
        );
    }
    Ok(())
}

fn waveform(args: &Args) -> CliResult {
    let cycles = args.get_parse("cycles", 32u64)?;
    let cfg = default_config(false);
    let mut h = Hierarchy::new(&cfg)?;
    h.load_program(&PatternProgram::cyclic(0, 8).with_outputs(64))?;
    h.attach_waveform();
    h.run()?;
    let wf = h.take_waveform().expect("attached");
    println!("{}", wf.to_ascii(0, cycles));
    if args.flag("vcd") {
        std::fs::create_dir_all("out")?;
        std::fs::write("out/waveform.vcd", wf.to_vcd("memhier"))?;
        println!("wrote out/waveform.vcd");
    }
    Ok(())
}

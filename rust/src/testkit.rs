//! Minimal property-testing framework (offline substitute for `proptest`).
//!
//! Provides seeded random generation over parameter spaces and greedy
//! shrinking of failing cases. Invariant tests over the hierarchy
//! configuration × pattern space (`rust/tests/prop_hierarchy.rs`) are
//! built on this.

use crate::util::rng::{Rng, Xoshiro256};

/// A generated test case: a vector of chosen values, one per dimension.
pub type Case = Vec<u64>;

/// One dimension of the parameter space: an inclusive range.
#[derive(Debug, Clone, Copy)]
pub struct Dim {
    /// Dimension label for failure reports.
    pub name: &'static str,
    /// Minimum value (inclusive).
    pub min: u64,
    /// Maximum value (inclusive).
    pub max: u64,
}

impl Dim {
    /// New dimension.
    pub const fn new(name: &'static str, min: u64, max: u64) -> Self {
        Self { name, min, max }
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult {
    /// All cases passed.
    Pass {
        /// Number of cases executed.
        cases: u32,
    },
    /// A case failed; `shrunk` is the minimized counterexample.
    Fail {
        /// The originally failing case.
        original: Case,
        /// The shrunk counterexample.
        shrunk: Case,
        /// The failure message from the shrunk case.
        message: String,
    },
}

/// Run `prop` over `n_cases` random cases drawn from `dims`; shrink on
/// failure. `prop` returns `Err(msg)` to signal violation.
pub fn check(
    seed: u64,
    dims: &[Dim],
    n_cases: u32,
    mut prop: impl FnMut(&Case) -> Result<(), String>,
) -> PropResult {
    let mut rng = Xoshiro256::new(seed);
    for _ in 0..n_cases {
        let case: Case = dims
            .iter()
            .map(|d| d.min + rng.gen_range(d.max - d.min + 1))
            .collect();
        if let Err(first_msg) = prop(&case) {
            // Shrink: per dimension, decreasing-step descent — try lowering
            // by `step`, halve the step on success (a pass), keep failures.
            // Converges to the boundary for monotone properties.
            let mut shrunk = case.clone();
            let mut msg = first_msg;
            let mut progress = true;
            while progress {
                progress = false;
                for (i, d) in dims.iter().enumerate() {
                    let mut step = (shrunk[i] - d.min).div_ceil(2);
                    while step > 0 && shrunk[i] > d.min {
                        let mut candidate = shrunk.clone();
                        candidate[i] = shrunk[i] - step.min(shrunk[i] - d.min);
                        match prop(&candidate) {
                            Err(m) => {
                                shrunk = candidate;
                                msg = m;
                                progress = true;
                            }
                            Ok(()) => step /= 2,
                        }
                    }
                }
            }
            return PropResult::Fail { original: case, shrunk, message: msg };
        }
    }
    PropResult::Pass { cases: n_cases }
}

/// Assert helper: panic with a readable report when a property fails.
pub fn assert_prop(
    seed: u64,
    dims: &[Dim],
    n_cases: u32,
    prop: impl FnMut(&Case) -> Result<(), String>,
) {
    match check(seed, dims, n_cases, prop) {
        PropResult::Pass { .. } => {}
        PropResult::Fail { original, shrunk, message } => {
            let named = |c: &Case| {
                dims.iter()
                    .zip(c.iter())
                    .map(|(d, v)| format!("{}={}", d.name, v))
                    .collect::<Vec<_>>()
                    .join(", ")
            };
            panic!(
                "property failed\n  original: {}\n  shrunk:   {}\n  message:  {}",
                named(&original),
                named(&shrunk),
                message
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let dims = [Dim::new("a", 1, 100), Dim::new("b", 1, 100)];
        match check(1, &dims, 200, |c| {
            if c[0] + c[1] >= 2 { Ok(()) } else { Err("impossible".into()) }
        }) {
            PropResult::Pass { cases } => assert_eq!(cases, 200),
            f => panic!("{f:?}"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let dims = [Dim::new("x", 0, 1000)];
        match check(2, &dims, 500, |c| {
            if c[0] < 500 { Ok(()) } else { Err(format!("x={} too big", c[0])) }
        }) {
            PropResult::Fail { shrunk, .. } => {
                assert_eq!(shrunk[0], 500, "greedy shrink reaches the boundary");
            }
            PropResult::Pass { .. } => panic!("property should fail"),
        }
    }

    #[test]
    fn cases_respect_ranges() {
        let dims = [Dim::new("a", 5, 9)];
        check(3, &dims, 300, |c| {
            assert!((5..=9).contains(&c[0]));
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn assert_prop_panics_with_report() {
        assert_prop(4, &[Dim::new("v", 0, 10)], 100, |c| {
            if c[0] <= 8 { Ok(()) } else { Err("boom".into()) }
        });
    }
}

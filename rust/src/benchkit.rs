//! Minimal benchmarking harness (offline substitute for `criterion`).
//!
//! Provides warm-up, repeated timed runs, and mean/stddev/throughput
//! reporting. The `rust/benches/*` binaries use it with `harness = false`.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u32,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Standard deviation across iterations.
    pub stddev: Duration,
    /// Minimum observed iteration time.
    pub min: Duration,
}

impl BenchResult {
    /// Items/second at a given per-iteration item count.
    pub fn throughput(&self, items_per_iter: u64) -> f64 {
        items_per_iter as f64 / self.mean.as_secs_f64()
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12?} ±{:>10?} (min {:?}, n={})",
            self.name, self.mean, self.stddev, self.min, self.iters
        )
    }
}

/// Benchmark runner with fixed warm-up and measurement budgets.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    max_iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup: Duration::from_millis(200), measure: Duration::from_secs(1), max_iters: 200 }
    }
}

impl Bencher {
    /// Quick-mode bencher for CI-ish runs.
    pub fn quick() -> Self {
        Self { warmup: Duration::from_millis(50), measure: Duration::from_millis(300), max_iters: 50 }
    }

    /// Time `f` repeatedly; `black_box` its result to defeat DCE.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warm-up.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && (samples.len() as u32) < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let n = samples.len().max(1) as u32;
        let mean_ns = samples.iter().map(|d| d.as_nanos()).sum::<u128>() / n as u128;
        let var = samples
            .iter()
            .map(|d| {
                let diff = d.as_nanos() as i128 - mean_ns as i128;
                (diff * diff) as u128
            })
            .sum::<u128>()
            / n as u128;
        let stddev_ns = (var as f64).sqrt() as u64;
        BenchResult {
            name: name.to_string(),
            iters: n,
            mean: Duration::from_nanos(mean_ns as u64),
            stddev: Duration::from_nanos(stddev_ns),
            min: samples.iter().min().copied().unwrap_or_default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            max_iters: 30,
        };
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.iters >= 1);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.mean);
        assert!(r.throughput(10_000) > 0.0);
        assert!(r.summary().contains("spin"));
    }
}

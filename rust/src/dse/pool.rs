//! Parallel design-space exploration over a pool of warm hierarchy
//! sessions.
//!
//! `dse::explore` is embarrassingly parallel: every candidate
//! configuration is scored by an independent, deterministic simulation
//! ([`crate::sim::engine`] consumes no ambient state — no clocks, no
//! RNG), so a sweep can fan out across threads without changing a single
//! bit of the result. [`HierarchyPool`] does exactly that:
//!
//! 1. the candidate list is enumerated once (same odometer, same order,
//!    as the serial path);
//! 2. `N` `std::thread` workers claim candidates from an atomic cursor;
//!    each worker owns **one warm session** that is re-armed (never
//!    reallocated) for every candidate it scores — the workload
//!    [`PatternProgram`] is shared read-only;
//! 3. results carry their enumeration index and are merged by sorting on
//!    that index, so the merged list is byte-identical to what the
//!    serial loop would have produced regardless of thread scheduling
//!    (warm-vs-cold determinism makes the per-worker session history
//!    invisible);
//! 4. the shared `finalize` tail (Pareto marking + area sort) runs on
//!    the merged list.
//!
//! [`HierarchyPool::explore_halving`] layers the successive-halving
//! schedule of [`crate::dse::HalvingSchedule`] on a worker pool with a
//! **shared checkpoint store and work-stealing queue**: workers claim
//! undecided candidates from an atomic cursor, and each candidate's
//! suspended [`crate::mem::HierarchyCheckpoint`] lives in a store any
//! worker can resume from — rung *k* resumes each undecided candidate
//! from its rung *k−1* state and simulates only the budget delta, and
//! survivors resume to completion instead of restarting. Per-worker
//! utilization and steal counts are reported in
//! [`crate::dse::HalvingStats`].
//!
//! ## Determinism guarantee
//!
//! For any thread count, [`HierarchyPool::explore`] returns a
//! [`DesignPoint`] list bitwise-identical to [`explore`]: same points,
//! same order, same `f64` bits, same Pareto front. This is asserted by
//! the `pool_matches_serial_bitwise` test and re-checked by the
//! `dse_pool` bench; wall-clock scales with cores because >99 % of the
//! time is spent inside the per-candidate simulations. The same holds
//! for `explore_halving` versus its serial counterpart.

use super::bound::prescreen;
use super::dims::JointSpace;
use super::search::{
    enumerate, explore, explore_pruned, finalize, halving_impl, joint_explore_impl,
    joint_halving_impl, DesignPoint, EvalSession, HalvingOutcome, HalvingSchedule, JointExplore,
    PrunedExplore, SearchSpace,
};
use crate::pattern::PatternProgram;
use crate::util::par_map_indexed_with;
use crate::Result;

/// A fixed-size worker pool evaluating hierarchy candidates in parallel.
#[derive(Debug, Clone)]
pub struct HierarchyPool {
    threads: usize,
}

impl HierarchyPool {
    /// New pool with `threads` workers; `0` means one worker per
    /// available core.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Self { threads: threads.max(1) }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Explore the space against a workload pattern on the pool.
    /// Bitwise-identical to [`explore`] (see module docs), but wall-clock
    /// scales with the worker count. Each worker keeps one warm session
    /// across all candidates it claims.
    pub fn explore(
        &self,
        space: &SearchSpace,
        workload: &PatternProgram,
    ) -> Result<Vec<DesignPoint>> {
        if self.threads == 1 {
            return explore(space, workload);
        }
        let candidates = enumerate(space);
        // Deterministic merge: par_map_indexed_with returns evaluation
        // results in enumeration order regardless of thread scheduling,
        // so the flattened list matches the serial filter_map exactly.
        let scored = par_map_indexed_with(
            candidates.len(),
            self.threads,
            EvalSession::new,
            |session, i| session.evaluate(candidates[i].clone(), workload, space.eval_hz),
        );
        Ok(finalize(scored.into_iter().flatten().collect()))
    }

    /// [`Self::explore`] behind the analytical bound-and-prune front end
    /// ([`crate::dse::bound`]). The prescreen itself is a serial stream
    /// (cheap: no simulation); only the survivors' cycle-accurate
    /// evaluations fan out over the pool. Bitwise-identical to the serial
    /// [`crate::dse::explore_pruned`] for any thread count.
    pub fn explore_pruned(
        &self,
        space: &SearchSpace,
        workload: &PatternProgram,
    ) -> Result<PrunedExplore> {
        if self.threads == 1 {
            return explore_pruned(space, workload);
        }
        let outcome = prescreen(space, workload);
        let mut stats = outcome.stats;
        let survivors = outcome.survivors;
        let scored = par_map_indexed_with(
            survivors.len(),
            self.threads,
            EvalSession::new,
            |session, i| session.evaluate(survivors[i].clone(), workload, space.eval_hz),
        );
        let points: Vec<DesignPoint> = scored.into_iter().flatten().collect();
        stats.skipped += stats.simulated - points.len();
        stats.simulated = points.len();
        Ok(PrunedExplore { points: finalize(points), pruned: outcome.pruned, stats })
    }

    /// Successive-halving exploration on the pool (see
    /// [`HalvingSchedule`]): screening rungs and survivor completion fan
    /// out over warm per-worker sessions claiming candidates from a
    /// shared work-stealing queue, with suspended states in a shared
    /// checkpoint store any worker can resume from. Bitwise-identical to
    /// the serial [`crate::dse::explore_halving`] for any thread count —
    /// points, front, and `HalvingStats` included (modulo the scheduling
    /// diagnostics its equality deliberately excludes).
    pub fn explore_halving(
        &self,
        space: &SearchSpace,
        workload: &PatternProgram,
        schedule: &HalvingSchedule,
    ) -> Result<HalvingOutcome> {
        halving_impl(space, workload, schedule, self.threads, true, false)
    }

    /// [`Self::explore_halving`] behind the analytical prescreen (the
    /// pooled [`crate::dse::explore_halving_pruned`]): rungs only ever
    /// see prescreen survivors.
    pub fn explore_halving_pruned(
        &self,
        space: &SearchSpace,
        workload: &PatternProgram,
        schedule: &HalvingSchedule,
    ) -> Result<HalvingOutcome> {
        halving_impl(space, workload, schedule, self.threads, true, true)
    }

    /// [`Self::explore_halving`] with restart screening (every rung
    /// re-runs undecided candidates from scratch; survivors restart their
    /// full run) — the pre-checkpoint baseline, kept for differential
    /// tests and the `halving_resume` bench.
    pub fn explore_halving_restart(
        &self,
        space: &SearchSpace,
        workload: &PatternProgram,
        schedule: &HalvingSchedule,
    ) -> Result<HalvingOutcome> {
        halving_impl(space, workload, schedule, self.threads, false, false)
    }

    /// Joint mapping × hierarchy exploration on the pool (the pooled
    /// [`crate::dse::explore_joint`]): prescreen and equivalence-class
    /// grouping run serially (cheap, no simulation); only the one
    /// representative simulation per behavior class fans out over warm
    /// per-worker sessions. Bitwise-identical to the serial path for any
    /// thread count — class representatives are merged in class order,
    /// and class members are scored from their representative's stats
    /// exactly as the serial loop does.
    pub fn explore_joint(&self, joint: &JointSpace) -> Result<JointExplore> {
        joint_explore_impl(joint, self.threads)
    }

    /// Successive-halving joint exploration on the pool (the pooled
    /// [`crate::dse::explore_joint_halving`]).
    pub fn explore_joint_halving(
        &self,
        joint: &JointSpace,
        schedule: &HalvingSchedule,
    ) -> Result<HalvingOutcome> {
        joint_halving_impl(joint, schedule, self.threads, false)
    }

    /// [`Self::explore_joint_halving`] behind the analytical joint
    /// prescreen (the pooled [`crate::dse::explore_joint_halving_pruned`]).
    pub fn explore_joint_halving_pruned(
        &self,
        joint: &JointSpace,
        schedule: &HalvingSchedule,
    ) -> Result<HalvingOutcome> {
        joint_halving_impl(joint, schedule, self.threads, true)
    }
}

/// Convenience: explore on a fresh pool (`threads = 0` → all cores).
pub fn explore_parallel(
    space: &SearchSpace,
    workload: &PatternProgram,
    threads: usize,
) -> Result<Vec<DesignPoint>> {
    HierarchyPool::new(threads).explore(space, workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::PatternProgram;

    use crate::dse::KindChoice;

    fn small_space() -> SearchSpace {
        SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![32, 128],
            word_widths: vec![32],
            level_kinds: vec![KindChoice::Standard, KindChoice::DoubleBuffered],
            try_dual_ported: true,
            protections: vec![crate::config::Protection::None],
            eval_hz: 100e6,
        }
    }

    fn assert_identical(a: &[DesignPoint], b: &[DesignPoint]) {
        assert_eq!(a.len(), b.len(), "point counts differ");
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.config, y.config);
            assert_eq!(x.area.to_bits(), y.area.to_bits(), "area bits differ");
            assert_eq!(x.power.to_bits(), y.power.to_bits(), "power bits differ");
            assert_eq!(x.cycles, y.cycles);
            assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits());
            assert_eq!(x.on_front, y.on_front);
        }
    }

    #[test]
    fn pool_matches_serial_bitwise() {
        let w = PatternProgram::shifted_cyclic(0, 64, 16).with_outputs(640);
        let serial = explore(&small_space(), &w).unwrap();
        assert!(serial.len() >= 4, "space must be non-trivial");
        for threads in [1usize, 2, 4, 8] {
            let pooled = HierarchyPool::new(threads).explore(&small_space(), &w).unwrap();
            assert_identical(&serial, &pooled);
        }
    }

    #[test]
    fn pool_repeated_runs_are_stable() {
        // Thread scheduling varies between runs; results must not.
        let w = PatternProgram::cyclic(0, 128).with_outputs(1_280);
        let pool = HierarchyPool::new(4);
        let a = pool.explore(&small_space(), &w).unwrap();
        let b = pool.explore(&small_space(), &w).unwrap();
        assert_identical(&a, &b);
    }

    #[test]
    fn zero_threads_autodetects() {
        // The resolution rule `0 → available_parallelism` is part of the
        // API (the CLI default and the shard coordinator both lean on
        // it): pin it exactly, with the documented fallback to 1 when
        // the platform cannot answer.
        let expect = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let p = HierarchyPool::new(0);
        assert_eq!(p.threads(), expect);
        assert!(p.threads() >= 1);
        // Explicit counts are taken as-is.
        assert_eq!(HierarchyPool::new(3).threads(), 3);
    }

    #[test]
    fn pooled_pruned_explore_matches_serial_bitwise() {
        let w = PatternProgram::cyclic(0, 64).with_outputs(640);
        let serial = explore_pruned(&small_space(), &w).unwrap();
        assert!(!serial.points.is_empty());
        for threads in [2usize, 4] {
            let pooled =
                HierarchyPool::new(threads).explore_pruned(&small_space(), &w).unwrap();
            assert_identical(&serial.points, &pooled.points);
            assert_eq!(serial.stats, pooled.stats, "threads={threads}");
            assert_eq!(serial.pruned.len(), pooled.pruned.len());
            for (a, b) in serial.pruned.iter().zip(pooled.pruned.iter()) {
                assert_eq!(a.config, b.config);
                assert_eq!(a.score.area.to_bits(), b.score.area.to_bits());
                assert_eq!(a.score.cycles_lb, b.score.cycles_lb);
                assert_eq!(a.score.cycles_ub, b.score.cycles_ub);
            }
        }
    }

    #[test]
    fn pooled_halving_matches_serial_bitwise() {
        let space = SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![32, 128, 1024],
            word_widths: vec![32],
            level_kinds: vec![KindChoice::Standard],
            try_dual_ported: false,
            protections: vec![crate::config::Protection::None],
            eval_hz: 100e6,
        };
        let w = PatternProgram::cyclic(0, 256).with_outputs(2_560);
        let schedule = crate::dse::HalvingSchedule::for_workload(&w);
        let serial = crate::dse::explore_halving(&space, &w, &schedule).unwrap();
        for threads in [2usize, 4] {
            let pooled = HierarchyPool::new(threads)
                .explore_halving(&space, &w, &schedule)
                .unwrap();
            assert_identical(&serial.points, &pooled.points);
            assert_eq!(serial.stats, pooled.stats, "threads={threads}");
        }
    }
}

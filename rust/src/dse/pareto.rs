//! Pareto-front extraction over (area, power, runtime) objectives.

/// Dominance relation between two objective vectors (lower is better).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dominance {
    /// First dominates second.
    Dominates,
    /// Second dominates first.
    Dominated,
    /// Neither dominates.
    Incomparable,
}

/// Compare two objective vectors (must be equal length; lower is better).
pub fn dominance(a: &[f64], b: &[f64]) -> Dominance {
    debug_assert_eq!(a.len(), b.len());
    let mut a_better = false;
    let mut b_better = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x < y {
            a_better = true;
        }
        if y < x {
            b_better = true;
        }
    }
    match (a_better, b_better) {
        (true, false) => Dominance::Dominates,
        (false, true) => Dominance::Dominated,
        _ => Dominance::Incomparable,
    }
}

/// Indices of the Pareto-optimal elements of `points` (lower = better).
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && dominance(q, p) == Dominance::Dominates {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basic() {
        assert_eq!(dominance(&[1.0, 1.0], &[2.0, 2.0]), Dominance::Dominates);
        assert_eq!(dominance(&[2.0, 2.0], &[1.0, 1.0]), Dominance::Dominated);
        assert_eq!(dominance(&[1.0, 3.0], &[2.0, 2.0]), Dominance::Incomparable);
        assert_eq!(dominance(&[1.0, 1.0], &[1.0, 1.0]), Dominance::Incomparable);
    }

    #[test]
    fn front_extraction() {
        let pts = vec![
            vec![1.0, 5.0], // front
            vec![5.0, 1.0], // front
            vec![3.0, 3.0], // front
            vec![4.0, 4.0], // dominated by [3,3]
            vec![6.0, 6.0], // dominated
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn single_point_is_front() {
        assert_eq!(pareto_front(&[vec![1.0]]), vec![0]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn duplicates_all_on_front() {
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0]];
        assert_eq!(pareto_front(&pts), vec![0, 1]);
    }
}

//! Pareto-front extraction over (area, power, runtime) objectives, plus
//! the incremental bound-frontier the analytical pruner queries.

use std::collections::BTreeMap;

/// Dominance relation between two objective vectors (lower is better).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dominance {
    /// First dominates second.
    Dominates,
    /// Second dominates first.
    Dominated,
    /// Neither dominates.
    Incomparable,
}

/// Compare two objective vectors (must be equal length; lower is better).
pub fn dominance(a: &[f64], b: &[f64]) -> Dominance {
    debug_assert_eq!(a.len(), b.len());
    let mut a_better = false;
    let mut b_better = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x < y {
            a_better = true;
        }
        if y < x {
            b_better = true;
        }
    }
    match (a_better, b_better) {
        (true, false) => Dominance::Dominates,
        (false, true) => Dominance::Dominated,
        _ => Dominance::Incomparable,
    }
}

/// Indices of the Pareto-optimal elements of `points` (lower = better).
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && dominance(q, p) == Dominance::Dominates {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Incrementally maintained witness frontier for interval dominance,
/// over (area, cycles) plus any number of **auxiliary** upper/lower
/// bounded axes (power for the config-only prescreen; power and
/// off-chip reads for the joint mapping × hierarchy prescreen).
///
/// Each inserted *witness* is an enumerated candidate's exact area plus
/// its **worst-case** cycles and auxiliary values (`cycle_upper_bound`,
/// power at that bound, exact traffic). A queried candidate is provably
/// absent from the exact Pareto front if some witness's worst case
/// dominates the candidate's **best** case — exact area,
/// `cycle_lower_bound`, per-axis lower bounds (see [`crate::dse`]
/// module docs for the soundness argument).
///
/// The frontier stores only witnesses Pareto-minimal in (area,
/// worst-case cycles), as a staircase keyed by area: walking towards
/// larger area, worst-case cycles strictly decrease; the auxiliary
/// values ride along on their witness. Insert and query are both
/// `O(log n)`: a query looks up the predecessor witness — the one with
/// the smallest worst-case cycles among all witnesses no larger in area
/// — and tests dominance against that single witness (checking its
/// auxiliary axes too, which is conservative but sound: a prune always
/// names one concrete dominating witness). Every insert/query of one
/// frontier must use the same auxiliary-axis count and order.
#[derive(Debug, Default)]
pub struct BoundFrontier {
    /// `area.to_bits() -> (cycles_ub, aux_ub)` — positive-f64 bit
    /// patterns order identically to the values, so the map is
    /// area-sorted with exact (bitwise) keys.
    stairs: BTreeMap<u64, (u64, Vec<f64>)>,
}

impl BoundFrontier {
    /// Empty frontier.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of Pareto-minimal witnesses retained.
    pub fn len(&self) -> usize {
        self.stairs.len()
    }

    /// Whether any witness has been retained.
    pub fn is_empty(&self) -> bool {
        self.stairs.is_empty()
    }

    /// Record a witness (exact `area`, worst-case `cycles_ub`, worst-case
    /// auxiliary values `aux_ub`). Witnesses dominated in (area, cycles)
    /// by an existing stair are dropped; stairs dominated by the new
    /// witness are removed. Staircase minimality is decided on (area,
    /// cycles) alone — auxiliary axes only gate queries.
    pub fn insert(&mut self, area: f64, cycles_ub: u64, aux_ub: &[f64]) {
        debug_assert!(
            area >= 0.0 && aux_ub.iter().all(|&v| v >= 0.0),
            "objectives must be non-negative"
        );
        let key = area.to_bits();
        if let Some((_, (c, _))) = self.stairs.range(..=key).next_back() {
            if *c <= cycles_ub {
                return; // an existing stair is no worse on both axes
            }
        }
        // Remove now-dominated stairs: equal or larger area with equal or
        // larger worst-case cycles (a contiguous run from `key` upward).
        let doomed: Vec<u64> = self
            .stairs
            .range(key..)
            .take_while(|(_, (c, _))| *c >= cycles_ub)
            .map(|(&k, _)| k)
            .collect();
        for k in doomed {
            self.stairs.remove(&k);
        }
        self.stairs.insert(key, (cycles_ub, aux_ub.to_vec()));
    }

    /// Whether a candidate with best case (`area`, `cycles_lb`,
    /// per-axis `aux_lb`) is interval-dominated by some retained witness
    /// — i.e. provably not on the exact Pareto front. Requires
    /// strictness on area or cycles so that a candidate is never pruned
    /// by a witness it ties with on every axis (ties survive to the
    /// exact sweep, which keeps duplicates on the front).
    pub fn dominated(&self, area: f64, cycles_lb: u64, aux_lb: &[f64]) -> bool {
        let key = area.to_bits();
        match self.stairs.range(..=key).next_back() {
            Some((&wkey, (c_ub, aux_ub))) => {
                debug_assert_eq!(aux_ub.len(), aux_lb.len(), "auxiliary axis count mismatch");
                *c_ub <= cycles_lb
                    && aux_ub.iter().zip(aux_lb.iter()).all(|(w, c)| w <= c)
                    && (*c_ub < cycles_lb || wkey < key)
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basic() {
        assert_eq!(dominance(&[1.0, 1.0], &[2.0, 2.0]), Dominance::Dominates);
        assert_eq!(dominance(&[2.0, 2.0], &[1.0, 1.0]), Dominance::Dominated);
        assert_eq!(dominance(&[1.0, 3.0], &[2.0, 2.0]), Dominance::Incomparable);
        assert_eq!(dominance(&[1.0, 1.0], &[1.0, 1.0]), Dominance::Incomparable);
    }

    #[test]
    fn front_extraction() {
        let pts = vec![
            vec![1.0, 5.0], // front
            vec![5.0, 1.0], // front
            vec![3.0, 3.0], // front
            vec![4.0, 4.0], // dominated by [3,3]
            vec![6.0, 6.0], // dominated
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn single_point_is_front() {
        assert_eq!(pareto_front(&[vec![1.0]]), vec![0]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn duplicates_all_on_front() {
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0]];
        assert_eq!(pareto_front(&pts), vec![0, 1]);
    }

    #[test]
    fn frontier_staircase_prunes_and_retains() {
        let mut f = BoundFrontier::new();
        assert!(f.is_empty());
        f.insert(10.0, 100, &[1.0]);
        // Worse on both axes than the stair: dominated (strict on area).
        assert!(f.dominated(11.0, 100, &[1.0]));
        // Equal on every axis: never pruned (ties go to the exact sweep).
        assert!(!f.dominated(10.0, 100, &[1.0]));
        // Strictly more cycles at equal area: dominated.
        assert!(f.dominated(10.0, 101, &[1.0]));
        // Better cycles than any witness's worst case: kept.
        assert!(!f.dominated(11.0, 99, &[1.0]));
        // Smaller area than every witness: kept.
        assert!(!f.dominated(9.0, 1_000, &[9.0]));
        // Power best-case below the witness's worst case: kept.
        assert!(!f.dominated(11.0, 100, &[0.5]));
    }

    #[test]
    fn frontier_insert_keeps_only_minimal_stairs() {
        let mut f = BoundFrontier::new();
        f.insert(10.0, 100, &[1.0]);
        f.insert(20.0, 50, &[1.0]); // new stair (fewer cycles at larger area)
        f.insert(15.0, 200, &[1.0]); // dominated by the 10.0 stair: dropped
        assert_eq!(f.len(), 2);
        f.insert(5.0, 40, &[1.0]); // dominates both stairs: replaces them
        assert_eq!(f.len(), 1);
        assert!(f.dominated(10.0, 100, &[1.0]));
        assert!(f.dominated(20.0, 50, &[1.0]));
        assert!(!f.dominated(5.0, 40, &[1.0]));
    }

    #[test]
    fn frontier_query_uses_predecessor_witness() {
        let mut f = BoundFrontier::new();
        f.insert(10.0, 100, &[5.0]);
        f.insert(20.0, 50, &[1.0]);
        // Candidate at area 15: only the 10.0 witness qualifies on area,
        // and its power worst case (5.0) exceeds the candidate's best
        // case (2.0) — no prune even though the 20.0 witness's power
        // would pass (its area does not).
        assert!(!f.dominated(15.0, 100, &[2.0]));
        // Same cycles/power best case at area 25: the 20.0 witness wins.
        assert!(f.dominated(25.0, 100, &[2.0]));
    }

    #[test]
    fn frontier_checks_every_auxiliary_axis() {
        // Joint-search shape: aux = [power, off-chip reads]. A candidate
        // better than the witness on ANY aux axis survives.
        let mut f = BoundFrontier::new();
        f.insert(10.0, 100, &[1.0, 500.0]);
        assert!(f.dominated(11.0, 100, &[1.0, 500.0]));
        // Fewer off-chip reads than the witness's worst case: kept.
        assert!(!f.dominated(11.0, 100, &[1.0, 400.0]));
        // Less power but more traffic: kept (incomparable on aux).
        assert!(!f.dominated(11.0, 100, &[0.5, 600.0]));
        // Worse on both aux axes: dominated.
        assert!(f.dominated(11.0, 100, &[2.0, 600.0]));
        // Staircase minimality ignores aux: a same-cycles insert at
        // larger area is dropped even with smaller aux values.
        f.insert(12.0, 100, &[0.1, 1.0]);
        assert_eq!(f.len(), 1);
    }
}

//! Sharded successive-halving DSE across OS processes.
//!
//! The in-process halving explorer ([`crate::dse::explore_halving`] and
//! its pooled variant) parallelizes over threads; this module farms the
//! same sweep out over **worker processes**, with suspended candidates
//! crossing the process boundary in the checkpoint wire format
//! ([`crate::mem::wire`]). The coordinator owns the candidate odometer,
//! the rung state machine, and a work-stealing queue; workers are
//! `dse-worker` subcommand invocations of the `memhier` binary speaking
//! length-prefixed frames over stdin/stdout:
//!
//! ```text
//!  coordinator (this module)                 worker 0..N  (memhier dse-worker)
//!  ─────────────────────────                 ──────────────────────────────────
//!  enumerate(space) ─► queue
//!        │ claim (work-stealing cursor)
//!        ▼
//!  ┌ REQ_EVAL ──────────────────────────────► stdin
//!  │   index, budget, eval_hz, keep_ckpt        │ decode; EvalSession (warm);
//!  │   + checkpoint blob (resume)               │ restore ckpt if present;
//!  │   | config TOML + program (cold)           │ eval_budgeted(budget delta)
//!  │                                            ▼
//!  └ stdout ◄────────────────────────── RESP_RESULT
//!        │     index, Δresumed, Δsaved,   (or RESP_ERR: protocol error)
//!        │     Skip | Exact{scores} | Partial{screen, ckpt blob}
//!        ▼
//!  apply in enumeration order; prune dominated; retain blobs;
//!  next rung re-ships each survivor's blob to *whichever worker
//!  steals it* — candidates migrate freely between workers mid-run.
//! ```
//!
//! ## Determinism
//!
//! The Pareto front (points, order, `f64` bits) is **bitwise-identical**
//! to the serial [`crate::dse::explore`]/`explore_halving` result, for
//! any shard count and any scheduling: per-candidate evaluation is the
//! same [`eval_budgeted`] code path the serial explorer runs (on a warm
//! session, warm==cold guaranteed), checkpoints round-trip bitwise
//! through the wire format, responses are applied in enumeration order,
//! and the prune rule ([`prune_dominated`]) is a pure function of the
//! merged rung results. Scores travel as IEEE-754 bit patterns, never
//! through text.
//!
//! ## Failure model and recovery
//!
//! The coordinator's checkpoint-blob store is updated only *between*
//! rungs, so every in-flight request can be rebuilt verbatim from the
//! store, and a misbehaving worker costs at most its in-flight
//! candidate — never the sweep:
//!
//! | failure                            | detected by                 | recovery |
//! |------------------------------------|-----------------------------|----------|
//! | crash / kill / EOF                 | reader thread (`Dead` event) | respawn the slot (next generation — stale events ignored), re-dispatch the lost claim |
//! | hang (no response, pipes open)     | per-candidate deadline ([`ShardOptions::deadline`]) | kill + respawn the slot, re-dispatch the lost claim |
//! | corrupt / truncated frame          | [`parse_response`] decode failure | respawn the slot (its stream can no longer be trusted), re-dispatch |
//! | worker-reported error (`RESP_ERR`) | response decode             | fatal: a protocol bug, not a candidate failure |
//!
//! A slot that fails repeatedly backs off exponentially (base 10 ms,
//! capped at 1 s) before each respawn, and a global respawn budget turns
//! a persistently dying fleet into an error instead of an infinite
//! kill/respawn spin. The [`ShardOptions::kill_after`],
//! [`ShardOptions::hang_after`], and [`ShardOptions::garbage_after`]
//! chaos knobs exercise these paths in tests and CI; under each of them
//! the returned front and stats stay bitwise-identical to the serial
//! explorer, with only the resilience diagnostics
//! ([`HalvingStats::respawns`], [`HalvingStats::backoffs`]) recording
//! the incidents.

use super::bound::{joint_prescreen, prescreen, PrunedPoint};
use super::dims::{JointSpace, Mapping};
use super::search::{
    enumerate, eval_budgeted, finalize_axes, prune_dominated, undecided_indices, CandidateState,
    DesignPoint, EvalSession, HalvingOutcome, HalvingSchedule, HalvingStats, Screen,
    ScreenOutcome, SearchSpace,
};
use crate::config::HierarchyConfig;
use crate::mem::{wire, FunctionalModel};
use crate::pattern::PatternProgram;
use crate::util::frame::{read_frame, write_frame, ByteReader, ByteWriter};
use crate::{Error, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// Frame tag: coordinator → worker evaluation request.
const REQ_EVAL: u8 = 1;
/// Frame tag: worker → coordinator evaluation result.
const RESP_RESULT: u8 = 2;
/// Frame tag: worker → coordinator protocol-level error (bad request).
const RESP_ERR: u8 = 3;

/// Default per-candidate deadline ([`ShardOptions::deadline`]): how long
/// one worker may hold one evaluation request before the coordinator
/// declares it hung, kills it, and re-dispatches the candidate on a
/// replacement. Generous: a single candidate's budget delta simulates in
/// well under this on any plausible hardware.
const DEFAULT_DEADLINE: Duration = Duration::from_secs(600);

/// Options for [`explore_halving_sharded`].
#[derive(Debug, Clone)]
pub struct ShardOptions {
    /// Worker process count; `0` resolves like
    /// [`crate::dse::HierarchyPool::new`] (one per available core).
    pub shards: usize,
    /// Worker executable; `None` uses the current executable
    /// (`std::env::current_exe`), which is the normal production mode.
    /// Tests point this at `CARGO_BIN_EXE_memhier`.
    pub worker_cmd: Option<PathBuf>,
    /// Chaos knob: after this many responses have been received, kill
    /// one worker process once (the slot after the one that just
    /// responded), exercising the crash-recovery path. `None` in
    /// production.
    pub kill_after: Option<u64>,
    /// Per-candidate watchdog: a worker holding one evaluation request
    /// longer than this is declared hung, killed, and replaced, and the
    /// candidate is re-dispatched — a hung worker costs one deadline, not
    /// the sweep. `None` disables the watchdog (the coordinator then
    /// waits indefinitely). Defaults to [`DEFAULT_DEADLINE`].
    pub deadline: Option<Duration>,
    /// Chaos knob: the *initial* worker on slot 0 wedges (sleeps forever
    /// holding its pipes open) on the request after this many responses,
    /// exercising the deadline/kill/re-dispatch path. `None` in
    /// production.
    pub hang_after: Option<u64>,
    /// Chaos knob: the *initial* worker on slot 0 answers the request
    /// after this many responses with one corrupted frame (unknown tag,
    /// junk body), exercising the corrupt-frame respawn path. `None` in
    /// production.
    pub garbage_after: Option<u64>,
    /// Run the analytical bound-and-prune prescreen
    /// ([`crate::dse::bound`]) on the coordinator before dispatching:
    /// provably-dominated candidates never reach a worker, and come back
    /// bound-scored in [`HalvingOutcome::pruned`]. Off by default.
    pub prune: bool,
}

impl ShardOptions {
    /// Options for `shards` workers with production defaults.
    pub fn new(shards: usize) -> Self {
        Self {
            shards,
            worker_cmd: None,
            kill_after: None,
            deadline: Some(DEFAULT_DEADLINE),
            hang_after: None,
            garbage_after: None,
            prune: false,
        }
    }
}

/// Run the `dse-worker` protocol over the given byte streams (the
/// subcommand binds these to stdin/stdout). Serves [`REQ_EVAL`] frames
/// on one warm [`EvalSession`] until clean EOF; request-level failures
/// (undecodable frames) are answered with [`RESP_ERR`] and the loop
/// continues — candidate-level failures are ordinary `Skip` results.
pub fn run_worker(input: impl Read, output: impl Write) -> Result<()> {
    run_worker_chaos(input, output, None, None)
}

/// [`run_worker`] with the chaos knobs wired: `hang_after` wedges the
/// worker (sleeps forever, pipes open) on the request after that many
/// responses, and `garbage_after` answers that request with one corrupt
/// frame instead — the worker-side halves of
/// [`ShardOptions::hang_after`] / [`ShardOptions::garbage_after`]. Both
/// `None` in production (the plain `dse-worker` subcommand).
pub fn run_worker_chaos(
    mut input: impl Read,
    mut output: impl Write,
    hang_after: Option<u64>,
    garbage_after: Option<u64>,
) -> Result<()> {
    let mut sess = EvalSession::new();
    let mut served = 0u64;
    while let Some((tag, body)) = read_frame(&mut input)? {
        if hang_after == Some(served) {
            // Chaos: wedge without closing the pipes. The coordinator
            // sees neither a response nor an EOF — only the per-candidate
            // deadline fires, and the watchdog's respawn kills us.
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        if garbage_after == Some(served) {
            // Chaos: one corrupt frame (unknown tag, junk body) instead
            // of the response. The coordinator replaces this worker, so
            // nothing after this frame is ever trusted.
            write_frame(&mut output, 0xAA, &[0xDE, 0xAD, 0xBE, 0xEF])?;
            served += 1;
            continue;
        }
        match handle_request(&mut sess, tag, &body) {
            Ok(resp) => write_frame(&mut output, RESP_RESULT, &resp)?,
            Err(e) => {
                let mut w = ByteWriter::new();
                w.put_str(&e.to_string());
                write_frame(&mut output, RESP_ERR, &w.into_bytes())?;
            }
        }
        served += 1;
    }
    Ok(())
}

/// Decode one evaluation request, run it, encode the response body.
fn handle_request(sess: &mut EvalSession, tag: u8, body: &[u8]) -> Result<Vec<u8>> {
    if tag != REQ_EVAL {
        return Err(Error::Parse(format!("dse-worker: unknown request tag {tag}")));
    }
    let mut r = ByteReader::new(body);
    let index = r.get_usize()?;
    let budget = r.get_u64()?;
    let eval_hz = r.get_f64()?;
    let keep_ckpt = r.get_bool()?;
    let (cfg, workload, inherited) = if r.get_bool()? {
        let (ck, workload) = wire::decode_checkpoint(r.get_bytes()?)?;
        (ck.config().clone(), workload, Some(ck))
    } else {
        let cfg = HierarchyConfig::from_toml(r.get_str()?)?;
        let workload = wire::read_program(&mut r)?;
        workload.validate()?;
        (cfg, workload, None)
    };
    r.finish()?;
    let delta =
        eval_budgeted(sess, &cfg, &workload, budget, eval_hz, inherited.as_ref(), keep_ckpt);
    let mut w = ByteWriter::new();
    w.put_usize(index);
    w.put_u64(delta.resumed);
    w.put_u64(delta.saved);
    match delta.outcome {
        ScreenOutcome::Skip => w.put_u8(0),
        ScreenOutcome::Exact(p) => {
            w.put_u8(1);
            w.put_f64(p.area);
            w.put_f64(p.power);
            w.put_u64(p.cycles);
            w.put_f64(p.efficiency);
            w.put_u64(p.skipped_cycles);
            w.put_u64(p.ff_jumps);
            w.put_u64(p.offchip_reads);
        }
        ScreenOutcome::Partial(sc) => {
            w.put_u8(2);
            w.put_u64(sc.units);
            w.put_f64(sc.area);
            w.put_f64(sc.power);
            match delta.ckpt {
                Some(ck) => {
                    w.put_bool(true);
                    w.put_bytes(&wire::encode_checkpoint(&ck, &workload)?);
                }
                None => w.put_bool(false),
            }
        }
    }
    Ok(w.into_bytes())
}

/// A decoded worker response.
struct EvalResponse {
    index: usize,
    resumed: u64,
    saved: u64,
    outcome: RespOutcome,
}

/// The outcome part of an [`EvalResponse`]. Mirrors
/// [`ScreenOutcome`] with scores carried as raw values (the coordinator
/// re-attaches the candidate's config — both sides enumerate the same
/// odometer) and the suspended state as a wire blob.
enum RespOutcome {
    /// Candidate invalid / misaligned / failed to simulate.
    Skip,
    /// Exactly scored within the budget.
    Exact {
        area: f64,
        power: f64,
        cycles: u64,
        efficiency: f64,
        skipped: u64,
        jumps: u64,
        offchip: u64,
    },
    /// Budget expired: proxies, plus the re-suspended checkpoint blob
    /// when the request asked for one.
    Partial { screen: Screen, ckpt: Option<Vec<u8>> },
}

/// Decode a worker frame into an [`EvalResponse`]; [`RESP_ERR`] frames
/// surface as [`Error::Runtime`] (a protocol bug, not a candidate skip).
fn parse_response(tag: u8, body: &[u8]) -> Result<EvalResponse> {
    let mut r = ByteReader::new(body);
    match tag {
        RESP_RESULT => {
            let index = r.get_usize()?;
            let resumed = r.get_u64()?;
            let saved = r.get_u64()?;
            let outcome = match r.get_u8()? {
                0 => RespOutcome::Skip,
                1 => RespOutcome::Exact {
                    area: r.get_f64()?,
                    power: r.get_f64()?,
                    cycles: r.get_u64()?,
                    efficiency: r.get_f64()?,
                    skipped: r.get_u64()?,
                    jumps: r.get_u64()?,
                    offchip: r.get_u64()?,
                },
                2 => {
                    // Traffic is never shipped: the coordinator fills it
                    // analytically when the axis is on (it is exact and
                    // budget-independent, like the in-process driver).
                    let screen = Screen {
                        units: r.get_u64()?,
                        area: r.get_f64()?,
                        power: r.get_f64()?,
                        traffic: 0,
                    };
                    let ckpt = if r.get_bool()? { Some(r.get_bytes()?.to_vec()) } else { None };
                    RespOutcome::Partial { screen, ckpt }
                }
                t => return Err(Error::Parse(format!("shard: unknown outcome tag {t}"))),
            };
            r.finish()?;
            Ok(EvalResponse { index, resumed, saved, outcome })
        }
        RESP_ERR => Err(Error::Runtime(format!("dse worker error: {}", r.get_str()?))),
        t => Err(Error::Parse(format!("shard: unknown response tag {t}"))),
    }
}

/// Event a worker's reader thread reports to the coordinator.
enum Event {
    /// A frame arrived from the worker on `slot`.
    Frame { slot: usize, gen: u64, tag: u8, body: Vec<u8> },
    /// The worker on `slot` is gone (EOF or read error).
    Dead { slot: usize, gen: u64 },
}

/// One worker slot: the child process, its request pipe, and what it is
/// currently evaluating (`(claim position, candidate index)`).
struct WorkerSlot {
    child: Child,
    stdin: Option<ChildStdin>,
    gen: u64,
    inflight: Option<(usize, usize)>,
    /// When the in-flight request was dispatched; drives the
    /// per-candidate deadline watchdog. `None` while idle.
    dispatched_at: Option<Instant>,
}

/// The coordinator's worker fleet.
struct WorkerPool {
    cmd: PathBuf,
    slots: Vec<WorkerSlot>,
    events: Receiver<Event>,
    tx: Sender<Event>,
    /// Candidates evaluated per slot (across respawns of that slot).
    items: Vec<u64>,
    /// Claims whose static owner was a different slot.
    steals: u64,
    /// Responses received across the whole run (chaos-kill trigger).
    responses_total: u64,
    /// Whether the `kill_after` chaos kill has fired.
    chaos_fired: bool,
    /// Respawns performed (runaway-crash backstop; surfaced as
    /// [`HalvingStats::respawns`]).
    respawns: usize,
    /// Backoff sleeps taken before respawns of a repeatedly failing slot
    /// (surfaced as [`HalvingStats::backoffs`]).
    backoffs: u64,
    /// Consecutive failures per slot since its last accepted response —
    /// drives the capped exponential backoff.
    fail_streak: Vec<u32>,
    /// Per-candidate deadline (see [`ShardOptions::deadline`]).
    deadline: Option<Duration>,
    /// Chaos knobs forwarded to the initial slot-0 worker's command line
    /// (see [`ShardOptions::hang_after`] / [`ShardOptions::garbage_after`]).
    hang_after: Option<u64>,
    garbage_after: Option<u64>,
}

impl WorkerPool {
    /// Spawn `shards` worker processes running `cmd dse-worker`, with the
    /// deadline and chaos knobs taken from `opts`.
    fn spawn(cmd: PathBuf, shards: usize, opts: &ShardOptions) -> Result<Self> {
        let (tx, events) = channel();
        let mut pool = Self {
            cmd,
            slots: Vec::with_capacity(shards),
            events,
            tx,
            items: vec![0; shards],
            steals: 0,
            responses_total: 0,
            chaos_fired: false,
            respawns: 0,
            backoffs: 0,
            fail_streak: vec![0; shards],
            deadline: opts.deadline,
            hang_after: opts.hang_after,
            garbage_after: opts.garbage_after,
        };
        for slot in 0..shards {
            let s = pool.spawn_slot(slot, 0)?;
            pool.slots.push(s);
        }
        Ok(pool)
    }

    /// Spawn one worker process for `slot` at generation `gen`, with a
    /// detached reader thread forwarding its frames (and its death) to
    /// the coordinator's event channel.
    fn spawn_slot(&self, slot: usize, gen: u64) -> Result<WorkerSlot> {
        let mut command = Command::new(&self.cmd);
        command.arg("dse-worker");
        // Chaos knobs target the *initial* slot-0 worker only: its
        // replacement (next generation) is a clean process, so recovery —
        // not the misbehavior — is what the sweep actually exercises.
        if slot == 0 && gen == 0 {
            if let Some(n) = self.hang_after {
                command.args(["--hang-after", &n.to_string()]);
            }
            if let Some(n) = self.garbage_after {
                command.args(["--garbage-after", &n.to_string()]);
            }
        }
        let mut child = command
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .map_err(|e| Error::Runtime(format!("shard: spawning worker: {e}")))?;
        let stdin = child
            .stdin
            .take()
            .ok_or_else(|| Error::Runtime("shard: worker stdin was not piped".into()))?;
        let mut stdout = child
            .stdout
            .take()
            .ok_or_else(|| Error::Runtime("shard: worker stdout was not piped".into()))?;
        let tx = self.tx.clone();
        std::thread::spawn(move || loop {
            match read_frame(&mut stdout) {
                Ok(Some((tag, body))) => {
                    if tx.send(Event::Frame { slot, gen, tag, body }).is_err() {
                        return;
                    }
                }
                Ok(None) | Err(_) => {
                    let _ = tx.send(Event::Dead { slot, gen });
                    return;
                }
            }
        });
        Ok(WorkerSlot { child, stdin: Some(stdin), gen, inflight: None, dispatched_at: None })
    }

    /// Kill and replace the worker on `slot` with a fresh process (next
    /// generation — events from the old process are ignored). The old
    /// in-flight claim, if any, is returned for re-dispatch. A slot that
    /// fails repeatedly (no accepted response between failures) sleeps a
    /// capped exponential backoff first — 10 ms doubling to a 1 s cap —
    /// so a persistently broken environment burns bounded process churn
    /// while the global respawn budget runs down.
    fn respawn(&mut self, slot: usize) -> Result<Option<(usize, usize)>> {
        self.respawns += 1;
        if self.respawns > self.slots.len() * 8 + 4 {
            return Err(Error::Runtime(
                "shard: workers keep dying; giving up after repeated respawns".into(),
            ));
        }
        self.fail_streak[slot] += 1;
        let streak = self.fail_streak[slot];
        if streak > 1 {
            let ms = (10u64 << (streak - 2).min(7)).min(1_000);
            std::thread::sleep(Duration::from_millis(ms));
            self.backoffs += 1;
        }
        let gen = self.slots[slot].gen + 1;
        let old = std::mem::replace(&mut self.slots[slot], self.spawn_slot(slot, gen)?);
        let WorkerSlot { mut child, stdin, inflight, .. } = old;
        drop(stdin);
        let _ = child.kill();
        let _ = child.wait();
        Ok(inflight)
    }

    /// Send the request for claim `k` / candidate `idx` to `slot`. A
    /// write failure is not an error: the worker is dying, its reader
    /// thread will report [`Event::Dead`], and the recorded in-flight
    /// claim gets re-dispatched on a fresh process. Utilization/steal
    /// counters are tallied when the *response* lands, so a crashed and
    /// re-dispatched candidate counts once.
    fn dispatch(&mut self, slot: usize, k: usize, idx: usize, req: &[u8]) {
        self.slots[slot].inflight = Some((k, idx));
        self.slots[slot].dispatched_at = Some(Instant::now());
        if let Some(stdin) = &mut self.slots[slot].stdin {
            let _ = write_frame(stdin, REQ_EVAL, req);
        }
    }

    /// Kill, replace, and re-dispatch every worker whose in-flight
    /// request has been outstanding longer than the per-candidate
    /// deadline. A hung worker (wedged process, pipes still open — the
    /// reader thread never reports a death) therefore costs one
    /// candidate's deadline, not the sweep. No-op when the watchdog is
    /// disabled.
    fn reap_expired(&mut self, build_req: &impl Fn(usize, usize) -> Vec<u8>) -> Result<()> {
        let Some(deadline) = self.deadline else { return Ok(()) };
        for slot in 0..self.slots.len() {
            let expired = self.slots[slot].inflight.is_some()
                && self.slots[slot].dispatched_at.is_some_and(|t| t.elapsed() >= deadline);
            if !expired {
                continue;
            }
            let lost = self.respawn(slot)?;
            if let Some((k, idx)) = lost {
                self.dispatch(slot, k, idx, &build_req(k, idx));
            }
        }
        Ok(())
    }

    /// Chaos: kill the slot after `responding` once the configured
    /// response count is reached (see [`ShardOptions::kill_after`]).
    fn maybe_chaos_kill(&mut self, kill_after: Option<u64>, responding: usize) {
        if self.chaos_fired || kill_after != Some(self.responses_total) {
            return;
        }
        self.chaos_fired = true;
        let victim = (responding + 1) % self.slots.len();
        // Drop the pipe and kill the process; the reader thread turns
        // this into a normal Dead event — recovery is the real path.
        self.slots[victim].stdin = None;
        let _ = self.slots[victim].child.kill();
    }

    /// Run one pass: evaluate every candidate in `items` (indices into
    /// the odometer), building each request with `build_req`, and return
    /// the responses sorted by candidate index. Workers claim candidates
    /// work-stealing style; a dead worker's in-flight claim is re-built
    /// and re-dispatched on its replacement. `on_resp` fires once per
    /// accepted response, mid-pass, with the responding candidate's
    /// index — the blob-release hook: a responded candidate can never be
    /// re-dispatched in this pass, so its stored blob is dead from that
    /// moment.
    fn run_pass(
        &mut self,
        items: &[usize],
        kill_after: Option<u64>,
        build_req: impl Fn(usize, usize) -> Vec<u8>,
        mut on_resp: impl FnMut(usize),
    ) -> Result<Vec<EvalResponse>> {
        let mut responses: Vec<EvalResponse> = Vec::with_capacity(items.len());
        let mut cursor = 0usize;
        // Prime every idle slot with one claim each.
        for slot in 0..self.slots.len() {
            if cursor < items.len() {
                let (k, idx) = (cursor, items[cursor]);
                cursor += 1;
                self.dispatch(slot, k, idx, &build_req(k, idx));
            }
        }
        // Poll granularity for the deadline watchdog: a fraction of the
        // deadline, clamped so a tight test deadline still gets several
        // checks and a generous production one does not spin the
        // coordinator.
        let tick = self.deadline.map_or(Duration::from_secs(1), |d| {
            (d / 8).clamp(Duration::from_millis(10), Duration::from_secs(1))
        });
        while responses.len() < items.len() {
            let ev = match self.events.recv_timeout(tick) {
                Ok(ev) => ev,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    self.reap_expired(&build_req)?;
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(Error::Runtime("shard: worker event channel closed".into()));
                }
            };
            match ev {
                Event::Frame { slot, gen, tag, body } => {
                    if self.slots[slot].gen != gen {
                        continue; // stale frame from a replaced process
                    }
                    let resp = match parse_response(tag, &body) {
                        Ok(resp) => resp,
                        Err(Error::Parse(_)) => {
                            // Corrupt/truncated frame: the worker's byte
                            // stream can no longer be trusted (framing may
                            // be desynchronized). Replace the process and
                            // re-dispatch its claim.
                            if let Some((k, idx)) = self.respawn(slot)? {
                                self.dispatch(slot, k, idx, &build_req(k, idx));
                            }
                            continue;
                        }
                        // RESP_ERR and I/O failures are protocol bugs, not
                        // recoverable worker misbehavior.
                        Err(e) => return Err(e),
                    };
                    match self.slots[slot].inflight.take() {
                        Some((k, idx)) if idx == resp.index => {
                            self.slots[slot].dispatched_at = None;
                            self.fail_streak[slot] = 0;
                            self.items[slot] += 1;
                            if k % self.slots.len() != slot {
                                self.steals += 1;
                            }
                        }
                        other => {
                            return Err(Error::Runtime(format!(
                                "shard: worker answered candidate {} while {:?} was in flight",
                                resp.index,
                                other.map(|(_, i)| i),
                            )));
                        }
                    }
                    on_resp(resp.index);
                    responses.push(resp);
                    self.responses_total += 1;
                    self.maybe_chaos_kill(kill_after, slot);
                    if cursor < items.len() && self.slots[slot].stdin.is_some() {
                        let (k, idx) = (cursor, items[cursor]);
                        cursor += 1;
                        self.dispatch(slot, k, idx, &build_req(k, idx));
                    }
                }
                Event::Dead { slot, gen } => {
                    if self.slots[slot].gen != gen {
                        continue; // stale death of an already-replaced process
                    }
                    let lost = self.respawn(slot)?;
                    match lost {
                        // Re-dispatch exactly what died with the worker: a
                        // dead worker's claim never responded, so its blob
                        // is still stored (the release hook fires only on
                        // responses, and new blobs land only between
                        // passes) and the rebuilt request is
                        // byte-identical.
                        Some((k, idx)) => self.dispatch(slot, k, idx, &build_req(k, idx)),
                        None if cursor < items.len() => {
                            let (k, idx) = (cursor, items[cursor]);
                            cursor += 1;
                            self.dispatch(slot, k, idx, &build_req(k, idx));
                        }
                        None => {}
                    }
                }
            }
        }
        responses.sort_by_key(|r| r.index);
        Ok(responses)
    }
}

impl Drop for WorkerPool {
    /// Close every request pipe (workers exit on EOF) and reap the
    /// children, killing stragglers.
    fn drop(&mut self) {
        for s in &mut self.slots {
            s.stdin = None;
        }
        for s in &mut self.slots {
            let _ = s.child.kill();
            let _ = s.child.wait();
        }
    }
}

/// Suspended-candidate wire blobs held by the coordinator, keyed by
/// candidate index, with byte-level accounting
/// ([`HalvingStats::blob_bytes_peak`] /
/// [`HalvingStats::blob_bytes_inserted`]).
///
/// Interior mutability lets the mid-pass release hook drop a responded
/// candidate's blob while the request-builder closure still holds a
/// shared borrow of the store. Only *in-flight* candidates ever need
/// their blob (crash re-dispatch), so a blob is dead the moment its
/// candidate's response is accepted — previously the survivor completion
/// pass kept every survivor's blob alive to the end of the sweep, and
/// screening passes kept each rung's full blob set resident until the
/// between-rung retain.
struct BlobStore {
    inner: RefCell<BlobStoreInner>,
}

#[derive(Default)]
struct BlobStoreInner {
    blobs: BTreeMap<usize, Vec<u8>>,
    /// Bytes currently resident.
    bytes_now: u64,
    /// Largest `bytes_now` ever observed.
    bytes_peak: u64,
    /// Total bytes ever inserted (peak < inserted proves blobs were
    /// released while others were still live).
    bytes_inserted: u64,
}

impl BlobStore {
    fn new() -> Self {
        Self { inner: RefCell::new(BlobStoreInner::default()) }
    }

    /// Candidate `idx`'s blob, cloned (it is about to be framed into a
    /// request anyway).
    fn get(&self, idx: usize) -> Option<Vec<u8>> {
        self.inner.borrow().blobs.get(&idx).cloned()
    }

    /// Store (or replace) candidate `idx`'s blob.
    fn insert(&self, idx: usize, blob: Vec<u8>) {
        let mut s = self.inner.borrow_mut();
        let len = blob.len() as u64;
        if let Some(old) = s.blobs.insert(idx, blob) {
            s.bytes_now -= old.len() as u64;
        }
        s.bytes_now += len;
        s.bytes_inserted += len;
        s.bytes_peak = s.bytes_peak.max(s.bytes_now);
    }

    /// Drop candidate `idx`'s blob, if stored.
    fn remove(&self, idx: usize) {
        let mut s = self.inner.borrow_mut();
        if let Some(old) = s.blobs.remove(&idx) {
            s.bytes_now -= old.len() as u64;
        }
    }

    /// Drop every blob whose candidate index fails `keep`.
    fn retain(&self, keep: impl Fn(usize) -> bool) {
        let mut s = self.inner.borrow_mut();
        let mut freed = 0u64;
        s.blobs.retain(|i, b| {
            let kept = keep(*i);
            if !kept {
                freed += b.len() as u64;
            }
            kept
        });
        s.bytes_now -= freed;
    }

    fn bytes_now(&self) -> u64 {
        self.inner.borrow().bytes_now
    }

    fn bytes_peak(&self) -> u64 {
        self.inner.borrow().bytes_peak
    }

    fn bytes_inserted(&self) -> u64 {
        self.inner.borrow().bytes_inserted
    }
}

/// Successive-halving exploration sharded across worker processes; see
/// the module docs for the protocol and the determinism and
/// crash-recovery guarantees. The returned points, front, and
/// `HalvingStats` semantics are bitwise-identical to the serial
/// [`crate::dse::explore_halving`] (scheduling diagnostics —
/// `worker_items`, `steals`, `respawns`, `backoffs` — reflect the shard
/// fleet instead; the blob-byte counters report coordinator memory). With
/// [`ShardOptions::prune`] the analytical prescreen runs first and the
/// fleet only ever sees survivors.
pub fn explore_halving_sharded(
    space: &SearchSpace,
    workload: &PatternProgram,
    schedule: &HalvingSchedule,
    opts: &ShardOptions,
) -> Result<HalvingOutcome> {
    let (candidates, bound_pruned, hstats) = if opts.prune {
        let outcome = prescreen(space, workload);
        let hstats = HalvingStats {
            candidates: outcome.stats.enumerated,
            skipped: outcome.stats.skipped,
            bound_pruned: outcome.stats.bound_pruned,
            bound_cycles_saved: outcome.stats.cycles_saved_lb,
            ..Default::default()
        };
        (outcome.survivors, outcome.pruned, hstats)
    } else {
        let candidates = enumerate(space);
        let hstats = HalvingStats { candidates: candidates.len(), ..Default::default() };
        (candidates, Vec::new(), hstats)
    };
    sharded_core(
        candidates.into_iter().map(|c| (0, c)).collect(),
        std::slice::from_ref(workload),
        None,
        schedule,
        opts,
        space.eval_hz,
        false,
        bound_pruned,
        hstats,
    )
}

/// Joint mapping × hierarchy successive halving sharded across worker
/// processes — the multi-process form of
/// [`crate::dse::explore_joint_halving`]. The coordinator owns the joint
/// odometer (and, with [`ShardOptions::prune`], the joint analytical
/// prescreen — provably-dominated *(mapping, config)* candidates never
/// reach a worker); each cold request ships the candidate's *derived
/// mapping workload*, the between-rung prune groups by mapping and
/// carries the exact analytic traffic axis, mappings are re-attached by
/// the coordinator (they never cross the wire), and the final front is
/// taken over four axes. Bitwise-identical points and front to the
/// serial joint halving for any shard count.
pub fn explore_joint_sharded(
    joint: &JointSpace,
    schedule: &HalvingSchedule,
    opts: &ShardOptions,
) -> Result<HalvingOutcome> {
    let (candidates, bound_pruned, hstats) = if opts.prune {
        let outcome = joint_prescreen(joint);
        let hstats = HalvingStats {
            candidates: outcome.stats.enumerated,
            skipped: outcome.stats.skipped,
            bound_pruned: outcome.stats.bound_pruned,
            bound_cycles_saved: outcome.stats.cycles_saved_lb,
            ..Default::default()
        };
        let candidates = outcome.survivors.into_iter().map(|s| (s.widx, s.cfg)).collect();
        (candidates, outcome.pruned, hstats)
    } else {
        let candidates: Vec<(usize, HierarchyConfig)> = joint.candidates().collect();
        let hstats = HalvingStats { candidates: candidates.len(), ..Default::default() };
        (candidates, Vec::new(), hstats)
    };
    sharded_core(
        candidates,
        &joint.workloads,
        Some(&joint.mappings),
        schedule,
        opts,
        joint.space.eval_hz,
        true,
        bound_pruned,
        hstats,
    )
}

/// The shard coordinator behind both the config-only and the joint
/// sweeps — the multi-process mirror of
/// [`crate::dse::search::halving_core`]: candidates are *(workload
/// index, config)* pairs over a workload menu, screened dominance is
/// grouped by workload index, and with `traffic_axis` each suspended
/// candidate's [`Screen`] carries its exact analytic off-chip reads
/// (computed once, coordinator-side — traffic never crosses the wire).
#[allow(clippy::too_many_arguments)]
fn sharded_core(
    candidates: Vec<(usize, HierarchyConfig)>,
    workloads: &[PatternProgram],
    mappings: Option<&[Mapping]>,
    schedule: &HalvingSchedule,
    opts: &ShardOptions,
    eval_hz: f64,
    traffic_axis: bool,
    bound_pruned: Vec<PrunedPoint>,
    mut hstats: HalvingStats,
) -> Result<HalvingOutcome> {
    use CandidateState as State;

    let n = candidates.len();
    let widx: Vec<usize> = candidates.iter().map(|&(w, _)| w).collect();
    let group_outputs: Vec<u64> = workloads.iter().map(|w| w.total_outputs).collect();
    let shards = if opts.shards == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        opts.shards
    };
    let shards = shards.max(1).min(n.max(1));
    let cmd = match &opts.worker_cmd {
        Some(p) => p.clone(),
        None => std::env::current_exe()
            .map_err(|e| Error::Runtime(format!("shard: locating worker binary: {e}")))?,
    };
    let mut pool = WorkerPool::spawn(cmd, shards, opts)?;
    let mut states: Vec<State> = vec![State::Undecided(None); n];
    // Analytic traffic per candidate, filled on first suspension (exact
    // and budget-independent; mirrors the in-process halving driver).
    let mut traffic: Vec<Option<u64>> = vec![None; n];
    // Suspended candidates as wire blobs. New blobs land only *between*
    // passes (crash re-dispatch depends on that); the mid-pass release
    // hook drops a blob the moment its candidate responds.
    let store = BlobStore::new();
    let cold_req = |idx: usize, budget: u64, keep: bool| {
        let (wi, cfg) = &candidates[idx];
        let mut w = ByteWriter::new();
        w.put_usize(idx);
        w.put_u64(budget);
        w.put_f64(eval_hz);
        w.put_bool(keep);
        w.put_bool(false);
        w.put_str(&cfg.to_toml());
        wire::write_program(&workloads[*wi], &mut w);
        w.into_bytes()
    };
    let resume_req = |idx: usize, blob: &[u8], budget: u64, keep: bool| {
        let mut w = ByteWriter::new();
        w.put_usize(idx);
        w.put_u64(budget);
        w.put_f64(eval_hz);
        w.put_bool(keep);
        w.put_bool(true);
        w.put_bytes(blob);
        w.into_bytes()
    };

    for &budget in &schedule.budgets {
        let undecided = undecided_indices(&states);
        if undecided.is_empty() {
            break;
        }
        let screened = pool.run_pass(
            &undecided,
            opts.kill_after,
            |_, idx| match store.get(idx) {
                Some(blob) => resume_req(idx, &blob, budget, true),
                None => cold_req(idx, budget, true),
            },
            // Mid-pass release: a responded candidate's previous-rung
            // blob can never be re-dispatched again.
            |idx| store.remove(idx),
        )?;
        for resp in screened {
            hstats.resumed_cycles += resp.resumed;
            hstats.saved_cycles += resp.saved;
            states[resp.index] = match resp.outcome {
                RespOutcome::Skip => {
                    hstats.skipped += 1;
                    State::Skipped
                }
                RespOutcome::Exact { area, power, cycles, efficiency, skipped, jumps, offchip } => {
                    hstats.screen_exact += 1;
                    State::Exact(DesignPoint {
                        config: candidates[resp.index].1.clone(),
                        area,
                        power,
                        cycles,
                        efficiency,
                        on_front: false,
                        skipped_cycles: skipped,
                        ff_jumps: jumps,
                        offchip_reads: offchip,
                        mapping: None,
                    })
                }
                RespOutcome::Partial { mut screen, ckpt } => {
                    if traffic_axis {
                        let (wi, cfg) = &candidates[resp.index];
                        // A suspended run loaded its program worker-side,
                        // so the compile cannot fail here.
                        screen.traffic = *traffic[resp.index].get_or_insert_with(|| {
                            FunctionalModel::new(cfg, &workloads[*wi])
                                .map(|fm| fm.expected_offchip_reads())
                                .unwrap_or(0)
                        });
                    }
                    if let Some(blob) = ckpt {
                        store.insert(resp.index, blob);
                    }
                    State::Undecided(Some(screen))
                }
            };
        }
        hstats.pruned += prune_dominated(&mut states, &widx, &group_outputs, traffic_axis);
        let keep: Vec<bool> = states.iter().map(|s| matches!(s, State::Undecided(_))).collect();
        store.retain(|i| keep[i]);
    }

    // Survivor completion runs, resumed from the stored blobs (each blob
    // released mid-pass as its survivor finishes, instead of the whole
    // set living to the end of the sweep).
    let survivors = undecided_indices(&states);
    let finished = pool.run_pass(
        &survivors,
        opts.kill_after,
        |_, idx| match store.get(idx) {
            Some(blob) => resume_req(idx, &blob, u64::MAX, false),
            None => cold_req(idx, u64::MAX, false),
        },
        |idx| store.remove(idx),
    )?;
    for resp in finished {
        hstats.resumed_cycles += resp.resumed;
        hstats.saved_cycles += resp.saved;
        states[resp.index] = match resp.outcome {
            RespOutcome::Exact { area, power, cycles, efficiency, skipped, jumps, offchip } => {
                hstats.full_runs += 1;
                State::Exact(DesignPoint {
                    config: candidates[resp.index].1.clone(),
                    area,
                    power,
                    cycles,
                    efficiency,
                    on_front: false,
                    skipped_cycles: skipped,
                    ff_jumps: jumps,
                    offchip_reads: offchip,
                    mapping: None,
                })
            }
            RespOutcome::Skip | RespOutcome::Partial { .. } => {
                hstats.skipped += 1;
                State::Skipped
            }
        };
    }
    hstats.worker_items = pool.items.clone();
    hstats.steals = pool.steals;
    hstats.respawns = pool.respawns as u64;
    hstats.backoffs = pool.backoffs;
    hstats.blob_bytes_peak = store.bytes_peak();
    hstats.blob_bytes_inserted = store.bytes_inserted();
    // The release hook drains the store as the completion pass responds;
    // nothing may survive the sweep.
    debug_assert_eq!(store.bytes_now(), 0, "blob store must be empty after the sweep");
    drop(pool);

    let points: Vec<DesignPoint> = states
        .into_iter()
        .enumerate()
        .filter_map(|(i, s)| match s {
            State::Exact(mut p) => {
                if let Some(ms) = mappings {
                    p.mapping = Some(ms[widx[i]]);
                }
                Some(p)
            }
            _ => None,
        })
        .collect();
    Ok(HalvingOutcome {
        points: finalize_axes(points, traffic_axis),
        pruned: bound_pruned,
        stats: hstats,
    })
}

//! The general dimension list behind the DSE search spaces.
//!
//! [`super::SearchSpace`] used to be a closed set of per-level odometer
//! fields; this module factors the space into an explicit list of
//! [`Dim`] values — word width, level count, depth stack, level kinds,
//! last-level ports, and (new) the loop-nest **mapping** — so new
//! dimensions compose with the existing lazy constant-memory odometer
//! instead of growing bespoke fields. The mapping dimension is what
//! [`JointSpace`] adds; the same mechanism is what an off-chip-backend
//! dimension will ride on later (see ROADMAP).
//!
//! A [`Mapping`] is a spatial [`Unrolling`] plus a temporal
//! [`LoopOrder`]. Its *workload* is derived, not configured:
//! [`mapping_workload`] generates the layer's weight address trace under
//! the mapping, normalizes it to the MCU fetch stream
//! ([`crate::pattern::effective_trace`] — a port word held across
//! consecutive steps costs one fetch), classifies it, and emits the
//! [`PatternProgram`] reproducing it. The derivation is **verified on
//! the spot**: the program's `expected_outputs()` must equal the
//! effective trace exactly, or the mapping is rejected as unsupported —
//! so every (mapping, config) candidate the joint sweep scores runs the
//! true fetch stream of that mapping, never an approximation.

use super::search::{Candidates, KindChoice, SearchSpace};
use crate::loopnest::{enumerate_unrollings, weight_trace, LoopDim, LoopOrder, Unrolling};
use crate::model::LayerSpec;
use crate::pattern::{classify_trace, effective_trace, Classification, PatternProgram};

/// A loop-nest mapping: spatial unrolling × temporal loop order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mapping {
    /// Spatial unrolling onto the MAC array.
    pub unrolling: Unrolling,
    /// Temporal loop order of the remaining iterations.
    pub order: LoopOrder,
}

impl Mapping {
    /// The loop order as a compact name, outermost first (e.g. `KCXF`).
    pub fn order_name(&self) -> String {
        self.order
            .0
            .iter()
            .map(|d| match d {
                LoopDim::K => 'K',
                LoopDim::C => 'C',
                LoopDim::X => 'X',
                LoopDim::F => 'F',
            })
            .collect()
    }
}

/// One explorable dimension of a search space. A space is an ordered
/// list of dimensions — earlier entries are slower odometer digits —
/// and enumeration is their lazy cartesian product (with the per-level
/// constraints the config odometer has always enforced: monotone depth
/// stacks, port variants only for standard last levels).
#[derive(Debug, Clone)]
pub enum Dim {
    /// Loop-nest mappings (the joint-search dimension; slowest digit).
    Mapping(Vec<Mapping>),
    /// Candidate word widths (bits).
    WordWidth(Vec<u32>),
    /// Candidate hierarchy level counts.
    LevelCount(Vec<usize>),
    /// Candidate RAM depths per level (monotone non-increasing stacks).
    DepthStack(Vec<u64>),
    /// Level kinds enumerated per level position.
    LevelKinds(Vec<KindChoice>),
    /// Whether to try dual-ported last levels.
    LastLevelPorts(bool),
    /// Storage-protection schemes, applied uniformly to every level
    /// (fastest digit). Absent from a list = unprotected candidates only,
    /// so pre-protection dimension lists enumerate unchanged.
    Protection(Vec<crate::config::Protection>),
}

impl SearchSpace {
    /// This space as a general dimension list (no mapping dimension —
    /// [`JointSpace::dims`] prepends one). The list order mirrors the
    /// odometer significance of [`SearchSpace::candidates`]: word width
    /// slowest, protection fastest.
    pub fn dims(&self) -> Vec<Dim> {
        vec![
            Dim::WordWidth(self.word_widths.clone()),
            Dim::LevelCount(self.depths.clone()),
            Dim::DepthStack(self.ram_depths.clone()),
            Dim::LevelKinds(self.level_kinds.clone()),
            Dim::LastLevelPorts(self.try_dual_ported),
            Dim::Protection(self.protections.clone()),
        ]
    }
}

/// Derive the pattern-program workload a mapping induces on the weight
/// memory: classify the (run-compressed) weight trace and reproduce it
/// as an MCU program. Returns `None` when the mapping's trace is empty,
/// falls outside the MCU-supported families (§5.3: parallel interleaved
/// or pseudo-random streams), or cannot be reproduced exactly — the
/// candidate mapping is then excluded from the joint space, mirroring
/// how invalid configs have always been skipped.
pub fn mapping_workload(layer: &LayerSpec, m: &Mapping) -> Option<PatternProgram> {
    let raw = weight_trace(layer, &m.unrolling, m.order);
    if raw.is_empty() {
        return None;
    }
    let tr = effective_trace(&raw);
    let n = tr.len() as u64;
    let prog = match classify_trace(&raw) {
        Classification::Trivial => PatternProgram::sequential(tr[0], n),
        Classification::Sequential { start } => PatternProgram::sequential(start, n),
        Classification::Strided { start, stride } => PatternProgram::strided(start, stride, n),
        Classification::Cyclic { start, cycle_length } => {
            PatternProgram::cyclic(start, cycle_length).with_outputs(n)
        }
        Classification::ShiftedCyclic { start, cycle_length, inter_cycle_shift, skip_shift } => {
            if inter_cycle_shift > cycle_length {
                return None;
            }
            PatternProgram::shifted_cyclic(start, cycle_length, inter_cycle_shift)
                .with_skip_shift(skip_shift)
                .with_outputs(n)
        }
        Classification::ParallelShiftedCyclic { .. } | Classification::PseudoRandom => return None,
    };
    // Verify the derivation: the program must replay the effective trace
    // bit for bit, whatever the classifier recovered.
    if prog.validate().is_err() || prog.expected_outputs() != tr {
        return None;
    }
    Some(prog)
}

/// The joint mapping × hierarchy search space: a config [`SearchSpace`]
/// extended with a [`Mapping`] dimension over one layer. Every mapping
/// carries its derived weight-stream workload ([`mapping_workload`]), so
/// a joint candidate is a *(mapping index, config)* pair scored against
/// `workloads[mapping index]`.
#[derive(Debug, Clone)]
pub struct JointSpace {
    /// The hierarchy-config half of the space.
    pub space: SearchSpace,
    /// The layer whose weight stream the mappings are evaluated on.
    pub layer: LayerSpec,
    /// The mapping menu, in the pinned enumeration order (unrolling
    /// lexicographic in `(uk, uc, ux)`, loop orders inner), restricted
    /// to mappings whose workload derivation succeeded.
    pub mappings: Vec<Mapping>,
    /// `workloads[i]` is the derived weight stream of `mappings[i]`.
    pub workloads: Vec<PatternProgram>,
}

impl JointSpace {
    /// Build the joint space: all unrollings of `n_macs` MAC units
    /// (factors capped at `n_macs`) crossed with `orders`, keeping only
    /// MCU-supported mappings. The mapping order is pinned: unrollings
    /// in [`enumerate_unrollings`] order (documented lexicographic),
    /// `orders` as given, order fastest.
    pub fn new(space: SearchSpace, layer: LayerSpec, n_macs: u64, orders: &[LoopOrder]) -> Self {
        let mut mappings = Vec::new();
        let mut workloads = Vec::new();
        for u in enumerate_unrollings(n_macs, n_macs) {
            for &order in orders {
                let m = Mapping { unrolling: u, order };
                if let Some(w) = mapping_workload(&layer, &m) {
                    mappings.push(m);
                    workloads.push(w);
                }
            }
        }
        Self { space, layer, mappings, workloads }
    }

    /// The joint space as a dimension list: the mapping dimension
    /// prepended (slowest digit) to the config dimensions.
    pub fn dims(&self) -> Vec<Dim> {
        let mut dims = vec![Dim::Mapping(self.mappings.clone())];
        dims.extend(self.space.dims());
        dims
    }

    /// Lazily enumerate *(mapping index, config)* candidates,
    /// mapping-major: for each mapping in menu order, the full config
    /// odometer in its pinned order. Constant memory, like
    /// [`SearchSpace::candidates`].
    pub fn candidates(&self) -> JointCandidates {
        let config_dims = self.space.dims();
        JointCandidates {
            inner: Candidates::from_dims(&config_dims),
            config_dims,
            n_mappings: self.mappings.len(),
            widx: 0,
        }
    }
}

/// Lazy streaming enumeration of a [`JointSpace`] (see
/// [`JointSpace::candidates`]).
pub struct JointCandidates {
    config_dims: Vec<Dim>,
    n_mappings: usize,
    widx: usize,
    inner: Candidates,
}

impl Iterator for JointCandidates {
    type Item = (usize, crate::config::HierarchyConfig);

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            if self.widx >= self.n_mappings {
                return None;
            }
            if let Some(cfg) = self.inner.next() {
                return Some((self.widx, cfg));
            }
            self.widx += 1;
            if self.widx < self.n_mappings {
                self.inner = Candidates::from_dims(&self.config_dims);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LayerKind, LayerSpec};

    fn small_layer() -> LayerSpec {
        LayerSpec { idx: 0, kind: LayerKind::Conv, k: 16, c: 8, f: 3, x: 4 }
    }

    fn small_space() -> SearchSpace {
        SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![32, 128],
            word_widths: vec![32],
            level_kinds: vec![KindChoice::Standard],
            try_dual_ported: true,
            protections: vec![crate::config::Protection::None],
            eval_hz: 100e6,
        }
    }

    #[test]
    fn mapping_workload_reproduces_effective_trace() {
        // Every supported mapping's derived program must replay the
        // run-compressed weight trace exactly — the oracle the joint
        // sweep's traffic accounting rests on.
        let l = small_layer();
        for u in enumerate_unrollings(16, 16) {
            for order in [LoopOrder::ultratrail(), LoopOrder::output_stationary()] {
                let m = Mapping { unrolling: u, order };
                let Some(prog) = mapping_workload(&l, &m) else { continue };
                let tr = effective_trace(&weight_trace(&l, &u, order));
                assert_eq!(prog.expected_outputs(), tr, "mapping {m:?}");
                assert_eq!(prog.total_outputs, tr.len() as u64);
            }
        }
    }

    #[test]
    fn joint_space_keeps_only_supported_mappings() {
        let joint = JointSpace::new(
            small_space(),
            small_layer(),
            16,
            &[LoopOrder::ultratrail(), LoopOrder::output_stationary()],
        );
        assert_eq!(joint.mappings.len(), joint.workloads.len());
        assert!(joint.mappings.len() >= 4, "got {}", joint.mappings.len());
        for (m, w) in joint.mappings.iter().zip(joint.workloads.iter()) {
            assert_eq!(Some(w), mapping_workload(&joint.layer, m).as_ref());
        }
    }

    #[test]
    fn joint_candidates_are_mapping_major_and_complete() {
        let joint = JointSpace::new(small_space(), small_layer(), 16, &[LoopOrder::ultratrail()]);
        let per_config: Vec<_> = joint.space.candidates().collect();
        let all: Vec<_> = joint.candidates().collect();
        assert_eq!(all.len(), joint.mappings.len() * per_config.len());
        for (i, (widx, cfg)) in all.iter().enumerate() {
            assert_eq!(*widx, i / per_config.len(), "mapping-major order");
            assert_eq!(*cfg, per_config[i % per_config.len()], "config order per mapping");
        }
    }

    #[test]
    fn dims_roundtrip_reproduces_candidates() {
        // A Candidates odometer rebuilt from the dimension list emits the
        // exact sequence SearchSpace::candidates emits.
        let space = small_space();
        let via_dims: Vec<_> = Candidates::from_dims(&space.dims()).collect();
        let direct: Vec<_> = space.candidates().collect();
        assert_eq!(via_dims, direct);
    }

    #[test]
    fn order_name_spells_the_loop_order() {
        let m = Mapping {
            unrolling: Unrolling { uk: 8, uc: 8, ux: 1, uf: 1 },
            order: LoopOrder::ultratrail(),
        };
        assert_eq!(m.order_name(), "KCXF");
        let m = Mapping { order: LoopOrder::output_stationary(), ..m };
        assert_eq!(m.order_name(), "XKCF");
    }
}

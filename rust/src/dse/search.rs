//! Configuration enumeration and simulation-backed scoring.

use super::pareto::pareto_front;
use crate::config::HierarchyConfig;
use crate::cost::{hierarchy_area, run_power};
use crate::mem::Hierarchy;
use crate::pattern::PatternProgram;
use crate::Result;

/// The search space (§4.1 parameters the DSE sweeps).
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Candidate hierarchy depths (1..=5).
    pub depths: Vec<usize>,
    /// Candidate RAM depths per level.
    pub ram_depths: Vec<u64>,
    /// Candidate word widths (bits).
    pub word_widths: Vec<u32>,
    /// Try dual-ported last levels.
    pub try_dual_ported: bool,
    /// Evaluation clock (Hz) for power scoring.
    pub eval_hz: f64,
}

impl Default for SearchSpace {
    fn default() -> Self {
        Self {
            depths: vec![1, 2],
            ram_depths: vec![32, 128, 512, 1024],
            word_widths: vec![32, 128],
            try_dual_ported: true,
            eval_hz: 100e6,
        }
    }
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// The configuration.
    pub config: HierarchyConfig,
    /// Chip area (µm²).
    pub area: f64,
    /// Average power on the workload (W).
    pub power: f64,
    /// Internal cycles to complete the workload.
    pub cycles: u64,
    /// Outputs per cycle.
    pub efficiency: f64,
    /// Whether this point is on the Pareto front (set by [`explore`]).
    pub on_front: bool,
}

/// Enumerate candidate configurations.
///
/// Depth stacks (monotonically shrinking toward the output) are generated
/// by a depth-first odometer over `ram_depths` with one reusable scratch
/// buffer (push/pop), replacing the previous breadth-first construction
/// that cloned every partial stack once per candidate depth — exponential
/// allocation over the depth of the space. The emission order is
/// identical to the old enumeration (lexicographic in depth choices,
/// level 0 most significant), which [`super::pool::HierarchyPool`] relies
/// on for deterministic merges.
pub(crate) fn enumerate(space: &SearchSpace) -> Vec<HierarchyConfig> {
    let mut out = Vec::new();
    let mut scratch: Vec<u64> = Vec::with_capacity(crate::config::MAX_LEVELS);
    for &w in &space.word_widths {
        for &nl in &space.depths {
            descend(space, w, nl, &mut scratch, &mut out);
        }
    }
    out
}

/// One odometer digit: try every depth allowed at this position, recurse
/// for the remaining positions, emit at depth zero.
fn descend(
    space: &SearchSpace,
    w: u32,
    remaining: usize,
    scratch: &mut Vec<u64>,
    out: &mut Vec<HierarchyConfig>,
) {
    if remaining == 0 {
        emit_candidates(space, w, scratch, out);
        return;
    }
    for &d in &space.ram_depths {
        if scratch.last().map_or(true, |&prev| d <= prev) {
            scratch.push(d);
            descend(space, w, remaining - 1, scratch, out);
            scratch.pop();
        }
    }
}

/// Build the configs for one depth stack (single- and, if requested,
/// dual-ported last level).
fn emit_candidates(space: &SearchSpace, w: u32, stack: &[u64], out: &mut Vec<HierarchyConfig>) {
    let port_options: &[u32] = if space.try_dual_ported { &[1, 2] } else { &[1] };
    for &last_ports in port_options {
        let mut b = HierarchyConfig::builder().offchip(32, 24, 1.0);
        for (i, &d) in stack.iter().enumerate() {
            let ports = if i + 1 == stack.len() { last_ports } else { 1 };
            b = b.level(w, d, 1, ports);
        }
        if w > 32 {
            b = b.osr(w.max(64), vec![32]);
        }
        if let Ok(cfg) = b.build() {
            out.push(cfg);
        }
    }
}

/// Score one candidate against the workload by simulation. Returns `None`
/// for configs the program does not align with (packing) or that fail to
/// simulate — the same skip semantics the serial explorer always had.
/// Pure function of its inputs, so candidates can be scored on any
/// thread in any order.
pub(crate) fn evaluate(
    cfg: HierarchyConfig,
    workload: &PatternProgram,
    eval_hz: f64,
) -> Option<DesignPoint> {
    let mut h = Hierarchy::new(&cfg).ok()?;
    if h.load_program(workload).is_err() {
        return None;
    }
    h.set_verify(false);
    let run = h.run().ok()?;
    let area = hierarchy_area(&cfg).total;
    let power = run_power(&cfg, &run.stats, eval_hz).total;
    Some(DesignPoint {
        config: cfg,
        area,
        power,
        cycles: run.stats.internal_cycles,
        efficiency: run.stats.efficiency(),
        on_front: false,
    })
}

/// Mark the Pareto front and sort by area. Shared tail of the serial and
/// pooled explorers: given the same points in the same order it produces
/// bit-for-bit identical results, so determinism reduces to feeding it
/// the evaluation results in enumeration order.
pub(crate) fn finalize(mut points: Vec<DesignPoint>) -> Vec<DesignPoint> {
    let objs: Vec<Vec<f64>> =
        points.iter().map(|p| vec![p.area, p.power, p.cycles as f64]).collect();
    for i in pareto_front(&objs) {
        points[i].on_front = true;
    }
    points.sort_by(|a, b| a.area.total_cmp(&b.area));
    points
}

/// Explore the space against a workload pattern; returns all evaluated
/// points with the Pareto front marked, sorted by area.
///
/// This is the serial reference path; [`super::pool::HierarchyPool`]
/// produces bitwise-identical results on multiple threads.
pub fn explore(space: &SearchSpace, workload: &PatternProgram) -> Result<Vec<DesignPoint>> {
    let points = enumerate(space)
        .into_iter()
        .filter_map(|cfg| evaluate(cfg, workload, space.eval_hz))
        .collect();
    Ok(finalize(points))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_space() -> SearchSpace {
        SearchSpace {
            depths: vec![1, 2],
            ram_depths: vec![32, 128],
            word_widths: vec![32],
            try_dual_ported: true,
            eval_hz: 100e6,
        }
    }

    #[test]
    fn explore_finds_points_and_front() {
        let pts = explore(&small_space(), &PatternProgram::cyclic(0, 64).with_outputs(640)).unwrap();
        assert!(pts.len() >= 4, "got {} points", pts.len());
        assert!(pts.iter().any(|p| p.on_front));
        // Front members are not dominated: quick spot check.
        for p in pts.iter().filter(|p| p.on_front) {
            for q in &pts {
                let dom = q.area < p.area && q.power < p.power && q.cycles < p.cycles;
                assert!(!dom, "front point dominated");
            }
        }
    }

    #[test]
    fn bigger_memory_buys_speed_on_large_windows() {
        // For a window of 128, configs whose last level holds it run ~2x
        // faster than those that stream (Fig 5 economics).
        let pts = explore(&small_space(), &PatternProgram::cyclic(0, 128).with_outputs(1_280)).unwrap();
        let fits = pts
            .iter()
            .filter(|p| p.config.last_level().capacity_words() >= 128)
            .map(|p| p.cycles)
            .min()
            .unwrap();
        let streams = pts
            .iter()
            .filter(|p| p.config.levels.iter().all(|l| l.capacity_words() < 128))
            .map(|p| p.cycles)
            .min();
        if let Some(st) = streams {
            assert!(st as f64 > 1.5 * fits as f64, "fits {fits} vs streams {st}");
        }
    }

    #[test]
    fn enumeration_respects_depth_monotonicity() {
        for cfg in enumerate(&small_space()) {
            let depths: Vec<u64> = cfg.levels.iter().map(|l| l.ram_depth).collect();
            assert!(depths.windows(2).all(|w| w[1] <= w[0]), "{depths:?}");
        }
    }
}
